"""Continuous-batching serve engine over the compiled Myia decode path.

Lifecycle (see docs/serving.md for the full walkthrough):

1. **submit** — requests enter a per-bucket FIFO queue.  A request's
   bucket is the smallest power-of-two ≥ ``prompt_len + max_new`` (so a
   request's cache never migrates: its KV length is fixed at admission).
   Bucketing bounds the number of compiled specializations at
   O(log max_len) — *not* O(distinct lengths) and *not* O(generated
   tokens).
2. **admit** — each bucket owns one slot batch (``n_slots`` lanes of a
   (B, L, D) KV cache).  When a slot is free, the next queued request of
   that bucket is prefilled alone at (1, L) — one compiled prefill per
   bucket — its K/V rows are written into the slot lane, and its first
   token is sampled from the prompt's last-row logits.
3. **step** — all active slots of a batch advance together through ONE
   compiled decode graph call (per-slot positions/done only enter as
   mask *values*, never shapes).  Finished slots (per-slot done mask:
   ``generated == max_new``) free immediately and the queue refills them
   mid-flight — continuous batching, not static batching.
4. **drain** — ``run()`` loops admit→step across buckets until queues
   and slots are empty, returning per-request generations + TTFT.

Compilation accounting: the engine counts one compilation per
(program, bucket) pair it instantiates — the floor is
``2 × |buckets in use|`` (prefill + decode) and ``benchmarks/
bench_serve.py`` gates it exactly.  With a :class:`ProgramCache`
attached, those compilations are durable: a warm process restart replays
the serialized executables and performs zero XLA compiles (asserted by
``tests/serve/test_serve_cache.py``).
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Any, Sequence

import numpy as np

import jax.numpy as jnp

from repro.core import api
from .model import (
    ServeLMDims,
    build_decode_step,
    build_prefill,
    causal_mask,
    decode_masks,
)

__all__ = ["Request", "ServeEngine", "bucket_for", "oracle_generate"]


def bucket_for(total_len: int, *, min_bucket: int = 16, max_bucket: int = 4096) -> int:
    """Smallest power-of-two bucket ≥ ``total_len`` (≥ ``min_bucket``)."""
    if total_len > max_bucket:
        raise ValueError(f"request length {total_len} exceeds max bucket {max_bucket}")
    b = min_bucket
    while b < total_len:
        b *= 2
    return b


class Request:
    """One generation request: a prompt and a token budget."""

    __slots__ = ("rid", "prompt", "max_new", "bucket", "submitted_at", "first_token_at")

    def __init__(self, rid: int, prompt: Sequence[int], max_new: int, bucket: int) -> None:
        self.rid = rid
        self.prompt = list(int(t) for t in prompt)
        self.max_new = int(max_new)
        self.bucket = bucket
        self.submitted_at = time.monotonic()
        self.first_token_at: float | None = None


class _SlotBatch:
    """One bucket's lanes: a (n_slots, L, D) KV cache + per-slot state."""

    def __init__(self, engine: "ServeEngine", bucket: int) -> None:
        B, D = engine.n_slots, engine.dims.d_model
        self.bucket = bucket
        self.engine = engine
        self.kcache = jnp.zeros((B, bucket, D), jnp.float32)
        self.vcache = jnp.zeros((B, bucket, D), jnp.float32)
        self.tok = np.zeros((B,), np.int32)
        self.pos = np.zeros((B,), np.int64)
        self.active: list[Request | None] = [None] * B
        self.out: list[list[int]] = [[] for _ in range(B)]

    def free_slot(self) -> int | None:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.active)

    def admit(self, req: Request, slot: int) -> list[tuple[Request, list[int]]]:
        eng = self.engine
        L = self.bucket
        padded = np.zeros((1, L), np.int32)
        padded[0, : len(req.prompt)] = req.prompt
        logits, k, v = eng._call("prefill", L, eng._prefill_fn)(
            *eng.params, jnp.asarray(padded), causal_mask(L)
        )
        first = int(jnp.argmax(logits[0, len(req.prompt) - 1]))
        req.first_token_at = time.monotonic()
        self.kcache = self.kcache.at[slot].set(k[0])
        self.vcache = self.vcache.at[slot].set(v[0])
        self.tok[slot] = first
        self.pos[slot] = len(req.prompt)
        self.out[slot] = [first]
        self.active[slot] = req
        eng.tokens_generated += 1
        if req.max_new <= 1:
            self.active[slot] = None
            return [(req, self.out[slot])]
        return []

    def step(self) -> list[tuple[Request, list[int]]]:
        if self.n_active == 0:
            return []
        eng = self.engine
        wcol, amask = decode_masks(self.pos, self.bucket)
        logits, self.kcache, self.vcache = eng._call("decode", self.bucket, eng._decode_fn)(
            *eng.params, jnp.asarray(self.tok), self.kcache, self.vcache, wcol, amask
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        eng.steps += 1
        finished: list[tuple[Request, list[int]]] = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.out[s].append(int(nxt[s]))
            self.tok[s] = nxt[s]
            self.pos[s] += 1
            eng.tokens_generated += 1
            if len(self.out[s]) >= req.max_new:
                finished.append((req, self.out[s]))
                self.active[s] = None  # slot frees mid-flight
        return finished


class ServeEngine:
    """Bucketed continuous-batching inference over compiled Myia graphs."""

    def __init__(
        self,
        dims: ServeLMDims,
        params: tuple,
        *,
        n_slots: int = 4,
        min_bucket: int = 16,
        max_bucket: int = 4096,
        program_cache: Any = None,
        fuse: bool = False,
    ) -> None:
        self.dims = dims
        self.params = tuple(params)
        self.n_slots = int(n_slots)
        self.min_bucket = int(min_bucket)
        self.max_bucket = int(max_bucket)
        self.program_cache = program_cache
        self._prefill_fn = api.myia(
            build_prefill(dims), program_cache=program_cache, fuse=fuse
        )
        self._decode_fn = api.myia(
            build_decode_step(dims, self.n_slots), program_cache=program_cache, fuse=fuse
        )
        self._queues: dict[int, deque[Request]] = {}
        self._batches: dict[int, _SlotBatch] = {}
        self._rids = itertools.count()
        self._specs_seen: set[tuple[str, int]] = set()
        self.compilations: dict[str, int] = {"prefill": 0, "decode": 0}
        self.tokens_generated = 0
        self.steps = 0

    # -- compiled-call bookkeeping ----------------------------------------
    def _call(self, kind: str, bucket: int, fn: Any) -> Any:
        spec = (kind, bucket)
        if spec not in self._specs_seen:
            self._specs_seen.add(spec)
            self.compilations[kind] += 1
        return fn

    @property
    def buckets_in_use(self) -> list[int]:
        return sorted(self._batches)

    @property
    def total_compilations(self) -> int:
        return sum(self.compilations.values())

    def compilation_floor(self) -> int:
        """What the bucket policy predicts: prefill + decode per bucket."""
        return 2 * len(self._batches)

    # -- request lifecycle -------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new: int) -> int:
        bucket = bucket_for(
            len(prompt) + max_new, min_bucket=self.min_bucket, max_bucket=self.max_bucket
        )
        req = Request(next(self._rids), prompt, max_new, bucket)
        self._queues.setdefault(bucket, deque()).append(req)
        return req.rid

    def run(self) -> dict[int, dict]:
        """Drain all queues; returns {rid: {tokens, ttft_s, bucket}}."""
        results: dict[int, dict] = {}

        def record(pairs: list[tuple[Request, list[int]]]) -> None:
            for req, toks in pairs:
                results[req.rid] = {
                    "tokens": list(toks),
                    "ttft_s": (req.first_token_at or req.submitted_at) - req.submitted_at,
                    "bucket": req.bucket,
                }

        while any(self._queues.values()) or any(
            b.n_active for b in self._batches.values()
        ):
            # admission: fill every free slot from its bucket's queue
            for bucket, q in self._queues.items():
                if not q:
                    continue
                batch = self._batches.get(bucket)
                if batch is None:
                    batch = self._batches[bucket] = _SlotBatch(self, bucket)
                while q:
                    slot = batch.free_slot()
                    if slot is None:
                        break
                    record(batch.admit(q.popleft(), slot))
            # one decode step per active batch
            for batch in self._batches.values():
                record(batch.step())
        return results

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict:
        out = {
            "n_slots": self.n_slots,
            "min_bucket": self.min_bucket,
            "buckets_in_use": self.buckets_in_use,
            "compilations": dict(self.compilations),
            "total_compilations": self.total_compilations,
            "compilation_floor": self.compilation_floor(),
            "tokens_generated": self.tokens_generated,
            "decode_steps": self.steps,
        }
        if self.program_cache is not None:
            out["program_cache"] = self.program_cache.stats.as_dict()
        return out


def oracle_generate(
    dims: ServeLMDims, params: tuple, prompt: Sequence[int], max_new: int, *, fns=None
) -> list[int]:
    """The pre-runtime serving path, kept as the differential oracle:
    greedy decode by **full-prefix recompute** — every step re-runs the
    whole forward at the grown length, one specialization per length,
    O(T²) total work.  ``fns`` (a dict) can be shared across calls to
    reuse the per-length MyiaFunctions."""
    fns = {} if fns is None else fns
    tokens = [int(t) for t in prompt]
    out: list[int] = []
    for _ in range(max_new):
        t = len(tokens)
        fn = fns.get(t)
        if fn is None:
            fn = fns[t] = api.myia(build_prefill(dims))
        logits, _k, _v = fn(
            *params, jnp.asarray([tokens], jnp.int32), causal_mask(t)
        )
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        tokens.append(nxt)
    return out
