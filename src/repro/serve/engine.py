"""Continuous-batching serve engine over the compiled Myia decode path.

Lifecycle (see docs/serving.md for the full walkthrough):

1. **submit** — requests enter a per-bucket FIFO queue.  A request's
   bucket is the smallest power-of-two ≥ ``prompt_len + max_new`` (so a
   request's cache never migrates: its KV length is fixed at admission).
   Bucketing bounds the number of compiled specializations at
   O(log max_len) — *not* O(distinct lengths) and *not* O(generated
   tokens).  Admission control happens here: an oversized prompt, a
   non-positive token budget, or a full queue (``max_queue``) yields a
   **rejected terminal status** — never an exception out of ``submit``
   and never a request that can wedge the run loop.
2. **admit** — each bucket owns one slot batch (``n_slots`` lanes of a
   (B, L, D) KV cache).  When a slot is free, the next queued request of
   that bucket is prefilled alone at (1, L) — one compiled prefill per
   bucket — its K/V rows are written into the slot lane, and its first
   token is sampled from the prompt's last-row logits.
3. **step** — all active slots of a batch advance together through ONE
   compiled decode graph call (per-slot positions/done only enter as
   mask *values*, never shapes).  Finished slots (per-slot done mask:
   ``generated == max_new``) free immediately and the queue refills them
   mid-flight — continuous batching, not static batching.
4. **drain** — ``run()`` loops admit→step across buckets until queues
   and slots are empty, returning per-request generations + TTFT + a
   terminal ``status``.

Failure containment (docs/serving.md, "Failure modes & degraded
operation"): every request ends in exactly one structured terminal
status — ``ok`` / ``rejected`` / ``timeout`` / ``failed`` — and no
single request can take the engine down:

* **deadlines** — a request carrying ``deadline_s`` (or the engine's
  ``default_deadline_s``) that exceeds it, queued or running, is
  retired with status ``timeout`` (:class:`DeadlineExceeded` taxonomy)
  and its partial tokens; its slot frees immediately,
* **step budget** — ``run()`` computes a hard bound on decode steps
  from the submitted work (override with ``step_budget``); exhausting
  it fails the stragglers and *returns* — the loop provably terminates,
* **NaN/inf sentinel** — non-finite logits on an active lane fail only
  that lane (status ``failed``, :class:`NumericalFault`); the rest of
  the batch decodes on, bit-identical to the unpoisoned run,
* **admission/step exceptions** — an exception inside a compiled call
  fails the affected request(s), never the process.

Compilation accounting: the engine counts one compilation per
(program, bucket) pair it instantiates — the floor is
``2 × |buckets in use|`` (prefill + decode) and ``benchmarks/
bench_serve.py`` gates it exactly.  With a :class:`ProgramCache`
attached, those compilations are durable: a warm process restart replays
the serialized executables and performs zero XLA compiles (asserted by
``tests/serve/test_serve_cache.py``).  The chaos corpus
(``tests/serve/test_chaos.py``) drives every fault class above through
``repro.serve.faults`` and pins the invariants.
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Any, Sequence

import numpy as np

import jax.numpy as jnp

from repro.core import api
from repro.obs import metrics as obs_metrics, trace as obs_trace
from . import faults
from .model import (
    ServeLMDims,
    build_decode_step,
    build_prefill,
    causal_mask,
    decode_masks,
    finite_lanes,
)

__all__ = [
    "Request",
    "ServeEngine",
    "ServeError",
    "RequestRejected",
    "DeadlineExceeded",
    "NumericalFault",
    "bucket_for",
    "oracle_generate",
    "request_telemetry",
]


class ServeError(Exception):
    """Base of the serving fault taxonomy.  The engine never lets these
    escape ``run()`` — they become per-request terminal statuses — but
    the classes give failures a name and a machine-readable ``reason``."""

    reason = "serve_error"


class RequestRejected(ServeError):
    """Refused at admission: oversize, zero budget, or queue full."""

    reason = "rejected"


class DeadlineExceeded(ServeError):
    """The request outlived its deadline (queued or mid-generation)."""

    reason = "deadline"


class NumericalFault(ServeError):
    """Non-finite logits on the request's lane (NaN/inf sentinel)."""

    reason = "nonfinite_logits"


def bucket_for(total_len: int, *, min_bucket: int = 16, max_bucket: int = 4096) -> int:
    """Smallest power-of-two bucket ≥ ``total_len`` (≥ ``min_bucket``)."""
    if total_len > max_bucket:
        raise ValueError(f"request length {total_len} exceeds max bucket {max_bucket}")
    b = min_bucket
    while b < total_len:
        b *= 2
    return b


class Request:
    """One generation request: a prompt, a token budget, a deadline."""

    __slots__ = (
        "rid", "prompt", "max_new", "bucket", "submitted_at",
        "first_token_at", "deadline_s", "status", "error", "reason",
    )

    def __init__(
        self,
        rid: int,
        prompt: Sequence[int],
        max_new: int,
        bucket: int | None,
        deadline_s: float | None = None,
    ) -> None:
        self.rid = rid
        self.prompt = list(int(t) for t in prompt)
        self.max_new = int(max_new)
        self.bucket = bucket
        self.submitted_at = time.monotonic()
        self.first_token_at: float | None = None
        self.deadline_s = deadline_s
        self.status = "queued"
        self.error: str | None = None
        self.reason: str | None = None

    def expired(self, now: float) -> bool:
        return self.deadline_s is not None and (now - self.submitted_at) > self.deadline_s


class _SlotBatch:
    """One bucket's lanes: a (n_slots, L, D) KV cache + per-slot state."""

    def __init__(self, engine: "ServeEngine", bucket: int) -> None:
        B, D = engine.n_slots, engine.dims.d_model
        self.bucket = bucket
        self.engine = engine
        self.kcache = jnp.zeros((B, bucket, D), jnp.float32)
        self.vcache = jnp.zeros((B, bucket, D), jnp.float32)
        self.tok = np.zeros((B,), np.int32)
        self.pos = np.zeros((B,), np.int64)
        self.active: list[Request | None] = [None] * B
        self.out: list[list[int]] = [[] for _ in range(B)]

    def free_slot(self) -> int | None:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.active)

    def admit(self, req: Request, slot: int) -> list[tuple[Request, list[int]]]:
        eng = self.engine
        L = self.bucket
        if obs_trace.active() is not None:
            admitted_at = time.monotonic()
            obs_trace.mark(
                "serve.admitted", ts=admitted_at, rid=req.rid, bucket=L, slot=slot
            )
            eng._observe_ms("serve.queue_ms", L, admitted_at - req.submitted_at)
        padded = np.zeros((1, L), np.int32)
        padded[0, : len(req.prompt)] = req.prompt
        with obs_trace.span("serve.prefill", rid=req.rid, bucket=L, slot=slot):
            logits, k, v = eng._call("prefill", L, eng._prefill_fn)(
                *eng.params, jnp.asarray(padded), causal_mask(L)
            )
        logits = faults.poison_logits(logits, eng.admissions, site="prefill")
        eng.admissions += 1
        row = logits[0, len(req.prompt) - 1]
        if not bool(finite_lanes(row[None])[0]):
            eng.slot_faults += 1
            eng._finish(req, NumericalFault, "non-finite prefill logits")
            return [(req, [])]
        first = int(jnp.argmax(row))
        req.first_token_at = time.monotonic()
        if obs_trace.active() is not None:
            obs_trace.mark("serve.first_token", ts=req.first_token_at, rid=req.rid)
            eng._observe_ms(
                "serve.ttft_ms", L, req.first_token_at - req.submitted_at
            )
        self.kcache = self.kcache.at[slot].set(k[0])
        self.vcache = self.vcache.at[slot].set(v[0])
        self.tok[slot] = first
        self.pos[slot] = len(req.prompt)
        self.out[slot] = [first]
        self.active[slot] = req
        req.status = "running"
        eng.tokens_generated += 1
        if req.max_new <= 1:
            self.active[slot] = None
            eng._finish(req, None, None)
            return [(req, self.out[slot])]
        return []

    def fail_all(
        self, exc: type[ServeError], msg: str, *, reason: str | None = None
    ) -> list[tuple[Request, list[int]]]:
        """Retire every active lane with ``status=failed`` (containment
        path for an exception out of the shared decode call, or budget
        exhaustion).  Partial tokens are preserved in the results."""
        eng = self.engine
        done: list[tuple[Request, list[int]]] = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            eng._finish(req, exc, msg)
            if reason is not None:
                req.reason = reason
            self.active[s] = None
            done.append((req, self.out[s]))
        return done

    def step(self) -> list[tuple[Request, list[int]]]:
        if self.n_active == 0:
            return []
        eng = self.engine
        sp = obs_trace.span(
            "serve.decode_step", bucket=self.bucket, n_active=self.n_active
        )
        with sp:
            finished = self._step_body()
        if sp is not obs_trace.NULL_SPAN:
            eng._observe_ms("serve.decode_step_ms", self.bucket, sp.dur_s)
        return finished

    def _step_body(self) -> list[tuple[Request, list[int]]]:
        eng = self.engine
        faults.on_decode_step(self.bucket)
        wcol, amask = decode_masks(self.pos, self.bucket)
        logits, self.kcache, self.vcache = eng._call("decode", self.bucket, eng._decode_fn)(
            *eng.params, jnp.asarray(self.tok), self.kcache, self.vcache, wcol, amask
        )
        logits = faults.poison_logits(logits, eng.steps, site="decode")
        finite = finite_lanes(logits)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        eng.steps += 1
        now = time.monotonic()
        finished: list[tuple[Request, list[int]]] = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if not bool(finite[s]):
                # NaN/inf sentinel: fail ONLY the poisoned lane — the
                # batch's other lanes never see its values (attention,
                # MLP and argmax are all lane-local) and decode on
                eng.slot_faults += 1
                eng._finish(
                    req, NumericalFault, f"non-finite logits at step {eng.steps - 1}"
                )
                self.active[s] = None
                finished.append((req, self.out[s]))
                continue
            self.out[s].append(int(nxt[s]))
            self.tok[s] = nxt[s]
            self.pos[s] += 1
            eng.tokens_generated += 1
            if len(self.out[s]) >= req.max_new:
                eng._finish(req, None, None)
                self.active[s] = None  # slot frees mid-flight
                finished.append((req, self.out[s]))
            elif req.expired(now):
                eng._finish(
                    req, DeadlineExceeded, f"deadline {req.deadline_s}s exceeded"
                )
                self.active[s] = None
                finished.append((req, self.out[s]))
        return finished


class ServeEngine:
    """Bucketed continuous-batching inference over compiled Myia graphs.

    Robustness knobs (all optional — defaults preserve the PR-5
    behavior): ``max_queue`` bounds the total queued requests
    (backpressure: over it, ``submit`` rejects), ``default_deadline_s``
    applies to requests submitted without an explicit deadline, and
    ``step_budget`` overrides the computed per-``run()`` decode-step
    bound."""

    def __init__(
        self,
        dims: ServeLMDims,
        params: tuple,
        *,
        n_slots: int = 4,
        min_bucket: int = 16,
        max_bucket: int = 4096,
        program_cache: Any = None,
        fuse: bool = False,
        max_queue: int | None = None,
        default_deadline_s: float | None = None,
        step_budget: int | None = None,
        trace: Any = None,
    ) -> None:
        self.dims = dims
        self.params = tuple(params)
        self.n_slots = int(n_slots)
        self.min_bucket = int(min_bucket)
        self.max_bucket = int(max_bucket)
        self.program_cache = program_cache
        self.max_queue = max_queue
        self.default_deadline_s = default_deadline_s
        self.step_budget = step_budget
        #: engine-owned tracer (``repro.obs.trace.Tracer``); armed for the
        #: extent of every ``submit``/``run`` call so lifecycle spans land
        #: without the caller managing a ``tracing(...)`` block.  An
        #: ambient tracer armed by the caller works too — ``trace=None``
        #: simply defers to it.
        self.trace = trace
        #: per-bucket latency histograms (TTFT / time-in-queue /
        #: decode-step), populated only while a tracer is armed — the
        #: disarmed serve hot path does zero telemetry work
        self.telemetry = obs_metrics.MetricsRegistry()
        opts = api.CompileOptions(program_cache=program_cache, fuse=fuse)
        self._prefill_fn = api.myia(build_prefill(dims), options=opts)
        self._decode_fn = api.myia(build_decode_step(dims, self.n_slots), options=opts)
        self._queues: dict[int, deque[Request]] = {}
        self._batches: dict[int, _SlotBatch] = {}
        self._rids = itertools.count()
        self._specs_seen: set[tuple[str, int]] = set()
        #: requests that reached a terminal state (any status) — results
        #: rows are built from here; rejected-at-submit land immediately
        self._done: dict[int, Request] = {}
        #: rejected-at-submit requests awaiting their results row (drained
        #: by the next ``run()`` so a later run does not re-report them)
        self._unreported: list[Request] = []
        self.compilations: dict[str, int] = {"prefill": 0, "decode": 0}
        self.tokens_generated = 0
        self.steps = 0
        self.admissions = 0
        self.slot_faults = 0
        self.admit_failures = 0
        self.step_failures = 0
        self.queue_peak = 0
        self.budget_exhausted = 0
        self.last_step_budget: int | None = None
        self.rejected = {"oversize": 0, "zero_budget": 0, "queue_full": 0}
        self.status_counts = {"ok": 0, "rejected": 0, "timeout": 0, "failed": 0}

    # -- telemetry ---------------------------------------------------------
    def _observe_ms(self, name: str, bucket: int, value_s: float) -> None:
        """Record ``value_s`` (seconds) into the per-bucket latency
        histogram ``name.b<bucket>`` — call sites gate on an armed tracer,
        so this never runs in the disarmed configuration."""
        self.telemetry.histogram(f"{name}.b{bucket}").observe(value_s * 1e3)

    # -- compiled-call bookkeeping ----------------------------------------
    def _call(self, kind: str, bucket: int, fn: Any) -> Any:
        spec = (kind, bucket)
        if spec not in self._specs_seen:
            self._specs_seen.add(spec)
            self.compilations[kind] += 1
        return fn

    @property
    def buckets_in_use(self) -> list[int]:
        return sorted(self._batches)

    @property
    def total_compilations(self) -> int:
        return sum(self.compilations.values())

    def compilation_floor(self) -> int:
        """What the bucket policy predicts: prefill + decode per bucket."""
        return 2 * len(self._batches)

    # -- terminal bookkeeping ----------------------------------------------
    def _finish(
        self, req: Request, exc: type[ServeError] | None, msg: str | None
    ) -> None:
        """Move ``req`` to its terminal status exactly once."""
        if req.rid in self._done:
            return
        if exc is None:
            req.status, req.reason, req.error = "ok", None, None
        elif exc is RequestRejected:
            req.status, req.reason, req.error = "rejected", RequestRejected.reason, msg
        elif exc is DeadlineExceeded:
            req.status, req.reason, req.error = "timeout", DeadlineExceeded.reason, msg
        else:
            req.status = "failed"
            req.reason = getattr(exc, "reason", ServeError.reason)
            req.error = msg
        self.status_counts[req.status] += 1
        self._done[req.rid] = req
        obs_trace.mark(
            "serve.terminal", rid=req.rid, status=req.status, reason=req.reason
        )

    def _reject(self, req: Request, kind: str, msg: str) -> int:
        self.rejected[kind] += 1
        # the taxonomy reason is refined to the admission-control kind so
        # callers can tell a full queue from a hopeless request
        self._finish(req, RequestRejected, msg)
        req.reason = kind
        self._unreported.append(req)
        return req.rid

    def _result_row(self, req: Request, tokens: list[int]) -> dict:
        return {
            "tokens": list(tokens),
            "ttft_s": (
                None
                if req.first_token_at is None
                else req.first_token_at - req.submitted_at
            ),
            "bucket": req.bucket,
            "status": req.status,
            "reason": req.reason,
            "error": req.error,
        }

    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # -- request lifecycle -------------------------------------------------
    def submit(
        self, prompt: Sequence[int], max_new: int, *, deadline_s: float | None = None
    ) -> int:
        """Admit a request; always returns a rid, never raises.

        Hopeless or unadmittable requests (token budget ≤ 0, total
        length over ``max_bucket``, queue at ``max_queue``) reach the
        terminal status ``rejected`` immediately — visible in the
        ``run()`` results and ``status_counts`` — instead of leaking
        ``ValueError`` to the caller or wedging the run loop."""
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        rid = next(self._rids)
        total = len(prompt) + max(int(max_new), 0)
        req = Request(rid, prompt, max_new, bucket=None, deadline_s=deadline_s)
        with obs_trace.tracing(self.trace):
            obs_trace.mark(
                "serve.submit",
                ts=req.submitted_at,
                rid=rid,
                prompt_len=len(req.prompt),
                max_new=req.max_new,
            )
            if max_new <= 0:
                return self._reject(
                    req, "zero_budget", f"max_new={max_new} requests no tokens"
                )
            if total > self.max_bucket:
                return self._reject(
                    req,
                    "oversize",
                    f"prompt+max_new={total} exceeds max bucket {self.max_bucket}",
                )
            if self.max_queue is not None and self.queued >= self.max_queue:
                return self._reject(
                    req, "queue_full", f"queue at capacity ({self.max_queue})"
                )
        req.bucket = bucket_for(
            total, min_bucket=self.min_bucket, max_bucket=self.max_bucket
        )
        self._queues.setdefault(req.bucket, deque()).append(req)
        self.queue_peak = max(self.queue_peak, self.queued)
        return rid

    def _default_step_budget(self) -> int:
        """A provable upper bound on useful decode steps for the pending
        work: serialized, every request needs < ``max_new`` steps (the
        first token comes from prefill), so 2× the sum plus slack can
        only be exhausted by a liveness bug or an injected hang — the
        run loop then *fails the stragglers and returns* instead of
        spinning."""
        pending = sum(r.max_new for q in self._queues.values() for r in q)
        for b in self._batches.values():
            for s, r in enumerate(b.active):
                if r is not None:
                    pending += max(r.max_new - len(b.out[s]), 1)
        return 2 * pending + 16 * (len(self._queues) + len(self._batches) + 1)

    def run(self, *, step_budget: int | None = None) -> dict[int, dict]:
        """Drain all queues; returns ``{rid: {tokens, ttft_s, bucket,
        status, reason, error}}`` — one terminal row per submitted rid,
        including requests rejected at ``submit`` time.  Guaranteed to
        terminate: bounded by the step budget even under injected hangs,
        poisoned numerics, or compiled-call exceptions."""
        with obs_trace.tracing(self.trace):
            return self._run_body(step_budget)

    def _run_body(self, step_budget: int | None) -> dict[int, dict]:
        results: dict[int, dict] = {}

        def record(pairs: list[tuple[Request, list[int]]]) -> None:
            for req, toks in pairs:
                results[req.rid] = self._result_row(req, toks)

        record([(req, []) for req in self._unreported])  # rejected at submit
        self._unreported.clear()
        budget = (
            step_budget
            if step_budget is not None
            else (self.step_budget or self._default_step_budget())
        )
        self.last_step_budget = budget
        steps_used = 0

        while any(self._queues.values()) or any(
            b.n_active for b in self._batches.values()
        ):
            # admission: fill every free slot from its bucket's queue
            for bucket, q in self._queues.items():
                if not q:
                    continue
                batch = self._batches.get(bucket)
                if batch is None:
                    batch = self._batches[bucket] = _SlotBatch(self, bucket)
                while q:
                    if q[0].expired(time.monotonic()):
                        req = q.popleft()
                        self._finish(
                            req, DeadlineExceeded,
                            f"deadline {req.deadline_s}s exceeded in queue",
                        )
                        record([(req, [])])
                        continue
                    slot = batch.free_slot()
                    if slot is None:
                        break
                    req = q.popleft()
                    try:
                        record(batch.admit(req, slot))
                    except Exception as e:  # compiled call blew up: contain
                        self.admit_failures += 1
                        self._finish(req, ServeError, f"admission failed: {e!r}")
                        record([(req, [])])
            # one decode step per active batch
            for batch in self._batches.values():
                if batch.n_active == 0 or steps_used >= budget:
                    continue
                steps_used += 1
                try:
                    record(batch.step())
                except Exception as e:  # shared decode call blew up
                    self.step_failures += 1
                    record(batch.fail_all(ServeError, f"decode step failed: {e!r}"))
            if steps_used >= budget and any(
                b.n_active for b in self._batches.values()
            ):
                # budget exhausted with work still active: a liveness
                # fault (hang, runaway request).  Fail the stragglers,
                # return — run() must never spin forever.
                self.budget_exhausted += 1
                msg = f"step budget ({budget}) exhausted"
                for batch in self._batches.values():
                    record(batch.fail_all(ServeError, msg, reason="step_budget"))
                for q in self._queues.values():
                    while q:
                        req = q.popleft()
                        self._finish(req, ServeError, msg)
                        req.reason = "step_budget"
                        record([(req, [])])
                break
        return results

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict:
        out = {
            "n_slots": self.n_slots,
            "min_bucket": self.min_bucket,
            "buckets_in_use": self.buckets_in_use,
            "compilations": dict(self.compilations),
            "total_compilations": self.total_compilations,
            "compilation_floor": self.compilation_floor(),
            "tokens_generated": self.tokens_generated,
            "decode_steps": self.steps,
            # robustness / backpressure telemetry
            "statuses": dict(self.status_counts),
            "rejected": dict(self.rejected),
            "queued": self.queued,
            "queue_peak": self.queue_peak,
            "slot_faults": self.slot_faults,
            "admit_failures": self.admit_failures,
            "step_failures": self.step_failures,
            "budget_exhausted": self.budget_exhausted,
            "last_step_budget": self.last_step_budget,
        }
        if self.program_cache is not None:
            out["program_cache"] = self.program_cache.stats.as_dict()
        telemetry = self.telemetry.as_dict()
        if telemetry:
            out["telemetry"] = telemetry
        return out


def request_telemetry(tracer: Any) -> dict[int, dict]:
    """Rebuild per-request lifecycle timings from a tracer's serve spans.

    Returns ``{rid: {status, reason, bucket, ttft_ms, queue_ms, gen_ms}}``
    assembled purely from the ``serve.submit`` / ``serve.admitted`` /
    ``serve.first_token`` / ``serve.terminal`` marks the engine emits.
    Because the submit and first-token marks carry the engine's own
    ``time.monotonic()`` readings (``Request.submitted_at`` /
    ``first_token_at``), the derived ``ttft_ms`` equals the engine's
    reported ``ttft_s`` exactly — not approximately (pinned by
    ``tests/obs/test_serve_telemetry.py``).  Timings a request never
    reached (e.g. TTFT of a rejected request) are ``None``."""
    rows: dict[int, dict] = {}

    def row(rid: int) -> dict:
        return rows.setdefault(
            rid,
            {
                "rid": rid,
                "status": None,
                "reason": None,
                "bucket": None,
                "submitted_t": None,
                "ttft_ms": None,
                "queue_ms": None,
                "gen_ms": None,
                "_first_token_t": None,
            },
        )

    for e in tracer.events:
        if e.kind != "mark" or not e.name.startswith("serve."):
            continue
        rid = e.attrs.get("rid")
        if rid is None:
            continue
        r = row(rid)
        if e.name == "serve.submit":
            r["submitted_t"] = e.t0
        elif e.name == "serve.admitted":
            r["bucket"] = e.attrs.get("bucket")
            if r["submitted_t"] is not None:
                r["queue_ms"] = (e.t0 - r["submitted_t"]) * 1e3
        elif e.name == "serve.first_token":
            r["_first_token_t"] = e.t0
            if r["submitted_t"] is not None:
                r["ttft_ms"] = (e.t0 - r["submitted_t"]) * 1e3
        elif e.name == "serve.terminal":
            r["status"] = e.attrs.get("status")
            r["reason"] = e.attrs.get("reason")
            if r["_first_token_t"] is not None:
                r["gen_ms"] = (e.t0 - r["_first_token_t"]) * 1e3
    for r in rows.values():
        del r["_first_token_t"]
    return rows


def oracle_generate(
    dims: ServeLMDims, params: tuple, prompt: Sequence[int], max_new: int, *, fns=None
) -> list[int]:
    """The pre-runtime serving path, kept as the differential oracle:
    greedy decode by **full-prefix recompute** — every step re-runs the
    whole forward at the grown length, one specialization per length,
    O(T²) total work.  ``fns`` (a dict) can be shared across calls to
    reuse the per-length MyiaFunctions."""
    fns = {} if fns is None else fns
    tokens = [int(t) for t in prompt]
    out: list[int] = []
    for _ in range(max_new):
        t = len(tokens)
        fn = fns.get(t)
        if fn is None:
            fn = fns[t] = api.myia(build_prefill(dims))
        logits, _k, _v = fn(
            *params, jnp.asarray([tokens], jnp.int32), causal_mask(t)
        )
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        tokens.append(nxt)
    return out
