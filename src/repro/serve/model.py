"""Myia-subset serving model: causal-attention LM with incremental decode.

The train-side LM (``launch/myia_step``) is position-independent (a
tanh-MLP over embeddings), so serving it incrementally would be trivial.
This model adds the part that makes serving a real problem — a causal
single-head attention block — written entirely in the Myia subset, so the
whole decode path goes through parse → infer → worklist-optimize → fuse →
lower and lands in the AOT program cache like any other compiled graph.

Two entry points, both pure functions of arrays (no Python state):

* :func:`build_prefill` — full-sequence forward over a (B, S) token grid
  with an explicit causal mask argument; returns ``(logits, k, v)`` so the
  caller keeps the attention cache.  This is also the *full-prefix
  oracle*: evaluated at every growing length it reproduces exactly what
  ``launch/serve.py --compiler myia`` did before the serving runtime —
  one specialization per length, O(T²) work.
* :func:`build_decode_step` — one token per slot against a fixed-length
  KV cache: the new K/V row is written functionally (``where`` on a
  one-hot column mask — no in-place mutation, the carry is a plain
  tuple), attention reads only rows ``<= pos`` via the attend mask, and
  the step returns ``(logits, kcache', vcache')`` as a tuple carry.  One
  specialization per cache bucket, O(T) per generated token.

Mask/position tensors are *arguments*, not baked constants, so a single
graph serves every request position at a bucket and the abstract
signature (hence the AOT cache key) depends only on (n_slots, bucket).
"""

from __future__ import annotations

import functools

import numpy as np

import jax

from repro.launch.myia_step import MyiaLMDims
import repro.core.primitives as P

__all__ = [
    "ServeLMDims",
    "build_prefill",
    "build_decode_step",
    "init_serve_params",
    "causal_mask",
    "decode_masks",
    "finite_lanes",
]

#: serving reuses the train-side dims object (vocab, d_model, d_hidden)
ServeLMDims = MyiaLMDims

_take = P.take
_tanh = P.tanh
_exp = P.exp
_rsum = P.reduce_sum
_rmax = P.reduce_max
_mT = P.mT
_where = P.where
_reshape = P.reshape

_NEG_INF = float("-inf")


def init_serve_params(dims: ServeLMDims, rng: jax.Array) -> tuple:
    """(emb, wq, wk, wv, w1, w2, wout) — the decode/prefill signature."""
    import jax.numpy as jnp

    k = jax.random.split(rng, 7)
    s = 0.1
    D, H, V = dims.d_model, dims.d_hidden, dims.vocab
    return (
        jax.random.normal(k[0], (V, D), jnp.float32) * s,
        jax.random.normal(k[1], (D, D), jnp.float32) * s,
        jax.random.normal(k[2], (D, D), jnp.float32) * s,
        jax.random.normal(k[3], (D, D), jnp.float32) * s,
        jax.random.normal(k[4], (D, H), jnp.float32) * s,
        jax.random.normal(k[5], (H, D), jnp.float32) * s,
        jax.random.normal(k[6], (D, V), jnp.float32) * s,
    )


def build_prefill(dims: ServeLMDims):
    """Full-sequence forward: (params…, tokens (B,S), cmask (1,S,S)) →
    (logits (B,S,V), k (B,S,D), v (B,S,D)).

    Shape-polymorphic over B and S (the mask arrives as an argument), so
    one builder covers prefill at every bucket AND the per-length
    full-prefix oracle."""
    scale = 1.0 / float(np.sqrt(dims.d_model))
    neg_inf = _NEG_INF

    def serve_prefill(emb, wq, wk, wv, w1, w2, wout, tokens, cmask):
        h0 = _take(emb, tokens)
        q = h0 @ wq
        k = h0 @ wk
        v = h0 @ wv
        s = (q @ _mT(k)) * scale
        s = _where(cmask, s, neg_inf)
        m = _rmax(s, (2,), True)
        e = _exp(s - m)
        p = e / _rsum(e, (2,), True)
        h = h0 + (p @ v)
        h = _tanh(h @ w1)
        h = _tanh(h @ w2)
        return (h @ wout, k, v)

    return serve_prefill


def build_decode_step(dims: ServeLMDims, n_slots: int):
    """Single-token decode against a bucket-length KV cache.

    (params…, tok (B,), kcache (B,L,D), vcache (B,L,D), wcol (B,L,1)
    bool, amask (B,1,L) bool) → (logits (B,V), kcache', vcache').

    ``wcol`` is the one-hot write column at each slot's position —
    ``where(wcol, k_new, kcache)`` is the functional cache write — and
    ``amask`` admits exactly rows ``<= pos`` to the softmax (stale rows
    past the position are masked to −inf and contribute exact zeros).
    The cache length L only enters through argument shapes: one
    specialization per bucket, replayed for every step at that bucket."""
    D = dims.d_model
    scale = 1.0 / float(np.sqrt(D))
    neg_inf = _NEG_INF
    row3 = (n_slots, 1, D)
    flat2 = (n_slots, D)

    def serve_decode(emb, wq, wk, wv, w1, w2, wout, tok, kcache, vcache, wcol, amask):
        h0 = _take(emb, tok)
        q = h0 @ wq
        k = h0 @ wk
        v = h0 @ wv
        kc = _where(wcol, _reshape(k, row3), kcache)
        vc = _where(wcol, _reshape(v, row3), vcache)
        s = (_reshape(q, row3) @ _mT(kc)) * scale
        s = _where(amask, s, neg_inf)
        m = _rmax(s, (2,), True)
        e = _exp(s - m)
        p = e / _rsum(e, (2,), True)
        h = h0 + _reshape(p @ vc, flat2)
        h = _tanh(h @ w1)
        h = _tanh(h @ w2)
        return (h @ wout, kc, vc)

    return serve_decode


# -- host-side mask helpers (plain jnp; tiny, recomputed per step) ----------


@functools.lru_cache(maxsize=32)
def causal_mask(seq: int):
    """(1, S, S) lower-triangular bool mask for :func:`build_prefill`.
    Memoized per length — admissions reuse the device array instead of
    re-building and re-uploading an S×S host mask per request."""
    import jax.numpy as jnp

    return jnp.asarray(np.tril(np.ones((seq, seq), bool)))[None, :, :]


def decode_masks(pos, bucket: int):
    """(wcol (B,L,1), amask (B,1,L)) for integer positions ``pos`` (B,)."""
    import jax.numpy as jnp

    pos = jnp.asarray(pos, jnp.int32)
    ar = jnp.arange(bucket, dtype=jnp.int32)
    wcol = (ar[None, :] == pos[:, None])[:, :, None]
    amask = (ar[None, :] <= pos[:, None])[:, None, :]
    return wcol, amask


def finite_lanes(logits) -> np.ndarray:
    """Per-lane NaN/inf sentinel: (B, …, V) logits → (B,) bool, True where
    the lane's logits are all finite.  Every op in the serve model is
    lane-local (per-row matmuls, per-slot attention over the lane's own
    KV rows), so a non-finite lane is *contained*: the engine fails only
    that slot (:class:`repro.serve.engine.NumericalFault`) and the other
    lanes' streams stay bit-identical to an unpoisoned run — the chaos
    corpus pins this."""
    import jax.numpy as jnp

    axes = tuple(range(1, logits.ndim))
    return np.asarray(jnp.isfinite(logits).all(axis=axes))
