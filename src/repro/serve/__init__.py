"""``repro.serve`` — the inference runtime over the compiled Myia pipeline.

Serving is where ahead-of-time compilation pays or dies: the same
optimized graphs the trainer lowers are specialized per shape *bucket*
(bounded, not per-length), compiled once, persisted in the AOT program
cache (``repro.core.jax_backend.ProgramCache``), and replayed across
process restarts with zero recompilation.  See docs/serving.md.
"""

from .engine import (  # noqa: F401
    DeadlineExceeded,
    NumericalFault,
    Request,
    RequestRejected,
    ServeEngine,
    ServeError,
    bucket_for,
    oracle_generate,
)
from .faults import (  # noqa: F401
    CacheFault,
    CompileFault,
    DecodeNaN,
    FaultPlan,
    StepDelay,
    inject_faults,
)
from .model import (  # noqa: F401
    ServeLMDims,
    build_decode_step,
    build_prefill,
    causal_mask,
    decode_masks,
    finite_lanes,
    init_serve_params,
)
