"""Deterministic fault injection for the serving runtime.

The hardened engine (docs/serving.md, "Failure modes & degraded
operation") claims a set of invariants — corrupt cache entries are
quarantined and recompiled around, failing compiles retry and then
degrade to the VM oracle, a NaN'd decode slot fails alone, a hung step
trips the request deadline instead of wedging ``run()``.  Claims about
failure behavior are worthless untested, and the real triggers (disk
corruption, OOM'd XLA, fp overflow) are not reproducible on demand — so
this module makes every fault class *injectable*, deterministically,
at explicit hook points:

* :class:`CacheFault` — corrupt/truncate/delete AOT-cache entry files
  just before ``ProgramCache._read`` opens them,
* :class:`CompileFault` — make the first N XLA compile attempts raise
  :class:`InjectedCompileError` (or sleep, simulating a hang) inside
  ``ProgramCache`` / the fallback ladder,
* :class:`DecodeNaN` — overwrite one slot's logits with NaN/inf after a
  chosen decode step (or a chosen prefill admission),
* :class:`StepDelay` — sleep before decode steps, so deadlines fire.

Usage (the chaos corpus, ``tests/serve/test_chaos.py``):

    plan = FaultPlan(seed=0, compile_fault=CompileFault(kind="raise", count=1))
    with inject_faults(plan):
        engine.run()
    assert plan.fired["compile"] == 1

Every hook is a module-level function whose fast path is a single
``_ACTIVE is None`` check — **zero overhead when no plan is armed**, and
production code paths never import anything else from here.  Plans are
explicit (fire at step K / first N attempts) rather than sampled, so a
chaos run is exactly reproducible; the ``seed`` only feeds the garbage
bytes written by :class:`CacheFault`.
"""

from __future__ import annotations

import contextlib
import os
import random
import time
from dataclasses import dataclass, field

__all__ = [
    "CacheFault",
    "CompileFault",
    "DecodeNaN",
    "StepDelay",
    "FaultPlan",
    "InjectedFault",
    "InjectedCompileError",
    "inject_faults",
    "active",
    "on_cache_read",
    "on_compile",
    "on_decode_step",
    "poison_logits",
]


class InjectedFault(Exception):
    """Base of every exception raised by an armed fault plan."""


class InjectedCompileError(InjectedFault):
    """An injected XLA-compile failure (stands in for OOM, backend bugs)."""


@dataclass
class CacheFault:
    """Damage AOT-cache entry files as they are about to be read.

    ``mode``: ``garbage`` (overwrite with random bytes), ``truncate``
    (cut the file to ``keep_bytes``), or ``delete``.  ``count`` bounds
    how many distinct files are damaged (``None`` = all)."""

    mode: str = "garbage"
    count: int | None = None
    keep_bytes: int = 16


@dataclass
class CompileFault:
    """Fail (or hang) the first ``count`` XLA compile attempts.

    ``kind="raise"`` raises :class:`InjectedCompileError`;
    ``kind="hang"`` sleeps ``hang_s`` (a *finite* stand-in for a hung
    compile — the engine's deadline layer must absorb it)."""

    kind: str = "raise"
    count: int = 1
    hang_s: float = 0.0


@dataclass
class DecodeNaN:
    """Overwrite slot ``slot``'s logits with ``value`` at one point.

    ``site="decode"``: fires when the engine's global decode-step
    counter equals ``step`` (0-based).  ``site="prefill"``: fires on the
    ``step``-th admission (0-based) instead."""

    step: int = 0
    slot: int = 0
    value: float = float("nan")
    site: str = "decode"


@dataclass
class StepDelay:
    """Sleep ``delay_s`` before every ``every``-th decode step."""

    delay_s: float = 0.05
    every: int = 1


@dataclass
class FaultPlan:
    """One deterministic chaos scenario: which faults fire, where, when.

    ``fired`` counts hook activations per site (``cache`` / ``compile``
    / ``decode_nan`` / ``delay``) so tests can assert the fault actually
    happened — a chaos test whose fault never fired proves nothing."""

    seed: int = 0
    cache_fault: CacheFault | None = None
    compile_fault: CompileFault | None = None
    decode_nan: DecodeNaN | None = None
    step_delay: StepDelay | None = None
    fired: dict[str, int] = field(default_factory=dict)

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)
        self._damaged: set[str] = set()
        self._compile_attempts = 0
        self._steps_seen = 0

    def _fire(self, site: str) -> None:
        self.fired[site] = self.fired.get(site, 0) + 1


_ACTIVE: FaultPlan | None = None


def active() -> FaultPlan | None:
    """The armed plan, or None (the production state)."""
    return _ACTIVE


@contextlib.contextmanager
def inject_faults(plan: FaultPlan):
    """Arm ``plan`` for the dynamic extent of the block (not thread-safe
    by design: chaos runs are single-process, single-engine)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = prev


# ---------------------------------------------------------------------------
# Hook points — called from engine.py / jax_backend.py; no-ops when disarmed
# ---------------------------------------------------------------------------


def on_cache_read(path: str) -> None:
    """Hook: ``ProgramCache._read`` is about to open ``path``."""
    if _ACTIVE is None or _ACTIVE.cache_fault is None:
        return
    cf = _ACTIVE.cache_fault
    if path in _ACTIVE._damaged:
        return  # damage each file once: the re-written entry stays clean
    if cf.count is not None and len(_ACTIVE._damaged) >= cf.count:
        return
    _ACTIVE._damaged.add(path)
    _ACTIVE._fire("cache")
    if cf.mode == "delete":
        with contextlib.suppress(OSError):
            os.unlink(path)
        return
    if cf.mode == "truncate":
        with contextlib.suppress(OSError), open(path, "r+b") as f:
            f.truncate(cf.keep_bytes)
        return
    size = max(os.path.getsize(path), 1)
    with contextlib.suppress(OSError), open(path, "wb") as f:
        f.write(bytes(_ACTIVE._rng.getrandbits(8) for _ in range(min(size, 256))))


def on_compile(tag: str) -> None:
    """Hook: an XLA compile attempt (``tag`` names the call site)."""
    if _ACTIVE is None or _ACTIVE.compile_fault is None:
        return
    cf = _ACTIVE.compile_fault
    if _ACTIVE._compile_attempts >= cf.count:
        return
    _ACTIVE._compile_attempts += 1
    _ACTIVE._fire("compile")
    if cf.kind == "hang":
        time.sleep(cf.hang_s)
        return
    raise InjectedCompileError(f"injected compile failure at {tag}")


def on_decode_step(bucket: int) -> None:
    """Hook: the engine is about to run one decode step at ``bucket``."""
    if _ACTIVE is None or _ACTIVE.step_delay is None:
        return
    sd = _ACTIVE.step_delay
    _ACTIVE._steps_seen += 1
    if sd.every > 0 and _ACTIVE._steps_seen % sd.every == 0:
        _ACTIVE._fire("delay")
        time.sleep(sd.delay_s)


def poison_logits(logits, step: int, *, site: str = "decode"):
    """Hook: maybe overwrite one slot's logits; returns the (possibly
    modified) array.  ``step`` is the engine's 0-based ordinal for the
    site (decode-step counter, or admissions-so-far for prefill)."""
    if _ACTIVE is None or _ACTIVE.decode_nan is None:
        return logits
    dn = _ACTIVE.decode_nan
    if dn.site != site or dn.step != step:
        return logits
    _ACTIVE._fire("decode_nan")
    if site == "prefill":
        # prefill logits are (1, S, V): poison the whole row grid
        return logits.at[:].set(dn.value)
    return logits.at[dn.slot].set(dn.value)
