"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: each kernel's test sweeps shapes and
dtypes and asserts ``allclose`` against the function here.  They are also
the *production implementation on non-TPU backends* (the dry-run lowers
these — XLA fuses them fine on CPU; the Pallas kernels are the TPU-target
hot-spot implementations, validated in interpret mode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30  # finite mask value: avoids NaN rows when l == 0


def attention_mask(
    q_len: int,
    kv_len: int,
    *,
    causal: bool,
    window: int | None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """(q_len, kv_len) boolean visibility mask.

    ``q_offset`` places the query block inside a longer sequence (decode:
    q_len==1 at position kv_len-1).  ``window`` means position ``j`` is
    visible from ``i`` iff ``i - j < window`` (and ``j <= i`` if causal).
    """
    rows = jnp.arange(q_len)[:, None] + q_offset
    cols = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= rows >= cols
    if window is not None:
        mask &= cols > rows - window
    return mask


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = False,
    window: int | None = None,
    sm_scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Full-softmax GQA attention.

    q: (B, H, Sq, D); k, v: (B, KVH, Skv, D) with H % KVH == 0.
    Returns (B, H, Sq, D) in q.dtype; softmax/matmuls accumulate in f32.
    """
    B, H, Sq, D = q.shape
    KVH = k.shape[1]
    assert H % KVH == 0, (H, KVH)
    group = H // KVH
    scale = sm_scale if sm_scale is not None else 1.0 / (D**0.5)

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads (GQA)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)

    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    mask = attention_mask(Sq, k.shape[2], causal=causal, window=window, q_offset=q_offset)
    s = jnp.where(mask[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return o.astype(q.dtype)


def flash_attention_ref_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = False,
    window: int | None = None,
    sm_scale: float | None = None,
    block_k: int = 512,
) -> jax.Array:
    """Online-softmax attention in pure jnp — the XLA-compilable twin of
    the Pallas flash kernel: O(S·D) live memory instead of the O(S²)
    score materialization of :func:`flash_attention_ref`.

    This is what the TPU kernel does per KV block, expressed as a
    ``lax.scan`` so the same memory behaviour shows up in the dry-run's
    bytes-accessed (hillclimb: the "memory" roofline term)."""
    B, H, Sq, D = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    group = H // KVH
    scale = sm_scale if sm_scale is not None else 1.0 / (D**0.5)
    nb = -(-Skv // block_k)
    pad = nb * block_k - Skv

    qf = q.astype(jnp.float32) * scale
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kf.reshape(B, KVH, nb, block_k, D).transpose(2, 0, 1, 3, 4)
    vb = vf.reshape(B, KVH, nb, block_k, D).transpose(2, 0, 1, 3, 4)

    rows = jnp.arange(Sq)[:, None]

    def step(carry, inp):
        acc, m, l = carry  # (B,H,Sq,D), (B,H,Sq,1), (B,H,Sq,1)
        bi, kblk, vblk = inp  # (), (B,KVH,bk,D), (B,KVH,bk,D)
        kr = jnp.repeat(kblk, group, axis=1)
        vr = jnp.repeat(vblk, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kr)
        cols = bi * block_k + jnp.arange(block_k)[None, :]
        mask = cols < Skv
        if causal:
            mask = mask & (rows >= cols)
        if window is not None:
            mask = mask & (cols > rows - window)
        s = jnp.where(mask[None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vr)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    m0 = jnp.full((B, H, Sq, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (jnp.arange(nb), kb, vb))
    out = acc / jnp.where(l == 0.0, 1.0, l)
    return out.astype(q.dtype)


def flash_attention_fwd_lse_chunked(
    q, k, v, causal=False, window=None, sm_scale=None, block_k: int = 512
):
    """Chunked forward that also returns the row logsumexp (needed by the
    chunked backward).  Same math as flash_attention_ref_chunked."""
    B, H, Sq, D = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    group = H // KVH
    scale = sm_scale if sm_scale is not None else 1.0 / (D**0.5)
    nb = -(-Skv // block_k)
    pad = nb * block_k - Skv

    qf = q.astype(jnp.float32) * scale
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kf.reshape(B, KVH, nb, block_k, D).transpose(2, 0, 1, 3, 4)
    vb = vf.reshape(B, KVH, nb, block_k, D).transpose(2, 0, 1, 3, 4)
    rows = jnp.arange(Sq)[:, None]

    def step(carry, inp):
        acc, m, l = carry
        bi, kblk, vblk = inp
        kr = jnp.repeat(kblk, group, axis=1)
        vr = jnp.repeat(vblk, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kr)
        cols = bi * block_k + jnp.arange(block_k)[None, :]
        mask = cols < Skv
        if causal:
            mask = mask & (rows >= cols)
        if window is not None:
            mask = mask & (cols > rows - window)
        s = jnp.where(mask[None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vr)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    m0 = jnp.full((B, H, Sq, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (jnp.arange(nb), kb, vb))
    lsafe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / lsafe).astype(q.dtype)
    lse = m + jnp.log(lsafe)
    return out, lse


def flash_attention_bwd_chunked(
    q, k, v, o, lse, do, causal=False, window=None, sm_scale=None, block_k: int = 512
):
    """Chunked flash backward: per-KV-block recomputation from the saved
    logsumexp — O(S·D) live memory (the naive vjp materializes O(S²)).

        δ_i   = Σ_d do_id·o_id
        p_ij  = exp(s_ij − lse_i)
        dv_j  = Σ_i p_ij·do_i
        ds_ij = p_ij·(do_i·v_j − δ_i)
        dq_i += scale·Σ_j ds_ij·k_j ;  dk_j = scale·Σ_i ds_ij·q_i
    """
    B, H, Sq, D = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    group = H // KVH
    scale = sm_scale if sm_scale is not None else 1.0 / (D**0.5)
    nb = -(-Skv // block_k)
    pad = nb * block_k - Skv

    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    delta = jnp.sum(dof * o.astype(jnp.float32), axis=-1, keepdims=True)
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = kf.reshape(B, KVH, nb, block_k, D).transpose(2, 0, 1, 3, 4)
    vb = vf.reshape(B, KVH, nb, block_k, D).transpose(2, 0, 1, 3, 4)
    rows = jnp.arange(Sq)[:, None]

    def step(dq, inp):
        bi, kblk, vblk = inp
        kr = jnp.repeat(kblk, group, axis=1)  # (B,H,bk,D)
        vr = jnp.repeat(vblk, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kr) * scale
        cols = bi * block_k + jnp.arange(block_k)[None, :]
        mask = cols < Skv
        if causal:
            mask = mask & (rows >= cols)
        if window is not None:
            mask = mask & (cols > rows - window)
        s = jnp.where(mask[None, None], s, _NEG_INF)
        p = jnp.exp(s - lse)  # (B,H,q,bk); masked → 0
        dv_r = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vr)
        ds = p * (dp - delta)
        dq = dq + scale * jnp.einsum("bhqk,bhkd->bhqd", ds, kr)
        dk_r = scale * jnp.einsum("bhqk,bhqd->bhkd", ds, qf)
        # fold grouped q-heads back onto their kv head
        dk_blk = dk_r.reshape(B, KVH, group, block_k, D).sum(axis=2)
        dv_blk = dv_r.reshape(B, KVH, group, block_k, D).sum(axis=2)
        return dq, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(step, dq0, (jnp.arange(nb), kb, vb))
    dk = dkb.transpose(1, 2, 0, 3, 4).reshape(B, KVH, nb * block_k, D)[:, :, :Skv]
    dv = dvb.transpose(1, 2, 0, 3, 4).reshape(B, KVH, nb * block_k, D)[:, :, :Skv]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def ssd_scan_ref_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    chunk: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD in pure jnp — the XLA twin of the Pallas SSD kernel:
    per-timestep state materialization (S×H×N×P bytes in the stepwise
    oracle) collapses to per-chunk matmuls + a (S/L)-step state scan."""
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    xf = x.astype(jnp.float32).reshape(Bt, nc, L, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bt, nc, L, H)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2).reshape(Bt, nc, L, H, N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2).reshape(Bt, nc, L, H, N)

    da = Af[None, None, None] * dtf  # (Bt,nc,L,H)
    cum = jnp.cumsum(da, axis=2)

    # intra-chunk (dual form): y_i += Σ_{j≤i} (C_i·B_j)·exp(cum_i−cum_j)·dt_j·x_j
    seg = cum[:, :, :, None] - cum[:, :, None, :]  # (Bt,nc,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    s = jnp.einsum("bclhn,bcmhn->bclmh", Cf, Bf) * decay * dtf[:, :, None]
    y = jnp.einsum("bclmh,bcmhp->bclhp", s, xf)

    # inter-chunk state recurrence over nc steps
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dtf  # (Bt,nc,L,H)
    chunk_state = jnp.einsum("bclhn,bclh,bclhp->bchnp", Bf, w, xf)
    total_decay = jnp.exp(cum[:, :, -1])  # (Bt,nc,H)

    def step(h, inp):
        cs, td = inp  # (Bt,H,N,P), (Bt,H)
        h_new = td[..., None, None] * h + cs
        return h_new, h  # emit state *entering* this chunk

    hT, h_in = jax.lax.scan(
        step,
        jnp.zeros((Bt, H, N, P), jnp.float32),
        (chunk_state.transpose(1, 0, 2, 3, 4), total_decay.transpose(1, 0, 2)),
    )
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # (Bt,nc,H,N,P)
    y = y + jnp.einsum("bclhn,bclh,bchnp->bclhp", Cf, jnp.exp(cum), h_in)
    return y.reshape(Bt, S, H, P).astype(x.dtype), hT


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last axis; accumulation in f32, output in x.dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)


def ssd_scan_ref(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Mamba-2 SSD recurrence, stepwise (the unambiguous oracle).

    x: (Bt, S, H, P)   token inputs per head
    dt: (Bt, S, H)     positive step sizes
    A: (H,)            negative per-head decay rates
    B, C: (Bt, S, G, N) input/output projections, G groups (H % G == 0)

        h_t = exp(A·dt_t)·h_{t-1} + dt_t·(B_t ⊗ x_t)     h: (H, N, P)
        y_t = C_t · h_t                                   y: (H, P)

    Returns (y, final_state) with y: (Bt, S, H, P) in x.dtype and
    final_state: (Bt, H, N, P) in f32.
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert H % G == 0, (H, G)
    rep = H // G

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)  # (Bt, S, H, N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # (H,P) (H,) (H,N) (H,N)
        a_t = jnp.exp(Af * dt_t)  # (H,)
        h = a_t[:, None, None] * h + dt_t[:, None, None] * b_t[:, :, None] * x_t[:, None, :]
        y_t = jnp.einsum("hn,hnp->hp", c_t, h)
        return h, y_t

    def scan_one(xb, dtb, bb, cb):
        h0 = jnp.zeros((H, N, P), jnp.float32)
        hT, ys = jax.lax.scan(step, h0, (xb, dtb, bb, cb))
        return ys, hT

    ys, hT = jax.vmap(scan_one)(xf, dtf, Bf, Cf)
    return ys.astype(x.dtype), hT


def ssd_step_ref(
    h: jax.Array,
    x_t: jax.Array,
    dt_t: jax.Array,
    A: jax.Array,
    B_t: jax.Array,
    C_t: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Single decode step of the SSD recurrence.

    h: (Bt, H, N, P) carried state; x_t: (Bt, H, P); dt_t: (Bt, H);
    B_t, C_t: (Bt, G, N).  Returns (new_state, y_t: (Bt, H, P))."""
    G = B_t.shape[1]
    H = x_t.shape[1]
    rep = H // G
    bf = jnp.repeat(B_t.astype(jnp.float32), rep, axis=1)  # (Bt,H,N)
    cf = jnp.repeat(C_t.astype(jnp.float32), rep, axis=1)
    a = jnp.exp(A.astype(jnp.float32) * dt_t.astype(jnp.float32))  # (Bt,H)
    h = a[..., None, None] * h + (
        dt_t.astype(jnp.float32)[..., None, None]
        * bf[..., :, None]
        * x_t.astype(jnp.float32)[..., None, :]
    )
    y = jnp.einsum("bhn,bhnp->bhp", cf, h)
    return h, y.astype(x_t.dtype)
