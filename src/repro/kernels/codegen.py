"""Pallas kernel code generation for fusion clusters.

The partitioner (``repro.core.fusion``) hands this module legal clusters;
``emit_cluster`` turns each one into a :class:`FusedKernel` — a callable
with three interchangeable execution paths, selected by the *same*
``set_kernel_mode`` switch the hand-written kernels use:

* ``"ref"`` / ``"chunked"``  — the **pure-jnp oracle**: exactly the same
  primitive ``impl`` calls, in the same order, as the unfused lowering
  would emit.  This path is bit-identical to the unfused program by
  construction and is what CPU test/serving traffic executes.
* ``"pallas_interpret"``     — the generated Pallas kernel run by the
  Pallas interpreter (correctness validation on CPU; every op inside the
  kernel is the same jnp call the oracle makes, so blocked map kernels
  remain bit-identical).
* ``"pallas"``               — the compiled Pallas TPU kernel.

Kernel shape strategy:

* **map clusters** (elementwise root): the body shape ``S`` is collapsed
  to 2-D ``(R, C) = (prod(S[:-1]), S[-1])`` and the grid blocks rows —
  ``grid=(R/br,)`` with ``BlockSpec((br, C))`` per operand, ``br`` the
  largest power-of-two row divisor that keeps a block within the VMEM
  budget.  Every operand is materialized *at* ``S`` by the wrapper
  (broadcast members run there; smaller external inputs are
  ``broadcast_to``-ed), so the kernel body is pure per-block elementwise
  code.
* **reduce clusters** (reduction root): one whole-array block (no grid) —
  the kernel computes the elementwise body and applies the reduction
  primitive with its static axes, so the floating-point reduction order
  is identical to the unfused lowering's.  Rank-0/1 results are staged
  through a 2-D output block and reshaped by the wrapper.

``emit_cluster`` *declines* (returns None) clusters it cannot express —
non-array external inputs, rank-0 bodies — and the lowering falls back to
the per-node jnp path for exactly that cluster, never the whole graph.

Generated source (kernel + wrapper + oracle) is kept on the result as
``FusedKernel.source`` — tests exec it and ``docs/fusion.md`` shows one.
"""

from __future__ import annotations

import math
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.fusion import Cluster, DeclineReason, classify
from repro.core.infer import AArray
from repro.core.ir import Apply, Constant, Node
from repro.obs import profile as obs_profile
from .ops import get_kernel_mode

__all__ = ["FusedKernel", "emit_cluster", "emit_cluster_explained"]

#: soft cap on elements per VMEM block for generated map kernels
_BLOCK_ELEMS = 128 * 1024

_counter = [0]


class FusedKernel:
    """One generated kernel: callable (mode-dispatching), with the oracle
    and both Pallas variants exposed for differential testing."""

    __slots__ = (
        "name",
        "source",
        "n_nodes",
        "kind",
        "body_shape",
        "out_shape",
        "bytes_moved",
        "oracle",
        "pallas_interpret",
        "pallas_compiled",
    )

    def __init__(
        self,
        name: str,
        source: str,
        n_nodes: int,
        kind: str,
        body_shape: tuple,
        out_shape: tuple,
        oracle: Callable,
        pallas_interpret: Callable,
        pallas_compiled: Callable,
        bytes_moved: int = 0,
    ) -> None:
        self.name = name
        self.source = source
        self.n_nodes = n_nodes
        self.kind = kind
        self.body_shape = body_shape
        self.out_shape = out_shape
        #: minimum HBM traffic per launch (cluster inputs + root output,
        #: from the inferred abstracts) — what the runtime profiler divides
        #: wall time into for achieved-GB/s / roofline_fraction
        self.bytes_moved = bytes_moved
        self.oracle = oracle
        self.pallas_interpret = pallas_interpret
        self.pallas_compiled = pallas_compiled

    def __call__(self, *args: Any) -> Any:
        mode = get_kernel_mode()
        if mode == "pallas_interpret":
            fn = self.pallas_interpret
        elif mode == "pallas":
            fn = self.pallas_compiled
        else:
            fn = self.oracle  # "ref" / "chunked"
        # runtime profiler hook: disarmed this is one module-global read
        # (the structural-zero-overhead contract); armed + concrete args,
        # the launch is timed to completion and attributed per kernel
        prof = obs_profile._ACTIVE
        if prof is None or any(isinstance(a, jax.core.Tracer) for a in args):
            return fn(*args)
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        prof.record(self.name, "fused", time.perf_counter() - t0, self.bytes_moved)
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<FusedKernel {self.name} {self.kind} n={self.n_nodes}>"


def _literal(value: Any) -> str | None:
    """Source literal for embeddable static values (mirrors
    ``lowering._literal``: exact types only, so numpy scalars stay
    closure-bound and dtype promotion is untouched)."""
    if value is None:
        return "None"
    t = type(value)
    if t is bool or t is str or t is int:
        return repr(value)
    if t is float:
        return repr(value) if math.isfinite(value) else None
    if t is tuple:
        elts = [_literal(v) for v in value]
        if any(e is None for e in elts):
            return None
        inner = ", ".join(elts)
        return f"({inner},)" if len(elts) == 1 else f"({inner})"
    return None


def _const_shape(value: Any) -> tuple | None:
    try:
        return tuple(int(d) for d in np.shape(value))
    except Exception:
        return None


def _block_rows(R: int, C: int) -> int:
    """Largest power-of-two divisor of R whose block stays under the VMEM
    budget (falls back to R itself when R is odd — correctness first)."""
    br = R
    while br > 1 and br % 2 == 0 and br * max(C, 1) > _BLOCK_ELEMS:
        br //= 2
    return br


def _abstract_nbytes(ab: Any) -> int:
    """Bytes of one array abstract (0 for non-arrays/unknown)."""
    if isinstance(ab, AArray):
        n = 1
        for d in ab.shape:
            n *= int(d)
        return n * np.dtype(ab.dtype).itemsize
    return 0


def _cluster_bytes(cluster: Cluster) -> int:
    """Minimum HBM traffic of one launch: every external input read once
    plus the root output written once (interior values live in VMEM)."""
    total = sum(_abstract_nbytes(n.abstract) for n in cluster.inputs)
    return total + _abstract_nbytes(cluster.root.abstract)


def emit_cluster(cluster: Cluster) -> FusedKernel | None:
    """Generate the fused kernel for ``cluster`` or decline with None."""
    kernel, _reason = emit_cluster_explained(cluster)
    return kernel


def emit_cluster_explained(
    cluster: Cluster,
) -> tuple[FusedKernel | None, DeclineReason | None]:
    """``(kernel, None)`` on success, ``(None, DeclineReason)`` when the
    generator cannot express the cluster — the structured verdict the
    explain layer reports per cluster."""
    got = _emit_cluster_impl(cluster)
    if isinstance(got, FusedKernel):
        return got, None
    return None, got


def _emit_cluster_impl(cluster: Cluster) -> FusedKernel | DeclineReason:
    body_shape = tuple(cluster.body_shape)
    out_shape = tuple(cluster.out_shape)
    out_dtype = cluster.out_dtype
    if out_dtype is None or len(body_shape) == 0:
        return DeclineReason(
            DeclineReason.EMPTY_BODY,
            "cluster has no output dtype or a rank-0 body; no kernel to win",
        )

    # -- name & classify members ------------------------------------------
    members = {n._id for n in cluster.order}
    pre: list[Apply] = []  # broadcast members: run in the wrapper
    body: list[Apply] = []  # elementwise members (+ reduction root)
    for n in cluster.order:
        (pre if classify(n) == "broadcast" else body).append(n)
    if not body or body[-1] is not cluster.root:
        return DeclineReason(
            DeclineReason.CODEGEN,
            "root is not the last body node (single-output ordering)",
        )

    env: dict[str, Any] = {"jnp": jnp, "jax": jax, "pl": pl}
    prim_names: dict[int, str] = {}

    def bind_prim(prim) -> str:
        name = prim_names.get(id(prim))
        if name is None:
            name = f"_prim_{prim.name}_{len(prim_names)}"
            prim_names[id(prim)] = name
            env[name] = prim.impl
        return name

    # -- operand discovery -------------------------------------------------
    # names for: cluster inputs (a{i}), bound constants (_const_{k}),
    # pre-member results (p{k}), body values (v{k})
    input_name: dict[int, str] = {}
    for i, node in enumerate(cluster.inputs):
        if not isinstance(node.abstract, AArray):
            # non-array input: the jnp path keeps this cluster
            return DeclineReason(
                DeclineReason.NOT_ARRAY,
                f"cluster input {i} has no array abstract",
            )
        input_name[node._id] = f"a{i}"

    def ext_ref(node: Node) -> str | None:
        """Name/literal for a non-member node, or None if unsupported."""
        got = input_name.get(node._id)
        if got is not None:
            return got
        if isinstance(node, Constant):
            lit = _literal(node.value)
            if lit is not None:
                return lit
            name = f"_const_{len(env)}"
            env[name] = node.value
            input_name[node._id] = name
            return name
        return None

    def ext_shape(node: Node) -> tuple | None:
        if isinstance(node.abstract, AArray):
            return node.abstract.shape
        if isinstance(node, Constant):
            return _const_shape(node.value)
        return None

    pre_name: dict[int, str] = {}
    pre_lines: list[str] = []
    for k, n in enumerate(pre):
        args = []
        for a in n.args:
            if a._id in members:
                return DeclineReason(
                    DeclineReason.CODEGEN,
                    "broadcast member consumes a kernel-body value",
                )
            r = ext_ref(a)
            if r is None:
                return DeclineReason(
                    DeclineReason.CODEGEN,
                    f"unsupported external reference feeding {n.fn.value.name}",
                )
            args.append(r)
        pre_name[n._id] = f"p{k}"
        pre_lines.append(
            f"    p{k} = {bind_prim(n.fn.value)}({', '.join(args)})  # {n.fn.value.name} (pre)"
        )

    # kernel operands: every value entering the elementwise body
    operands: list[tuple[str, str, tuple | None]] = []  # (slot, wrapper expr, shape)
    operand_slot: dict[int, str] = {}

    def operand_for(a: Node) -> str | None:
        slot = operand_slot.get(a._id)
        if slot is not None:
            return slot
        if a._id in pre_name:
            expr, shape = pre_name[a._id], body_shape
        else:
            r = ext_ref(a)
            if r is None or r[0] not in "a_":  # literal: embedded, not an operand
                return r
            shape = ext_shape(a)
            if shape is None:
                return None
            expr = r
        slot = f"x{len(operands)}"
        operand_slot[a._id] = slot
        operands.append((slot, expr, shape))
        return slot

    body_lines: list[str] = []
    vname: dict[int, str] = {}
    red_root = cluster.kind == "reduce"
    for k, n in enumerate(body):
        rendered = []
        is_root_reduction = red_root and n is cluster.root
        for j, a in enumerate(n.args):
            if a._id in vname:
                rendered.append(vname[a._id])
                continue
            if is_root_reduction and j > 0:
                # static reduction config (axes / shape / keepdims)
                assert isinstance(a, Constant)
                r = ext_ref(a)
            else:
                r = operand_for(a)
            if r is None:
                return DeclineReason(
                    DeclineReason.CODEGEN,
                    f"unsupported operand feeding {n.fn.value.name}",
                )
            rendered.append(r)
        vname[n._id] = f"v{k}"
        body_lines.append(
            f"v{k} = {bind_prim(n.fn.value)}({', '.join(rendered)})  # {n.fn.value.name}"
        )
    root_v = vname[cluster.root._id]

    # -- shapes ------------------------------------------------------------
    C = body_shape[-1]
    R = int(np.prod(body_shape[:-1])) if len(body_shape) > 1 else 1
    br = _block_rows(R, C)
    out2 = (1, max(int(np.prod(out_shape)), 1)) if len(out_shape) < 2 else None

    _counter[0] += 1
    name = f"fused_{cluster.kind}{_counter[0]}_" + "_".join(
        dict.fromkeys(n.fn.value.name for n in cluster.order)
    )
    env["_out_dtype"] = np.dtype(out_dtype)

    # -- source ------------------------------------------------------------
    nargs = ", ".join(f"a{i}" for i in range(len(cluster.inputs)))
    krefs = ", ".join(f"{slot}_ref" for slot, _, _ in operands)
    lines = [f"def _kernel({krefs}{', ' if krefs else ''}o_ref):"]
    for slot, _, _ in operands:
        lines.append(f"    {slot} = {slot}_ref[...]")
    if cluster.kind == "map":
        lines += [f"    {l}" for l in body_lines]
        lines.append(f"    o_ref[...] = {root_v}")
    else:
        # whole-array block: operands arrive at body_shape already; the
        # reduction's static axes were rendered into the body line itself
        lines += [f"    {l}" for l in body_lines]
        lines.append(f"    o_ref[...] = jnp.reshape({root_v}, {out2 or out_shape})")
    lines.append("")

    # wrapper: prepare operands at body shape, call pallas, restore shape
    lines.append("def _make(interpret):")
    lines.append(f"    def {name}({nargs}):")
    for pl_line in pre_lines:
        lines.append("    " + pl_line)
    call_args = []
    for slot, expr, shape in operands:
        e = expr
        if shape != body_shape:
            e = f"jnp.broadcast_to({e}, {body_shape})"
        if cluster.kind == "map" and (len(body_shape) != 2):
            e = f"jnp.reshape({e}, ({R}, {C}))"
        elif cluster.kind == "map":
            pass  # already (R, C)
        lines.append(f"        {slot} = {e}")
        call_args.append(slot)
    if cluster.kind == "map":
        lines += [
            "        out = pl.pallas_call(",
            "            _kernel,",
            f"            grid=({R // br},),",
            "            in_specs=[" + ", ".join(
                f"pl.BlockSpec(({br}, {C}), lambda i: (i, 0))" for _ in operands
            ) + "],",
            f"            out_specs=pl.BlockSpec(({br}, {C}), lambda i: (i, 0)),",
            f"            out_shape=jax.ShapeDtypeStruct(({R}, {C}), _out_dtype),",
            "            interpret=interpret,",
            f"            name={name!r},",
            f"        )({', '.join(call_args)})",
            f"        return jnp.reshape(out, {out_shape})",
        ]
    else:
        lines += [
            "        out = pl.pallas_call(",
            "            _kernel,",
            f"            out_shape=jax.ShapeDtypeStruct({out2 or out_shape}, _out_dtype),",
            "            interpret=interpret,",
            f"            name={name!r},",
            f"        )({', '.join(call_args)})",
            f"        return jnp.reshape(out, {out_shape})",
        ]
    lines.append(f"    return {name}")
    lines.append("")

    # oracle: the exact unfused computation (impl call per member, original
    # shapes, no broadcasts inserted) — bit-identical to direct lowering
    lines.append(f"def _oracle({nargs}):")
    ovname: dict[int, str] = {}
    for n in cluster.order:
        rendered = []
        for a in n.args:
            if a._id in ovname:
                rendered.append(ovname[a._id])
            else:
                rendered.append(ext_ref(a))
        ovname[n._id] = f"w{len(ovname)}"
        lines.append(
            f"    {ovname[n._id]} = {bind_prim(n.fn.value)}"
            f"({', '.join(rendered)})  # {n.fn.value.name}"
        )
    lines.append(f"    return {ovname[cluster.root._id]}")
    source = "\n".join(lines) + "\n"

    namespace = dict(env)
    try:
        exec(compile(source, f"<myia-fused:{name}>", "exec"), namespace)
    except SyntaxError:  # pragma: no cover - codegen bug guard
        return DeclineReason(
            DeclineReason.CODEGEN, "generated source failed to compile"
        )
    oracle = namespace["_oracle"]
    interp = namespace["_make"](True)
    compiled = namespace["_make"](False)
    for fn in (oracle, interp, compiled):
        fn.__fused_source__ = source
    return FusedKernel(
        name=name,
        source=source,
        n_nodes=len(cluster.order),
        kind=cluster.kind,
        body_shape=body_shape,
        out_shape=out_shape,
        oracle=oracle,
        pallas_interpret=interp,
        pallas_compiled=compiled,
        bytes_moved=_cluster_bytes(cluster),
    )
