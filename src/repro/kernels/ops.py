"""Public kernel API: dispatch + AD + Myia primitive registration.

Each op has three interchangeable implementations selected by
:func:`set_kernel_mode` (or a per-call ``impl=`` override):

* ``"ref"``              — the pure-jnp oracle (default; what the dry-run
                            lowers and what CPU smoke tests execute),
* ``"pallas_interpret"`` — the Pallas TPU kernel executed by the Pallas
                            interpreter (correctness validation on CPU),
* ``"pallas"``           — the compiled Pallas TPU kernel (real hardware).

AD: every op is a ``jax.custom_vjp``.  Backward passes recompute from the
reference formulas (flash-attention/SSD) or run the dedicated Pallas
backward kernel (rmsnorm).  The ops are ALSO registered as *Myia
primitives* with hand-written backpropagators — the paper's "write
efficient low-level kernels and their derivatives in a low-level language,
and expose them to Myia as primitives" (§3, Myia's intended use case).
"""

from __future__ import annotations

import functools
import os

import jax

from repro.core.primitives import register_primitive, zeros_like
from . import ref
from .flash_attention import flash_attention_fwd
from .rmsnorm import rmsnorm_bwd, rmsnorm_fwd
from .ssd_scan import ssd_scan_fwd

__all__ = [
    "set_kernel_mode",
    "get_kernel_mode",
    "flash_attention",
    "rmsnorm",
    "ssd_scan",
    "ssd_step",
]

_MODES = ("ref", "chunked", "pallas_interpret", "pallas")


def _validate(mode: str) -> str:
    """Invalid values fail loudly — a typo'd CI matrix entry must not
    silently green the ref path."""
    if mode not in _MODES:
        raise ValueError(f"MYIA_KERNEL_MODE must be one of {_MODES}, got {mode!r}")
    return mode


# ``MYIA_KERNEL_MODE`` (the CI matrix axis: the fast job runs ``ref``, the
# full job also ``pallas_interpret``) used to be read ONCE at import, so a
# process that changed the environment afterwards — the serve engine
# flipping modes between workloads, or a test driving the mode matrix
# in-process — silently kept the stale mode.  The env var is now re-read
# on every query: a *change* to it takes effect immediately, while an
# explicit ``set_kernel_mode`` wins until the env var next changes.
_ENV_SEEN = os.environ.get("MYIA_KERNEL_MODE")
# validate the RAW value when the var is set: an empty/typo'd CI matrix
# expansion must fail loudly, not silently green the ref path
_MODE = _validate("ref" if _ENV_SEEN is None else _ENV_SEEN)


def set_kernel_mode(mode: str) -> None:
    global _MODE, _ENV_SEEN
    _MODE = _validate(mode)
    # sync the watermark: a later env-var CHANGE still overrides
    _ENV_SEEN = os.environ.get("MYIA_KERNEL_MODE")


def get_kernel_mode() -> str:
    global _MODE, _ENV_SEEN
    env = os.environ.get("MYIA_KERNEL_MODE")
    if env != _ENV_SEEN:
        if env is not None:
            # validate BEFORE moving the watermark: a typo'd value keeps
            # failing on every query instead of raising once and going quiet
            _MODE = _validate(env)
        _ENV_SEEN = env
    return _MODE


def _resolve(impl: str | None) -> str:
    return impl if impl is not None else get_kernel_mode()


# ===========================================================================
# flash attention
# ===========================================================================


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, window, sm_scale, impl):
    return _flash_fwd_dispatch(q, k, v, causal, window, sm_scale, impl)


def _flash_fwd_dispatch(q, k, v, causal, window, sm_scale, impl):
    if impl == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window, sm_scale=sm_scale)
    if impl == "chunked":
        return ref.flash_attention_ref_chunked(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale
        )
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, sm_scale=sm_scale,
        interpret=(impl == "pallas_interpret"),
    )


def _flash_fwd_vjp(q, k, v, causal, window, sm_scale, impl):
    if impl in ("chunked", "pallas", "pallas_interpret"):
        # chunked/flash backward needs (o, lse) residuals
        o, lse = ref.flash_attention_fwd_lse_chunked(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale
        )
        if impl != "chunked":  # the kernel produces o; lse from the twin
            o = _flash_fwd_dispatch(q, k, v, causal, window, sm_scale, impl)
        return o, (q, k, v, o, lse)
    return _flash_fwd_dispatch(q, k, v, causal, window, sm_scale, impl), (q, k, v)


def _flash_bwd_vjp(causal, window, sm_scale, impl, res, dout):
    if impl in ("chunked", "pallas", "pallas_interpret"):
        q, k, v, o, lse = res
        return ref.flash_attention_bwd_chunked(
            q, k, v, o, lse, dout, causal=causal, window=window, sm_scale=sm_scale
        )
    q, k, v = res
    # naive recompute backward (paper-faithful baseline): materializes the
    # O(S²) score matrix — the §Perf hillclimb replaces it with the
    # chunked backward above
    _, vjp_fn = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window, sm_scale=sm_scale
        ),
        q, k, v,
    )
    return vjp_fn(dout)


_flash_attention.defvjp(_flash_fwd_vjp, _flash_bwd_vjp)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    window: int | None = None,
    sm_scale: float | None = None,
    impl: str | None = None,
) -> jax.Array:
    """GQA attention. q: (B,H,Sq,D); k,v: (B,KVH,Skv,D) → (B,H,Sq,D)."""
    scale = float(sm_scale) if sm_scale is not None else 1.0 / (q.shape[-1] ** 0.5)
    return _flash_attention(q, k, v, bool(causal), window, scale, _resolve(impl))


# ===========================================================================
# rmsnorm
# ===========================================================================


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm(x, w, eps, impl):
    if impl in ("ref", "chunked"):
        return ref.rmsnorm_ref(x, w, eps)
    return rmsnorm_fwd(x, w, eps=eps, interpret=(impl == "pallas_interpret"))


def _rmsnorm_fwd_vjp(x, w, eps, impl):
    return _rmsnorm(x, w, eps, impl), (x, w)


def _rmsnorm_bwd_vjp(eps, impl, res, dy):
    x, w = res
    if impl in ("ref", "chunked"):
        _, vjp_fn = jax.vjp(lambda x_, w_: ref.rmsnorm_ref(x_, w_, eps), x, w)
        return vjp_fn(dy)
    return rmsnorm_bwd(x, w, dy, eps=eps, interpret=(impl == "pallas_interpret"))


_rmsnorm.defvjp(_rmsnorm_fwd_vjp, _rmsnorm_bwd_vjp)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6, impl: str | None = None) -> jax.Array:
    """Fused RMSNorm over the last axis."""
    return _rmsnorm(x, w, float(eps), _resolve(impl))


# ===========================================================================
# SSD scan (Mamba-2)
# ===========================================================================


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd_scan_y(x, dt, A, B, C, impl):
    return _ssd_dispatch(x, dt, A, B, C, impl)[0]


#: SSD chunk length: 128 keeps the (L,L) intra-chunk matmuls MXU-aligned;
#: the bytes-vs-flops sweep (EXPERIMENTS.md §Perf) showed 64 within 0.3%
#: on bytes, so alignment wins the tie.
_SSD_CHUNK = int(os.environ.get("REPRO_SSD_CHUNK", "128"))


def _ssd_dispatch(x, dt, A, B, C, impl):
    if impl == "ref":
        return ref.ssd_scan_ref(x, dt, A, B, C)
    if impl == "chunked":
        return ref.ssd_scan_ref_chunked(x, dt, A, B, C, chunk=_SSD_CHUNK)
    return ssd_scan_fwd(x, dt, A, B, C, interpret=(impl == "pallas_interpret"))


def _ssd_fwd_vjp(x, dt, A, B, C, impl):
    return _ssd_scan_y(x, dt, A, B, C, impl), (x, dt, A, B, C)


def _ssd_bwd_vjp(impl, res, dy):
    x, dt, A, B, C = res
    if impl in ("chunked", "pallas", "pallas_interpret"):
        # vjp through the chunked form: residuals are per-CHUNK states
        # (S/L × N×P) instead of per-timestep (S × N×P)
        _, vjp_fn = jax.vjp(
            lambda *a: ref.ssd_scan_ref_chunked(*a, chunk=_SSD_CHUNK)[0], x, dt, A, B, C
        )
    else:
        _, vjp_fn = jax.vjp(lambda *a: ref.ssd_scan_ref(*a)[0], x, dt, A, B, C)
    return vjp_fn(dy)


_ssd_scan_y.defvjp(_ssd_fwd_vjp, _ssd_bwd_vjp)


def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    return_final_state: bool = False,
    impl: str | None = None,
):
    """Mamba-2 SSD over a sequence.  With ``return_final_state`` the call is
    NOT differentiable (serving path); the training path returns only y."""
    mode = _resolve(impl)
    if return_final_state:
        return _ssd_dispatch(x, dt, A, B, C, mode)
    return _ssd_scan_y(x, dt, A, B, C, mode)


def ssd_step(h, x_t, dt_t, A, B_t, C_t):
    """Single decode step (state carried explicitly; pure jnp — the state
    update is bandwidth-bound elementwise work, no kernel needed)."""
    return ref.ssd_step_ref(h, x_t, dt_t, A, B_t, C_t)


# ===========================================================================
# Myia primitive registration (paper §3: kernels as primitives with known
# backpropagators; bprops are Myia-subset functions, so reverse-over-reverse
# stays possible through *other* ops while kernel vjps terminate the chain).
# ===========================================================================


def _prim_flash_impl(q, k, v, causal, window, sm_scale):
    return flash_attention(q, k, v, causal=causal, window=window, sm_scale=sm_scale)


def _prim_flash_vjp_impl(q, k, v, causal, window, sm_scale, dout):
    scale = float(sm_scale) if sm_scale is not None else 1.0 / (q.shape[-1] ** 0.5)
    return _flash_bwd_vjp(bool(causal), window, scale, _resolve(None), (q, k, v), dout)


flash_attention_vjp = register_primitive(
    "flash_attention_vjp", _prim_flash_vjp_impl, bprop="zeros"
)


def _bprop_flash_attention(q, k, v, causal, window, sm_scale, out, dout):
    g = flash_attention_vjp(q, k, v, causal, window, sm_scale, dout)
    return (
        g[0],
        g[1],
        g[2],
        zeros_like(causal),
        zeros_like(window),
        zeros_like(sm_scale),
    )


flash_attention_prim = register_primitive(
    "flash_attention", _prim_flash_impl, bprop=_bprop_flash_attention
)


def _prim_rmsnorm_impl(x, w, eps):
    return rmsnorm(x, w, eps=eps)


def _prim_rmsnorm_vjp_impl(x, w, eps, dy):
    return _rmsnorm_bwd_vjp(float(eps), _resolve(None), (x, w), dy)


rmsnorm_vjp = register_primitive("rmsnorm_vjp", _prim_rmsnorm_vjp_impl, bprop="zeros")


def _bprop_rmsnorm(x, w, eps, out, dout):
    g = rmsnorm_vjp(x, w, eps, dout)
    return (g[0], g[1], zeros_like(eps))


rmsnorm_prim = register_primitive("rmsnorm", _prim_rmsnorm_impl, bprop=_bprop_rmsnorm)


def _prim_ssd_impl(x, dt, A, B, C):
    return ssd_scan(x, dt, A, B, C)


def _prim_ssd_vjp_impl(x, dt, A, B, C, dy):
    return _ssd_bwd_vjp(_resolve(None), (x, dt, A, B, C), dy)


ssd_scan_vjp = register_primitive("ssd_scan_vjp", _prim_ssd_vjp_impl, bprop="zeros")


def _bprop_ssd_scan(x, dt, A, B, C, out, dout):
    g = ssd_scan_vjp(x, dt, A, B, C, dout)
    return (g[0], g[1], g[2], g[3], g[4])


ssd_scan_prim = register_primitive("ssd_scan", _prim_ssd_impl, bprop=_bprop_ssd_scan)
