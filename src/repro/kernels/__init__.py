"""Pallas TPU kernels for the compute hot-spots, each with a pure-jnp
oracle (``ref.py``) and a dispatching, differentiable wrapper (``ops.py``).

Kernels double as *Myia primitives with known backpropagators* — the
paper's model for low-level code (§3: "the user can write efficient
low-level kernels and their derivatives in a low-level language … and
expose them to Myia as primitives").
"""

from . import ref
from .codegen import FusedKernel, emit_cluster
from .ops import (
    flash_attention,
    get_kernel_mode,
    rmsnorm,
    set_kernel_mode,
    ssd_scan,
    ssd_step,
)

__all__ = [
    "ref",
    "flash_attention",
    "rmsnorm",
    "ssd_scan",
    "ssd_step",
    "set_kernel_mode",
    "get_kernel_mode",
    "FusedKernel",
    "emit_cluster",
]
