"""Mamba-2 SSD (state-space duality) chunked-scan Pallas kernel.

TPU adaptation (DESIGN.md §3): the sequence is split into chunks of length
``L``.  Within a chunk the recurrence is *dualized* into attention-like
matmuls (MXU work); across chunks only the small (N × P) state is carried
— in VMEM scratch across sequential grid steps, exactly like the flash-
attention online-softmax carry.

Per chunk (head h, group g = h // (H/G)), with a_t = A_h·dt_t and
``cum`` the inclusive cumsum of a over the chunk:

    intra:   y_i += Σ_{j≤i} (C_i·B_j) · exp(cum_i − cum_j) · dt_j · x_j
    inter:   y_i += exp(cum_i) · C_i · h_in
    state:   h_out = exp(cum_L) · h_in + Σ_j exp(cum_L − cum_j) · dt_j · B_j ⊗ x_j

All three are (L×N)@(N×L/P) matmuls — MXU-aligned for L, N, P multiples
of 128 (P=64 heads still fill half the MXU; acceptable).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hT_ref, h_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
    a = a_ref[0].astype(jnp.float32)  # ()
    bm = b_ref[0, :, 0].astype(jnp.float32)  # (L, N)
    cm = c_ref[0, :, 0].astype(jnp.float32)  # (L, N)

    da = a * dt  # (L,) log-decay increments (a < 0)
    cum = jnp.cumsum(da)  # (L,) inclusive

    # -- intra-chunk (dual / attention-like form) ---------------------------
    s = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L):  C_i · B_j
    seg = cum[:, None] - cum[None, :]  # log decay j→i
    L = x.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(rows >= cols, jnp.exp(seg), 0.0)
    s = s * decay * dt[None, :]
    y = jax.lax.dot_general(
        s, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, P)

    # -- inter-chunk: carried state contribution ----------------------------
    h = h_ref[...]  # (N, P) f32
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # -- state update --------------------------------------------------------
    w = jnp.exp(cum[-1] - cum) * dt  # (L,)
    h_new = jnp.exp(cum[-1]) * h + jax.lax.dot_general(
        bm * w[:, None], x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (N, P)
    h_ref[...] = h_new

    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        hT_ref[0, 0] = h_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_fwd(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """x: (Bt,S,H,P); dt: (Bt,S,H); A: (H,); B,C: (Bt,S,G,N).

    Returns (y: (Bt,S,H,P) in x.dtype, final_state: (Bt,H,N,P) f32)."""
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert H % G == 0, (H, G)
    rep = H // G
    L = min(chunk, S)
    assert S % L == 0, (S, L)

    grid = (Bt, H, S // L)
    kwargs = {}
    if not interpret:  # pragma: no cover - requires TPU
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    y, hT = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c, r=rep: (b, c, h // r, 0)),
            pl.BlockSpec((1, L, 1, N), lambda b, h, c, r=rep: (b, c, h // r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((Bt, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
        name="ssd_scan_fwd",
        **kwargs,
    )(x, dt, A, B, C)
    return y, hT
