"""FlashAttention forward kernel, re-blocked for TPU (VMEM + MXU).

TPU adaptation of the GPU algorithm (DESIGN.md §3):

* The grid is ``(batch, q_heads, q_blocks, kv_blocks)`` with the KV axis
  innermost and *sequential* ("arbitrary" dimension semantics): the online-
  softmax running state (acc, m, l) lives in VMEM scratch and is carried
  across KV grid steps instead of a CUDA thread-block loop.
* Block shapes are multiples of the MXU tile (128 on the contracted and
  lane dims).  Per step the working set is q(bq×D) + k,v(bk×D) + acc —
  streamed HBM→VMEM by ``BlockSpec``; nothing quadratic is materialized.
* GQA is free at the ``index_map`` level: KV blocks are fetched with head
  index ``h // group`` so kv tensors are never physically repeated.
* Causal and sliding-window masking skip fully-masked KV blocks via
  ``pl.when`` (the MXU work is gated; block fetch still occurs — the XLA
  grid is static).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _fa_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    sm_scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Block-level visibility: skip the MXU work for fully-masked blocks.
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window is not None:
        # newest visible column for the oldest row is q_start - window + 1
        run &= k_start + block_k - 1 >= q_start - window + 1

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)

        if causal or window is not None:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
            if causal:
                mask &= rows >= cols
            if window is not None:
                mask &= cols > rows - window
            s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (bq, bk)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = o.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    window: int | None = None,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, KVH, Skv, D).  Returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    assert H % KVH == 0, (H, KVH)
    group = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    scale = sm_scale if sm_scale is not None else 1.0 / (D**0.5)

    grid = (B, H, Sq // block_q, Skv // block_k)
    kernel = functools.partial(
        _fa_kernel,
        sm_scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
    )
    kwargs = {}
    if not interpret:  # pragma: no cover - requires TPU
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
        name="flash_attention_fwd",
        **kwargs,
    )(q, k, v)
