"""Fused RMSNorm Pallas kernels (forward + backward).

One HBM round-trip per tensor: rows are tiled ``block_rows`` at a time into
VMEM, the f32 mean-square/rsqrt is computed in-register, and the scaled
output is written back in the input dtype.  The backward kernel emits
``dx`` plus a per-block partial ``dw`` (summed by the caller) so no
cross-block communication is needed inside the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (br, D)
    w = w_ref[...].astype(jnp.float32)  # (1, D)
    var = jnp.mean(jnp.square(x), axis=1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


def _bwd_kernel(x_ref, w_ref, dy_ref, dx_ref, dwp_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (br, D)
    w = w_ref[...].astype(jnp.float32)  # (1, D)
    dy = dy_ref[...].astype(jnp.float32)  # (br, D)
    D = x.shape[1]
    var = jnp.mean(jnp.square(x), axis=1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)  # (br, 1)
    dyw = dy * w
    # dx = r·dy·w − x·r³·mean(dy·w·x)
    proj = jnp.sum(dyw * x, axis=1, keepdims=True) / D
    dx_ref[...] = (r * dyw - x * (r * r * r) * proj).astype(dx_ref.dtype)
    dwp_ref[...] = jnp.sum(dy * x * r, axis=0, keepdims=True)  # (1, D) f32


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_fwd(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """x: (..., D); w: (D,).  Returns same shape/dtype as x."""
    orig_shape = x.shape
    D = orig_shape[-1]
    x2 = x.reshape(-1, D)
    R = x2.shape[0]
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
        name="rmsnorm_fwd",
    )(x2, w.reshape(1, D))
    return out.reshape(orig_shape)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_bwd(
    x: jax.Array,
    w: jax.Array,
    dy: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (dx, dw) with dx in x.dtype and dw in w.dtype."""
    orig_shape = x.shape
    D = orig_shape[-1]
    x2 = x.reshape(-1, D)
    dy2 = dy.reshape(-1, D)
    R = x2.shape[0]
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    nb = R // br
    dx, dw_part = pl.pallas_call(
        functools.partial(_bwd_kernel, eps=eps),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
            pl.BlockSpec((br, D), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, D), x.dtype),
            jax.ShapeDtypeStruct((nb, D), jnp.float32),
        ],
        interpret=interpret,
        name="rmsnorm_bwd",
    )(x2, w.reshape(1, D), dy2)
    return dx.reshape(orig_shape), jnp.sum(dw_part, axis=0).astype(w.dtype)
