"""Optimizers, built in JAX from scratch (no external deps).

* AdamW — f32 moments by default, dtype-configurable (bf16 state halves
  optimizer HBM for ≥300B models).
* Adafactor — factored second moment: O(n+m) state instead of O(n·m) for
  matrices; the trillion-param (kimi-k2) training config uses it.
* global-norm clipping, linear-warmup + cosine decay schedule,
  microbatch gradient accumulation helper.

State pytrees mirror the parameter pytree, so the distribution layer can
shard optimizer state with the same rules as parameters (ZeRO-style: the
``fsdp`` logical axis shards both).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = [
    "OptConfig",
    "make_optimizer",
    "Optimizer",
    "clip_by_global_norm",
    "warmup_cosine",
]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"  # bf16 halves optimizer HBM
    # adafactor
    decay_offset: int = 0
    min_dim_size_to_factor: int = 128
    # schedule
    warmup_steps: int = 100
    total_steps: int = 10_000


@dataclasses.dataclass
class Optimizer:
    config: OptConfig
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    """update(grads, state, params, step) -> (new_params, new_state, metrics)"""


def warmup_cosine(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _adamw(cfg: OptConfig) -> Optimizer:
    sdt = jnp.dtype(cfg.state_dtype)

    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
        }

    def update(grads, state, params, step):
        grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
        lr = warmup_cosine(cfg, step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - cfg.b1**t
        bc2 = 1.0 - cfg.b2**t

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
            v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
            return (
                (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m_new.astype(sdt),
                v_new.astype(sdt),
            )

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v}, {"gnorm": gn, "lr": lr}

    return Optimizer(cfg, init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; Shazeer & Stern 2018)
# ---------------------------------------------------------------------------


def _factored(cfg: OptConfig, shape: tuple[int, ...]) -> bool:
    return (
        len(shape) >= 2
        and shape[-1] >= cfg.min_dim_size_to_factor
        and shape[-2] >= cfg.min_dim_size_to_factor
    )


def _adafactor(cfg: OptConfig) -> Optimizer:
    sdt = jnp.dtype(cfg.state_dtype)

    def init(params):
        def one(p):
            if _factored(cfg, p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], sdt),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], sdt),
                }
            return {"v": jnp.zeros(p.shape, sdt)}

        return {"v": jax.tree.map(one, params)}

    def update(grads, state, params, step):
        grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
        lr = warmup_cosine(cfg, step)
        t = (step + 1).astype(jnp.float32)
        beta2 = 1.0 - t**-0.8  # Adafactor's schedule

        def upd(p, g, v):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + 1e-30
            if _factored(cfg, p.shape):
                vr = beta2 * v["vr"].astype(jnp.float32) + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"].astype(jnp.float32) + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = (
                    vr[..., None]
                    / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                    * vc[..., None, :]
                )
                upd_ = gf * jax.lax.rsqrt(denom + 1e-30)
                nv = {"vr": vr.astype(sdt), "vc": vc.astype(sdt)}
            else:
                vf = beta2 * v["v"].astype(jnp.float32) + (1 - beta2) * g2
                upd_ = gf * jax.lax.rsqrt(vf + 1e-30)
                nv = {"v": vf.astype(sdt)}
            # update clipping (RMS ≤ 1) — Adafactor stability
            rms = jnp.sqrt(jnp.mean(jnp.square(upd_)) + 1e-30)
            upd_ = upd_ / jnp.maximum(1.0, rms)
            new_p = p.astype(jnp.float32) - lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), nv

        p_leaves, treedef = jax.tree.flatten(params)
        g_leaves = treedef.flatten_up_to(grads)
        v_leaves = treedef.flatten_up_to(state["v"])
        outs = [upd(p, g, v) for p, g, v in zip(p_leaves, g_leaves, v_leaves)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_p, {"v": new_v}, {"gnorm": gn, "lr": lr}

    return Optimizer(cfg, init, update)


def make_optimizer(cfg: OptConfig) -> Optimizer:
    if cfg.name == "adamw":
        return _adamw(cfg)
    if cfg.name == "adafactor":
        return _adafactor(cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
