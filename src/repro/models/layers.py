"""Layer building blocks: GQA attention, GLU MLP, top-k MoE, Mamba-2 mixer.

Every block is a pair of pure functions ``*_init(cfg, key) -> params`` and
``*_apply(cfg, params, …) -> y`` (plus a cached decode variant where the
block carries state).  Activation sharding is expressed through *logical*
axis names via :func:`repro.parallel.constrain`; parameter sharding rules
live in :mod:`repro.distributed.sharding` and match the pytree paths used
here.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import kernels
from repro.parallel import constrain
from .common import ModelConfig, apply_rope, dense_init, softcap

Params = dict[str, Any]

# ===========================================================================
# Norm
# ===========================================================================


def norm_init(cfg: ModelConfig) -> jax.Array:
    return jnp.ones((cfg.d_model,), jnp.float32)


def norm_apply(cfg: ModelConfig, w: jax.Array, x: jax.Array) -> jax.Array:
    return kernels.rmsnorm(x, w, eps=cfg.norm_eps)


# ===========================================================================
# Attention (self / cross, global / sliding-window, GQA)
# ===========================================================================


def attn_init(cfg: ModelConfig, key: jax.Array, *, cross: bool = False) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    D, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.pdtype
    return {
        "wq": dense_init(kq, (D, H, hd), dt, fan_in=D),
        "wk": dense_init(kk, (D, KVH, hd), dt, fan_in=D),
        "wv": dense_init(kv, (D, KVH, hd), dt, fan_in=D),
        "wo": dense_init(ko, (H, hd, D), dt, fan_in=H * hd),
    }


def _qkv(cfg: ModelConfig, p: Params, x: jax.Array, kv_src: jax.Array):
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(cfg.cdtype))
    k = jnp.einsum("bsd,dhk->bhsk", kv_src, p["wk"].astype(cfg.cdtype))
    v = jnp.einsum("bsd,dhk->bhsk", kv_src, p["wv"].astype(cfg.cdtype))
    q = constrain(q, "batch", "heads", "seq", "head_dim")
    k = constrain(k, "batch", "kv_heads", "seq", "head_dim")
    v = constrain(v, "batch", "kv_heads", "seq", "head_dim")
    return q, k, v


def _out(cfg: ModelConfig, p: Params, o: jax.Array) -> jax.Array:
    y = jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(cfg.cdtype))
    return constrain(y, "batch", "seq", "embed")


def attn_apply(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    *,
    kind: str = "global",
    causal: bool = True,
    cross_states: jax.Array | None = None,
) -> jax.Array:
    """Full-sequence attention (training / prefill).  x: (B, S, D)."""
    kv_src = cross_states if cross_states is not None else x
    q, k, v = _qkv(cfg, p, x, kv_src)
    if cross_states is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.local_window if kind == "local" else None
    o = kernels.flash_attention(
        q, k, v, causal=causal and cross_states is None, window=window
    )
    o = constrain(o, "batch", "heads", "seq", "head_dim")
    return _out(cfg, p, o)


def attn_cache_init(
    cfg: ModelConfig, batch: int, max_len: int, *, kind: str = "global"
) -> Params:
    KVH, hd = cfg.n_kv_heads, cfg.hd
    size = min(max_len, cfg.local_window) if kind == "local" else max_len
    seq_axis = "seq" if kind == "local" else "kv_seq"  # big caches shard on seq
    k = jnp.zeros((batch, KVH, size, hd), cfg.cdtype)
    v = jnp.zeros((batch, KVH, size, hd), cfg.cdtype)
    return {
        "k": constrain(k, "batch", "kv_heads", seq_axis, "head_dim"),
        "v": constrain(v, "batch", "kv_heads", seq_axis, "head_dim"),
    }


def attn_decode(
    cfg: ModelConfig,
    p: Params,
    x_t: jax.Array,
    pos: jax.Array,
    cache: Params,
    *,
    kind: str = "global",
) -> tuple[jax.Array, Params]:
    """One-token decode.  x_t: (B, 1, D); pos: scalar absolute position."""
    B = x_t.shape[0]
    size = cache["k"].shape[2]
    q = jnp.einsum("bsd,dhk->bhsk", x_t, p["wq"].astype(cfg.cdtype))
    k_t = jnp.einsum("bsd,dhk->bhsk", x_t, p["wk"].astype(cfg.cdtype))
    v_t = jnp.einsum("bsd,dhk->bhsk", x_t, p["wv"].astype(cfg.cdtype))
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k_t = apply_rope(k_t, pos[None], cfg.rope_theta)

    slot = jnp.mod(pos, size) if kind == "local" else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k_t.astype(cache["k"].dtype), (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v_t.astype(cache["v"].dtype), (0, 0, slot, 0))
    seq_axis = "seq" if kind == "local" else "kv_seq"
    ck = constrain(ck, "batch", "kv_heads", seq_axis, "head_dim")
    cv = constrain(cv, "batch", "kv_heads", seq_axis, "head_dim")

    # visibility: slot j holds absolute position p_j; attend iff 0 <= p_j <= pos
    # (ring buffers additionally imply pos - p_j < window by construction)
    j = jnp.arange(size)
    if kind == "local":
        p_j = pos - jnp.mod(pos - j, size)
    else:
        p_j = j
    valid = (p_j >= 0) & (p_j <= pos)

    # grouped-head einsum: q reshaped (B, KVH, group, 1, hd) contracts the
    # cache directly — no jnp.repeat, so no H-sized KV materialization and
    # no involuntary kv→heads resharding collective on the mesh
    group = cfg.n_heads // cfg.n_kv_heads
    qg = q.astype(jnp.float32).reshape(B, cfg.n_kv_heads, group, 1, cfg.hd)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, ck.astype(jnp.float32)) * (cfg.hd**-0.5)
    s = softcap(s, cfg.attn_logit_softcap)
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", pattn, cv.astype(jnp.float32))
    o = o.reshape(B, cfg.n_heads, 1, cfg.hd).astype(cfg.cdtype)
    return _out(cfg, p, o), {"k": ck, "v": cv}


def cross_cache_init(cfg: ModelConfig, p: Params, states: jax.Array) -> Params:
    """Precompute cross-attention K/V from encoder states (prefill once)."""
    k = jnp.einsum("bsd,dhk->bhsk", states, p["wk"].astype(cfg.cdtype))
    v = jnp.einsum("bsd,dhk->bhsk", states, p["wv"].astype(cfg.cdtype))
    return {"k": k, "v": v}


def cross_attn_decode(cfg: ModelConfig, p: Params, x_t: jax.Array, cache: Params) -> jax.Array:
    B = x_t.shape[0]
    q = jnp.einsum("bsd,dhk->bhsk", x_t, p["wq"].astype(cfg.cdtype))
    group = cfg.n_heads // cfg.n_kv_heads
    qg = q.astype(jnp.float32).reshape(B, cfg.n_kv_heads, group, 1, cfg.hd)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, cache["k"].astype(jnp.float32)) * (cfg.hd**-0.5)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", pattn, cache["v"].astype(jnp.float32))
    o = o.reshape(B, cfg.n_heads, 1, cfg.hd).astype(cfg.cdtype)
    return _out(cfg, p, o)


# ===========================================================================
# Dense GLU MLP
# ===========================================================================


def mlp_init(cfg: ModelConfig, key: jax.Array, d_ff: int | None = None) -> Params:
    ki, kg, ko = jax.random.split(key, 3)
    D, F = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.pdtype
    return {
        "wi": dense_init(ki, (D, F), dt),
        "wg": dense_init(kg, (D, F), dt),
        "wo": dense_init(ko, (F, D), dt, fan_in=F),
    }


def _act(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return jax.nn.silu(x) if cfg.mlp_act == "silu" else jax.nn.gelu(x)


def mlp_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(cfg.cdtype))
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(cfg.cdtype))
    h = constrain(h * _act(cfg, g), "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(cfg.cdtype))
    return constrain(y, "batch", "seq", "embed")


# ===========================================================================
# Token-choice top-k MoE (GShard dispatch/combine einsums)
# ===========================================================================


def moe_init(cfg: ModelConfig, key: jax.Array) -> Params:
    kr, ki, kg, ko, ks = jax.random.split(key, 5)
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    dt = cfg.pdtype
    p: Params = {
        "router": dense_init(kr, (D, E), jnp.float32),
        "wi": dense_init(ki, (E, D, F), dt, fan_in=D),
        "wg": dense_init(kg, (E, D, F), dt, fan_in=D),
        "wo": dense_init(ko, (E, F, D), dt, fan_in=F),
    }
    if cfg.shared_experts:
        p["shared"] = mlp_init(cfg, ks, d_ff=(cfg.moe_d_ff or cfg.d_ff) * cfg.shared_experts)
    return p


def moe_apply(
    cfg: ModelConfig, p: Params, x: jax.Array, *, full_capacity: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss).  Token-choice top-K routing with per-group
    capacity, index-based (gather/scatter) dispatch:

        route:    top-K(softmax(x·router))                    (G,S,K)
        dispatch: slot buffer (E, C) of token indices; gather (G,E,C,D)
        expert GLU on the gathered slots
        combine:  scatter-add of gated expert outputs back to tokens

    Unlike the dense GShard dispatch-einsum (O(S·E·C) one-hot tensors and
    2·S·E·C·D routing FLOPs — prohibitive at E=384), the gather/scatter
    form costs O(E·C·D) memory and ~zero routing FLOPs; the expert-
    parallel all-to-all materializes when the gathered slots are
    resharded from the data axis to the expert axis (constrain below).
    Tokens beyond capacity are dropped (the residual passes them through);
    groups = batch rows, as in GShard.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    if full_capacity:
        # decode / tiny batches: one group; capacity bounded by a generous
        # factor instead of C=T (which cost E·T slots — 48× overcompute for
        # kimi's 384 experts at decode batch 128; §Perf hillclimb)
        G, Sg = 1, B * S
        dcf = max(cfg.capacity_factor, 2.0)
        C = min(Sg, max(1, int(Sg * K / E * dcf)))
    else:
        G, Sg = B, S
        C = min(Sg, max(1, int(Sg * K / E * cfg.capacity_factor)))
    xg = x.reshape(G, Sg, D)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (G,S,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch/GShard)
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=2), axis=(0, 1)
    )
    aux = E * jnp.sum(me * ce)

    def route_group(xs, eidx, gv):
        # xs (Sg,D); eidx/gv (Sg,K) → slot buffers (E,C)
        e_flat = eidx.reshape(-1)  # (Sg*K,) expert of each assignment
        tok_flat = jnp.repeat(jnp.arange(Sg), K)
        g_flat = gv.reshape(-1)
        # position of each assignment within its expert's buffer
        sel = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (Sg*K,E)
        pos_flat = jnp.sum(sel * (jnp.cumsum(sel, axis=0) - 1), axis=-1)
        keep = pos_flat < C
        e_safe = jnp.where(keep, e_flat, E)  # overflow row
        p_safe = jnp.where(keep, pos_flat, 0)
        slot_tok = jnp.full((E + 1, C), Sg, jnp.int32)  # Sg = zero-pad row
        slot_tok = slot_tok.at[e_safe, p_safe].set(tok_flat, mode="drop")[:E]
        slot_gate = jnp.zeros((E + 1, C), jnp.float32)
        slot_gate = slot_gate.at[e_safe, p_safe].set(g_flat, mode="drop")[:E]
        xs_pad = jnp.concatenate([xs, jnp.zeros((1, D), xs.dtype)], axis=0)
        xe = xs_pad[slot_tok]  # (E,C,D) gather
        return xe, slot_tok, slot_gate

    xe, slot_tok, slot_gate = jax.vmap(route_group)(xg, gate_idx, gate_vals)
    # the EP boundary: (G,E,C,D) moves from data-sharded G to expert-sharded
    # E here — GSPMD materializes the MoE all-to-all at this constraint
    xe = constrain(xe, "batch", "experts", None, "embed")
    h = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(cfg.cdtype))
    g_ = jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(cfg.cdtype))
    ye = jnp.einsum("gecf,efd->gecd", h * _act(cfg, g_), p["wo"].astype(cfg.cdtype))
    ye = constrain(ye, "batch", "experts", None, "embed")

    def combine_group(ye_g, slot_tok_g, slot_gate_g):
        y = jnp.zeros((Sg + 1, D), jnp.float32)
        w = ye_g.astype(jnp.float32) * slot_gate_g[..., None]
        y = y.at[slot_tok_g.reshape(-1)].add(w.reshape(-1, D), mode="drop")
        return y[:Sg]

    y = jax.vmap(combine_group)(ye, slot_tok, slot_gate)
    y = y.reshape(B, S, D).astype(cfg.cdtype)

    if cfg.shared_experts:
        y = y + mlp_apply(cfg, p["shared"], x)
    return constrain(y, "batch", "seq", "embed"), aux


# ===========================================================================
# Mamba-2 mixer (SSD)
# ===========================================================================


def mamba_init(cfg: ModelConfig, key: jax.Array) -> Params:
    kin, kout, kconv, kdt = jax.random.split(key, 4)
    D, DI, NH, N = cfg.d_model, cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_state
    G = 1  # n_groups
    dt = cfg.pdtype
    conv_dim = DI + 2 * G * N
    proj_out = 2 * DI + 2 * G * N + NH  # [z, x, B, C, dt]
    return {
        "in_proj": dense_init(kin, (D, proj_out), dt),
        "conv_w": dense_init(kconv, (cfg.conv_kernel, conv_dim), dt, fan_in=cfg.conv_kernel),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, NH, dtype=jnp.float32)),
        "D_skip": jnp.ones((NH,), jnp.float32),
        "dt_bias": jnp.zeros((NH,), jnp.float32),
        "gate_norm": jnp.ones((DI,), jnp.float32),
        "out_proj": dense_init(kout, (DI, D), dt, fan_in=DI),
    }


def _mamba_split(cfg: ModelConfig, zxbcdt: jax.Array):
    DI, N, NH = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z, xc, dt = jnp.split(zxbcdt, [DI, 2 * DI + 2 * N], axis=-1)
    return z, xc, dt  # xc = [x, B, C] (conv'd together), dt: (…, NH)


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along axis 1.  x: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    return out


def mamba_apply(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    *,
    return_state: bool = False,
):
    """Full-sequence Mamba-2 block.  x: (B, S, D)."""
    B, S, D = x.shape
    DI, N, NH, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cfg.cdtype))
    z, xc, dtr = _mamba_split(cfg, zxbcdt)
    xc = _causal_conv(xc, p["conv_w"].astype(cfg.cdtype))
    xc = jax.nn.silu(xc)
    xs, Bm, Cm = jnp.split(xc, [DI, DI + N], axis=-1)
    xs = constrain(xs, "batch", "seq", "ssm_proj")

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # (B,S,NH)
    A = -jnp.exp(p["A_log"])  # (NH,) negative
    xh = xs.reshape(B, S, NH, P)
    out = kernels.ssd_scan(
        xh,
        dt,
        A,
        Bm[:, :, None, :],
        Cm[:, :, None, :],
        return_final_state=return_state,
    )
    y, state = out if return_state else (out, None)
    y = y + p["D_skip"].astype(cfg.cdtype)[None, None, :, None] * xh  # skip
    y = y.astype(cfg.cdtype).reshape(B, S, DI)
    y = y * jax.nn.silu(z)
    y = kernels.rmsnorm(y, p["gate_norm"], eps=cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cfg.cdtype))
    y = constrain(y, "batch", "seq", "embed")
    if return_state:
        return y, state
    return y


def mamba_cache_init(cfg: ModelConfig, batch: int) -> Params:
    G = 1
    conv_dim = cfg.d_inner + 2 * G * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), cfg.cdtype),
        "ssm": jnp.zeros(
            (batch, cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
        ),
    }


def mamba_decode(
    cfg: ModelConfig, p: Params, x_t: jax.Array, cache: Params
) -> tuple[jax.Array, Params]:
    """One-token Mamba-2 step.  x_t: (B, 1, D)."""
    B = x_t.shape[0]
    DI, N, NH, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x_t, p["in_proj"].astype(cfg.cdtype))
    z, xc_t, dtr = _mamba_split(cfg, zxbcdt)  # xc_t: (B,1,conv_dim)

    window = jnp.concatenate([cache["conv"], xc_t], axis=1)  # (B,K,conv)
    w = p["conv_w"].astype(cfg.cdtype)
    xc = jnp.einsum("bkc,kc->bc", window, w)[:, None, :]
    xc = jax.nn.silu(xc)
    new_conv = window[:, 1:]

    xs, Bm, Cm = jnp.split(xc, [DI, DI + N], axis=-1)
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,NH)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, NH, P)
    new_ssm, y = kernels.ssd_step(cache["ssm"], xh, dt, A, Bm[:, 0, None, :], Cm[:, 0, None, :])
    y = y + p["D_skip"].astype(cfg.cdtype)[None, :, None] * xh
    y = y.astype(cfg.cdtype).reshape(B, 1, DI)
    y = y * jax.nn.silu(z)
    y = kernels.rmsnorm(y, p["gate_norm"], eps=cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cfg.cdtype))
    return y, {"conv": new_conv, "ssm": new_ssm}
