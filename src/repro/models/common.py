"""Model configuration schema + shared building blocks (RoPE, init, norms).

One :class:`ModelConfig` describes every assigned architecture family:
dense/GQA transformers, sliding-window patterns, MoE, Mamba-2 SSM mixers,
hybrid interleaves, encoder-decoder, and cross-attention (VLM) injection.
The per-layer structure is an explicit list of :class:`LayerSpec`s, which
the stack builder groups into ``lax.scan`` segments (repeating periods) to
bound HLO size at 60+ layers.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

import jax
import jax.numpy as jnp

Mixer = Literal["attn", "mamba"]
AttnKind = Literal["global", "local"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Structure of one layer: the sequence mixer + the channel mixer."""

    mixer: Mixer = "attn"
    attn_kind: AttnKind = "global"
    moe: bool = False
    ffn: bool = True  # False: mixer-only layer (pure Mamba-2 stacks)
    cross_attn: bool = False  # extra cross-attention sublayer (VLM/enc-dec)

    @property
    def tag(self) -> str:
        return (
            f"{self.mixer}-{self.attn_kind if self.mixer == 'attn' else 'ssm'}"
            f"{'-moe' if self.moe else ''}{'-x' if self.cross_attn else ''}"
        )


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | hybrid | ssm | audio | vlm

    # dimensions
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int | None = None  # default d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024

    # attention
    rope_theta: float = 10_000.0
    local_window: int = 1024  # for attn_kind == "local"
    attn_logit_softcap: float | None = None
    mlp_act: str = "silu"  # silu (SwiGLU) | gelu

    # layer structure: period repeated through the depth (see layer_specs())
    layer_period: tuple[LayerSpec, ...] | None = None

    # MoE
    num_experts: int = 0
    top_k: int = 2
    moe_d_ff: int | None = None  # expert FFN width (default d_ff)
    shared_experts: int = 0  # always-on experts alongside routed ones
    capacity_factor: float = 1.25  # GShard token-choice capacity

    # Mamba-2 (SSM mixers)
    ssm_state: int = 128  # N
    ssm_head_dim: int = 64  # P
    ssm_expand: int = 2  # d_inner = expand * d_model
    conv_kernel: int = 4

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0

    # VLM cross-attention injection
    cross_attn_period: int = 0  # 0 = none; k = every k-th layer gets cross-attn
    num_image_tokens: int = 1024

    # dtypes / numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # distribution hints (consumed by repro.distributed)
    fsdp: bool = False  # additionally shard params over the data axis
    remat: bool = True  # activation checkpointing on layer blocks
    scan_layers: bool = True  # lax.scan over repeated periods

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def pdtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self) -> jnp.dtype:
        return jnp.dtype(self.compute_dtype)

    def layer_specs(self) -> list[LayerSpec]:
        """The full depth-wise layer list, from the period."""
        period = self.layer_period or (LayerSpec(),)
        out = [period[i % len(period)] for i in range(self.n_layers)]
        if self.cross_attn_period:
            out = [
                dataclasses.replace(
                    s, cross_attn=((i + 1) % self.cross_attn_period == 0)
                )
                for i, s in enumerate(out)
            ]
        return out

    def scan_segments(self) -> list[tuple[tuple[LayerSpec, ...], int]]:
        """Group the depth into (pattern, repeat) segments for lax.scan.

        A full period repeated r times scans with the period as body; any
        remainder layers become trailing repeat-1 segments."""
        specs = self.layer_specs()
        period = list(self.layer_period or (LayerSpec(),))
        if self.cross_attn_period:
            # cross-attn breaks the strict period: fall back to chunking by
            # the cross-attn cycle so the scan body stays uniform.
            cyc = self.cross_attn_period
            period = specs[:cyc]
            if len(specs) >= cyc and all(
                specs[i] == period[i % cyc] for i in range(len(specs) - len(specs) % cyc)
            ):
                reps, rem = divmod(len(specs), cyc)
                segs = [(tuple(period), reps)] if reps else []
                segs += [((s,), 1) for s in specs[reps * cyc:]]
                return segs
            return [((s,), 1) for s in specs]
        k = len(period)
        reps, rem = divmod(self.n_layers, k)
        segs: list[tuple[tuple[LayerSpec, ...], int]] = []
        if reps:
            segs.append((tuple(period), reps))
        segs += [((specs[reps * k + i],), 1) for i in range(rem)]
        return segs


# ---------------------------------------------------------------------------
# Initializers / numerics helpers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: Sequence[int], dtype, fan_in: int | None = None):
    """Truncated-normal with 1/sqrt(fan_in) scale (standard LM init)."""
    fi = fan_in if fan_in is not None else shape[0]
    scale = fi**-0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, tuple(shape), jnp.float32) * scale).astype(
        dtype
    )


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, D) with D even; positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,D/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
