"""Model assembly: embedding → scanned layer stack → head, for every
assigned architecture family.

Three execution paths share one parameter pytree:

* ``forward``      — full-sequence training forward (logits).
* ``prefill``      — full-sequence forward that additionally materializes
                     the decode caches (KV / conv+SSM state / cross-KV).
* ``decode_step``  — single-token step against the caches.

Depth is organized as *scan segments* (``ModelConfig.scan_segments``): a
repeating period of layers becomes a ``lax.scan`` whose body applies one
period, with parameters (and caches) stacked on the leading axis.  This
bounds compiled-HLO size at 60+ layers and is remat-friendly: the
checkpoint policy wraps the scan body.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel import constrain
from . import layers as L
from .common import LayerSpec, ModelConfig

Params = dict[str, Any]

_AUX_WEIGHT = 0.01  # MoE load-balance loss weight


# ===========================================================================
# Per-layer init / apply
# ===========================================================================


def layer_init(cfg: ModelConfig, spec: LayerSpec, key: jax.Array) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": L.norm_init(cfg)}
    p["mixer"] = L.attn_init(cfg, k1) if spec.mixer == "attn" else L.mamba_init(cfg, k1)
    if spec.ffn:
        p["norm2"] = L.norm_init(cfg)
        p["ffn"] = L.moe_init(cfg, k2) if spec.moe else L.mlp_init(cfg, k2)
    if spec.cross_attn:
        p["norm_x"] = L.norm_init(cfg)
        p["cross"] = L.attn_init(cfg, k3, cross=True)
    return p


def layer_apply(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    cross_states: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    h = L.norm_apply(cfg, p["norm1"], x)
    if spec.mixer == "attn":
        y = L.attn_apply(cfg, p["mixer"], h, positions, kind=spec.attn_kind, causal=causal)
    else:
        y = L.mamba_apply(cfg, p["mixer"], h)
    x = x + y
    if spec.cross_attn and cross_states is not None:
        hx = L.norm_apply(cfg, p["norm_x"], x)
        x = x + L.attn_apply(cfg, p["cross"], hx, positions, cross_states=cross_states)
    if not spec.ffn:
        return x, jnp.zeros((), jnp.float32)
    h2 = L.norm_apply(cfg, p["norm2"], x)
    if spec.moe:
        y2, aux = L.moe_apply(cfg, p["ffn"], h2)
    else:
        y2, aux = L.mlp_apply(cfg, p["ffn"], h2), jnp.zeros((), jnp.float32)
    return x + y2, aux


# -- cached decode ----------------------------------------------------------


def layer_cache_init(
    cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int
) -> Params:
    c: Params = {}
    if spec.mixer == "attn":
        c["self"] = L.attn_cache_init(cfg, batch, max_len, kind=spec.attn_kind)
    else:
        c["self"] = L.mamba_cache_init(cfg, batch)
    if spec.cross_attn:
        KVH, hd = cfg.n_kv_heads, cfg.hd
        n_cross = cfg.num_image_tokens
        c["cross"] = {
            "k": jnp.zeros((batch, KVH, n_cross, hd), cfg.cdtype),
            "v": jnp.zeros((batch, KVH, n_cross, hd), cfg.cdtype),
        }
    return c


def layer_decode(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Params,
    x_t: jax.Array,
    pos: jax.Array,
    cache: Params,
) -> tuple[jax.Array, Params]:
    new_cache: Params = {}
    h = L.norm_apply(cfg, p["norm1"], x_t)
    if spec.mixer == "attn":
        y, new_cache["self"] = L.attn_decode(
            cfg, p["mixer"], h, pos, cache["self"], kind=spec.attn_kind
        )
    else:
        y, new_cache["self"] = L.mamba_decode(cfg, p["mixer"], h, cache["self"])
    x_t = x_t + y
    if spec.cross_attn:
        hx = L.norm_apply(cfg, p["norm_x"], x_t)
        x_t = x_t + L.cross_attn_decode(cfg, p["cross"], hx, cache["cross"])
        new_cache["cross"] = cache["cross"]
    if not spec.ffn:
        return x_t, new_cache
    h2 = L.norm_apply(cfg, p["norm2"], x_t)
    if spec.moe:
        y2, _ = L.moe_apply(cfg, p["ffn"], h2, full_capacity=True)
    else:
        y2 = L.mlp_apply(cfg, p["ffn"], h2)
    return x_t + y2, new_cache


def layer_prefill(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    max_len: int,
    *,
    cross_states: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    """Forward + cache construction (prefill).  Runs the same math as
    ``layer_apply`` and additionally stores K/V (padded to ``max_len``),
    conv windows and final SSM state."""
    B, S, _ = x.shape
    cache: Params = {}
    h = L.norm_apply(cfg, p["norm1"], x)
    if spec.mixer == "attn":
        q, k, v = L._qkv(cfg, p["mixer"], h, h)
        from repro import kernels

        from .common import apply_rope

        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        window = cfg.local_window if spec.attn_kind == "local" else None
        o = kernels.flash_attention(q, k, v, causal=True, window=window)
        y = L._out(cfg, p["mixer"], o)
        c0 = L.attn_cache_init(cfg, B, max_len, kind=spec.attn_kind)
        size = c0["k"].shape[2]
        ktail = k[:, :, -size:] if S > size else k
        vtail = v[:, :, -size:] if S > size else v
        tail = ktail.shape[2]
        if spec.attn_kind == "local" and S > size:
            # ring placement: token at absolute position p lives in slot p%size
            idx = jnp.mod(jnp.arange(tail) + (S - tail), size)
            ck = c0["k"].at[:, :, idx].set(ktail.astype(c0["k"].dtype))
            cv = c0["v"].at[:, :, idx].set(vtail.astype(c0["v"].dtype))
        else:
            ck = jax.lax.dynamic_update_slice(c0["k"], ktail.astype(c0["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(c0["v"], vtail.astype(c0["v"].dtype), (0, 0, 0, 0))
        cache["self"] = {"k": ck, "v": cv}
    else:
        DI, N = cfg.d_inner, cfg.ssm_state
        zxbcdt = jnp.einsum("bsd,de->bse", h, p["mixer"]["in_proj"].astype(cfg.cdtype))
        _, xc_raw, _ = L._mamba_split(cfg, zxbcdt)
        y, state = L.mamba_apply(cfg, p["mixer"], h, return_state=True)
        K = cfg.conv_kernel
        conv = jnp.pad(xc_raw, ((0, 0), (max(K - 1 - S, 0), 0), (0, 0)))[:, -(K - 1):]
        cache["self"] = {"conv": conv.astype(cfg.cdtype), "ssm": state}
    x = x + y
    if spec.cross_attn and cross_states is not None:
        hx = L.norm_apply(cfg, p["norm_x"], x)
        x = x + L.attn_apply(cfg, p["cross"], hx, positions, cross_states=cross_states)
        cache["cross"] = L.cross_cache_init(cfg, p["cross"], cross_states)
    if not spec.ffn:
        return x, cache
    h2 = L.norm_apply(cfg, p["norm2"], x)
    if spec.moe:
        y2, _ = L.moe_apply(cfg, p["ffn"], h2)
    else:
        y2 = L.mlp_apply(cfg, p["ffn"], h2)
    return x + y2, cache


# ===========================================================================
# Stack (scan segments)
# ===========================================================================


def _stack_leaves(trees: list[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def stack_init(cfg: ModelConfig, key: jax.Array, segments=None) -> list[Params]:
    segments = segments if segments is not None else cfg.scan_segments()
    out = []
    for pattern, reps in segments:
        keys = jax.random.split(key, reps + 1)
        key = keys[0]
        per_rep = [
            [layer_init(cfg, spec, k2)
             for spec, k2 in zip(pattern, jax.random.split(k, len(pattern)))]
            for k in keys[1:]
        ]
        if reps == 1:
            out.append({"layers": per_rep[0]})
        else:
            out.append(
                {"layers": [_stack_leaves([r[i] for r in per_rep])
                            for i in range(len(pattern))]}
            )
    return out


def _maybe_remat(cfg: ModelConfig, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def stack_apply(
    cfg: ModelConfig,
    segs: list[Params],
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    cross_states: jax.Array | None = None,
    segments=None,
) -> tuple[jax.Array, jax.Array]:
    segments = segments if segments is not None else cfg.scan_segments()
    aux_total = jnp.zeros((), jnp.float32)
    for (pattern, reps), seg in zip(segments, segs):
        if reps == 1 or not cfg.scan_layers:
            lp_list = seg["layers"]
            iters = (
                [jax.tree.map(lambda l: l[i], lp_list) for i in range(reps)]
                if reps > 1 else [lp_list]
            )
            for lps in iters:
                for spec, lp in zip(pattern, lps):
                    x, aux = layer_apply(
                        cfg, spec, lp, x, positions, causal=causal, cross_states=cross_states
                    )
                    aux_total = aux_total + aux
        else:

            def body(carry, lps, pattern=pattern):
                x, aux_sum = carry
                for spec, lp in zip(pattern, lps):
                    x, aux = layer_apply(
                        cfg, spec, lp, x, positions, causal=causal, cross_states=cross_states
                    )
                    aux_sum = aux_sum + aux
                return (x, aux_sum), None

            (x, aux_total), _ = jax.lax.scan(
                _maybe_remat(cfg, body), (x, aux_total), seg["layers"]
            )
    return x, aux_total


def stack_cache_init(
    cfg: ModelConfig, batch: int, max_len: int, segments=None
) -> list[Params]:
    segments = segments if segments is not None else cfg.scan_segments()
    out = []
    for pattern, reps in segments:
        per_pos = [layer_cache_init(cfg, spec, batch, max_len) for spec in pattern]
        if reps == 1:
            out.append({"layers": per_pos})
        else:
            out.append(
                {"layers": [jax.tree.map(lambda c: jnp.stack([c] * reps), c) for c in per_pos]}
            )
    return out


def stack_decode(
    cfg: ModelConfig,
    segs: list[Params],
    caches: list[Params],
    x_t: jax.Array,
    pos: jax.Array,
    segments=None,
) -> tuple[jax.Array, list[Params]]:
    segments = segments if segments is not None else cfg.scan_segments()
    new_caches = []
    for (pattern, reps), seg, seg_cache in zip(segments, segs, caches):
        if reps == 1 or not cfg.scan_layers:
            ncs = []
            layer_iter = (
                [
                    (jax.tree.map(lambda l: l[i], seg["layers"]),
                     jax.tree.map(lambda c: c[i], seg_cache["layers"]))
                    for i in range(reps)
                ]
                if reps > 1
                else [(seg["layers"], seg_cache["layers"])]
            )
            for lps, lcs in layer_iter:
                ncs_rep = []
                for spec, lp, lc in zip(pattern, lps, lcs):
                    x_t, nc = layer_decode(cfg, spec, lp, x_t, pos, lc)
                    ncs_rep.append(nc)
                ncs.append(ncs_rep)
            if reps > 1:
                new_caches.append(
                    {"layers": [_stack_leaves([r[i] for r in ncs])
                                for i in range(len(pattern))]}
                )
            else:
                new_caches.append({"layers": ncs[0]})
        else:

            def body(x_t, lps_lcs, pattern=pattern):
                lps, lcs = lps_lcs
                ncs = []
                for spec, lp, lc in zip(pattern, lps, lcs):
                    x_t, nc = layer_decode(cfg, spec, lp, x_t, pos, lc)
                    ncs.append(nc)
                return x_t, ncs

            x_t, nc_stacked = jax.lax.scan(body, x_t, (seg["layers"], seg_cache["layers"]))
            new_caches.append({"layers": nc_stacked})
    return x_t, new_caches


def stack_prefill(
    cfg: ModelConfig,
    segs: list[Params],
    x: jax.Array,
    positions: jax.Array,
    max_len: int,
    *,
    cross_states: jax.Array | None = None,
    segments=None,
) -> tuple[jax.Array, list[Params]]:
    segments = segments if segments is not None else cfg.scan_segments()
    caches = []
    for (pattern, reps), seg in zip(segments, segs):
        if reps == 1 or not cfg.scan_layers:
            iters = (
                [jax.tree.map(lambda l: l[i], seg["layers"]) for i in range(reps)]
                if reps > 1
                else [seg["layers"]]
            )
            ncs = []
            for lps in iters:
                ncs_rep = []
                for spec, lp in zip(pattern, lps):
                    x, c = layer_prefill(
                        cfg, spec, lp, x, positions, max_len, cross_states=cross_states
                    )
                    ncs_rep.append(c)
                ncs.append(ncs_rep)
            if reps > 1:
                caches.append(
                    {"layers": [_stack_leaves([r[i] for r in ncs])
                                for i in range(len(pattern))]}
                )
            else:
                caches.append({"layers": ncs[0]})
        else:

            def body(x, lps, pattern=pattern):
                cs = []
                for spec, lp in zip(pattern, lps):
                    x, c = layer_prefill(
                        cfg, spec, lp, x, positions, max_len, cross_states=cross_states
                    )
                    cs.append(c)
                return x, cs

            x, cs_stacked = jax.lax.scan(_maybe_remat(cfg, body), x, seg["layers"])
            caches.append({"layers": cs_stacked})
    return x, caches


# ===========================================================================
# Full model
# ===========================================================================


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    ke, ks, kh, kenc = jax.random.split(key, 4)
    p: Params = {
        "embed": L.dense_init(ke, (cfg.vocab, cfg.d_model), cfg.pdtype, fan_in=cfg.d_model),
        "segments": stack_init(cfg, ks),
        "final_norm": L.norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(kh, (cfg.d_model, cfg.vocab), cfg.pdtype)
    if cfg.enc_dec:
        enc_cfg = encoder_config(cfg)
        p["encoder"] = {
            "segments": stack_init(enc_cfg, kenc, enc_cfg.scan_segments()),
            "final_norm": L.norm_init(enc_cfg),
        }
    return p


def encoder_config(cfg: ModelConfig) -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        cfg,
        n_layers=cfg.n_enc_layers,
        layer_period=(LayerSpec(),),
        cross_attn_period=0,
        enc_dec=False,
    )


def _embed(cfg: ModelConfig, p: Params, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["embed"], tokens, axis=0).astype(cfg.cdtype)
    if cfg.tie_embeddings:
        x = x * (cfg.d_model**0.5)  # gemma-style embedding scale
    return constrain(x, "batch", "seq", "embed")


def _logits(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    x = L.norm_apply(cfg, p["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", x, p["embed"].astype(cfg.cdtype),
            preferred_element_type=jnp.float32,
        )
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", x, p["lm_head"].astype(cfg.cdtype),
            preferred_element_type=jnp.float32,
        )
    return constrain(logits, "batch", "seq", "vocab")


def encode(cfg: ModelConfig, p: Params, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend per the brief).  Non-causal self-attention."""
    enc_cfg = encoder_config(cfg)
    S = frames.shape[1]
    pos = jnp.arange(S)
    x = frames.astype(cfg.cdtype)
    x, _ = stack_apply(
        enc_cfg, p["encoder"]["segments"], x, pos, causal=False,
        segments=enc_cfg.scan_segments(),
    )
    return L.norm_apply(enc_cfg, p["encoder"]["final_norm"], x)


def forward(
    cfg: ModelConfig,
    p: Params,
    tokens: jax.Array,
    *,
    cross_states: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Training forward.  Returns (logits (B,S,V) f32, moe aux loss)."""
    B, S = tokens.shape
    pos = jnp.arange(S)
    x = _embed(cfg, p, tokens)
    x, aux = stack_apply(cfg, p["segments"], x, pos, causal=True, cross_states=cross_states)
    return _logits(cfg, p, x), aux


def loss_fn(cfg: ModelConfig, p: Params, batch: dict) -> tuple[jax.Array, dict]:
    cross = _cross_states(cfg, p, batch)
    logits, aux = forward(cfg, p, batch["tokens"], cross_states=cross)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    loss = nll + _AUX_WEIGHT * aux
    return loss, {"nll": nll, "aux": aux}


def _cross_states(cfg: ModelConfig, p: Params, batch: dict) -> jax.Array | None:
    if cfg.enc_dec:
        return encode(cfg, p, batch["enc_frames"])
    if cfg.cross_attn_period:
        return batch["image_embeds"].astype(cfg.cdtype)
    return None


def cache_init(cfg: ModelConfig, batch: int, max_len: int) -> list[Params]:
    return stack_cache_init(cfg, batch, max_len)


def prefill(
    cfg: ModelConfig,
    p: Params,
    tokens: jax.Array,
    max_len: int,
    *,
    batch_extras: dict | None = None,
) -> tuple[jax.Array, list[Params]]:
    """Returns (logits of last position (B,V), caches)."""
    B, S = tokens.shape
    pos = jnp.arange(S)
    cross = _cross_states(cfg, p, batch_extras or {})
    x = _embed(cfg, p, tokens)
    x, caches = stack_prefill(cfg, p["segments"], x, pos, max_len, cross_states=cross)
    logits = _logits(cfg, p, x[:, -1:])[:, 0]
    return logits, caches


def decode_step(
    cfg: ModelConfig,
    p: Params,
    token_t: jax.Array,
    pos: jax.Array,
    caches: list[Params],
) -> tuple[jax.Array, list[Params]]:
    """token_t: (B,) int32; pos: scalar int32.  Returns ((B,V) f32, caches)."""
    x_t = _embed(cfg, p, token_t[:, None])
    x_t, new_caches = stack_decode(cfg, p["segments"], caches, x_t, pos)
    logits = _logits(cfg, p, x_t)[:, 0]
    return logits, new_caches
