"""Model zoo: one config schema, every assigned architecture family."""

from .common import LayerSpec, ModelConfig
from .model import (
    cache_init,
    decode_step,
    encode,
    forward,
    init_params,
    loss_fn,
    prefill,
)

__all__ = [
    "LayerSpec",
    "ModelConfig",
    "init_params",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "cache_init",
    "encode",
]
