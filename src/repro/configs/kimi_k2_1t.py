"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 + 1 shared expert.

[arXiv:2501.kimi2 per the brief] 61L d_model=7168 64H (GQA kv=8)
d_ff=2048 (expert width) vocab=163840, MoE 384e top-8.  head_dim pinned to
128 (64×112 ≠ published head size).  Adafactor + bf16 state at this scale
(see repro.optim)."""

from repro.models import LayerSpec, ModelConfig

SUBQUADRATIC = False


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=2048,
        vocab=163840,
        layer_period=(LayerSpec(moe=True),),
        num_experts=384,
        top_k=8,
        moe_d_ff=2048,
        shared_experts=1,
        fsdp=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-reduced",
        family="moe",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=32,
        vocab=512,
        layer_period=(LayerSpec(moe=True),),
        num_experts=8,
        top_k=4,
        moe_d_ff=32,
        shared_experts=1,
        capacity_factor=8.0,
        param_dtype="float32",
        compute_dtype="float32",
    )
