"""Architecture registry: ``--arch <id>`` → ModelConfig, cells, specs.

``input_specs(cfg, cell)`` builds ShapeDtypeStruct stand-ins for every
model input of a (architecture × shape) cell — weak-type-correct,
shardable, no device allocation — which is exactly what the multi-pod
dry-run lowers."""

from __future__ import annotations

import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import ModelConfig
from .base import SHAPES, ShapeCell

__all__ = [
    "ARCHS",
    "SHAPES",
    "ShapeCell",
    "get_config",
    "is_subquadratic",
    "cells_for",
    "input_specs",
    "cache_specs",
]

ARCHS: dict[str, str] = {
    "jamba-v0.1-52b": "jamba_v01_52b",
    "gemma3-1b": "gemma3_1b",
    "internlm2-1.8b": "internlm2_1_8b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "starcoder2-15b": "starcoder2_15b",
    "whisper-medium": "whisper_medium",
    "grok-1-314b": "grok_1_314b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "mamba2-370m": "mamba2_370m",
    "llama-3.2-vision-11b": "llama32_vision_11b",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str, *, reduced: bool = False) -> ModelConfig:
    m = _module(arch)
    return m.reduced() if reduced else m.config()


def is_subquadratic(arch: str) -> bool:
    return bool(_module(arch).SUBQUADRATIC)


def cells_for(arch: str) -> list[ShapeCell]:
    """The runnable shape cells for an arch (long_500k only when
    sub-quadratic, per the brief)."""
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if is_subquadratic(arch):
        cells.append(SHAPES["long_500k"])
    return cells


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict[str, Any]:
    """Model inputs for a cell.

    train:   {tokens, labels}                     (B, S) int32
    prefill: {tokens}                             (B, S) int32
    decode:  {token, pos}                         (B,) int32 + scalar
    plus modality stubs (enc_frames / image_embeds) where the arch needs
    them — "the modality frontend is a STUB; input_specs() provides
    precomputed frame/patch embeddings" (brief).
    """
    B, S = cell.global_batch, cell.seq_len
    out: dict[str, Any] = {}
    if cell.kind == "train":
        out["tokens"] = _sds((B, S), jnp.int32)
        out["labels"] = _sds((B, S), jnp.int32)
    elif cell.kind == "prefill":
        out["tokens"] = _sds((B, S), jnp.int32)
    else:  # decode
        out["token"] = _sds((B,), jnp.int32)
        out["pos"] = _sds((), jnp.int32)
    if cfg.enc_dec and cell.kind != "decode":
        out["enc_frames"] = _sds((B, min(S, ENC_FRAMES), cfg.d_model), cfg.cdtype)
    if cfg.cross_attn_period and not cfg.enc_dec and cell.kind != "decode":
        out["image_embeds"] = _sds((B, cfg.num_image_tokens, cfg.d_model), cfg.cdtype)
    return out


#: Whisper encoder output length (30 s at 50 Hz post-conv — the published
#: frontend geometry; the conv stub's output length).
ENC_FRAMES = 1500


def cache_specs(cfg: ModelConfig, cell: ShapeCell) -> Any:
    """Decode-cache ShapeDtypeStructs for a decode cell (the KV/SSM state
    the serve_step reads and writes)."""
    from repro.models import cache_init

    if cfg.enc_dec:
        # decoder KV sized to the target seq; cross-KV sized to the encoder
        # output length (the conv-stub geometry)
        import dataclasses

        cfg = dataclasses.replace(cfg, num_image_tokens=min(cell.seq_len, ENC_FRAMES))
    return jax.eval_shape(
        lambda: cache_init(cfg, cell.global_batch, cell.seq_len)
    )
