"""Whisper-medium — encoder-decoder audio transformer (backbone only).

[arXiv:2212.04356] 24L(enc)+24L(dec) d_model=1024 16H (kv=16) d_ff=4096
vocab=51865.  The conv frontend is a STUB per the brief: ``input_specs``
provides precomputed frame embeddings (B, S, d_model).  Decoder layers
carry self-attention + cross-attention.  RoPE replaces Whisper's absolute
positions (DESIGN.md §7)."""

from repro.models import ModelConfig

SUBQUADRATIC = False  # full attention enc+dec → long_500k skipped


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        n_layers=24,
        n_enc_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab=51865,
        enc_dec=True,
        cross_attn_period=1,  # cross-attention on every decoder layer
        mlp_act="gelu",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-reduced",
        family="audio",
        n_layers=3,
        n_enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        enc_dec=True,
        cross_attn_period=1,
        mlp_act="gelu",
        param_dtype="float32",
        compute_dtype="float32",
    )
