"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2.  Jamba block: 8 layers, 1 attention (index 3),
MoE on every other layer (odd indices).  Our mixer is Mamba-2/SSD
(DESIGN.md §3 — the paper-era Mamba-1 selective scan and SSD share the
recurrence; SSD is the TPU-native chunked form)."""

from repro.models import LayerSpec, ModelConfig

SUBQUADRATIC = True  # hybrid: constant-state mixers dominate → long_500k runs

_PERIOD = tuple(
    LayerSpec(mixer=("attn" if i == 3 else "mamba"), moe=(i % 2 == 1))
    for i in range(8)
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=65536,
        layer_period=_PERIOD,
        num_experts=16,
        top_k=2,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        fsdp=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-reduced",
        family="hybrid",
        n_layers=8,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        layer_period=tuple(
            LayerSpec(mixer=("attn" if i == 3 else "mamba"), moe=(i % 2 == 1))
            for i in range(8)
        ),
        num_experts=4,
        top_k=2,
        ssm_state=16,
        ssm_head_dim=16,
        capacity_factor=8.0,
        param_dtype="float32",
        compute_dtype="float32",
    )
