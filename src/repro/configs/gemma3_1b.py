"""Gemma-3 1B — dense, 5:1 local:global attention, MQA (kv=1), 262k vocab.

[hf:google/gemma-3-1b-pt] 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144; sliding window 512 on local layers; head_dim 256 (published
config — heads × head_dim ≠ d_model in Gemma); tied embeddings."""

from repro.models import LayerSpec, ModelConfig

SUBQUADRATIC = True  # sliding-window-dominant (4 global layers of 26)

_PERIOD = (LayerSpec(attn_kind="local"),) * 5 + (LayerSpec(attn_kind="global"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab=262144,
        layer_period=_PERIOD,
        local_window=512,
        rope_theta=1_000_000.0,
        mlp_act="gelu",
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-reduced",
        family="dense",
        n_layers=6,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab=512,
        layer_period=_PERIOD,
        local_window=8,
        mlp_act="gelu",
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
    )
