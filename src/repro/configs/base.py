"""Shape cells and the architecture registry scaffolding.

Every architecture module exposes ``config()`` (the exact published dims)
and ``reduced()`` (a same-family miniature for CPU smoke tests), plus
``SUBQUADRATIC`` — whether the arch can run the ``long_500k`` cell (the
brief: skip long_500k for pure full-attention archs)."""

from __future__ import annotations

import dataclasses
from typing import Literal

Kind = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: Kind
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}
