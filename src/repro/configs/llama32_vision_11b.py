"""Llama-3.2-Vision 11B — dense GQA with cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision] 40L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256; cross-attention injected every 5th layer.  The
vision tower is a STUB per the brief: ``input_specs`` provides projected
patch embeddings (B, num_image_tokens, d_model)."""

from repro.models import ModelConfig

SUBQUADRATIC = False


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=128256,
        cross_attn_period=5,
        num_image_tokens=1600,
        rope_theta=500_000.0,
        fsdp=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-reduced",
        family="vlm",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        cross_attn_period=5,
        num_image_tokens=16,
        param_dtype="float32",
        compute_dtype="float32",
    )
