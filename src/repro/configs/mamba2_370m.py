"""Mamba-2 370M — attention-free SSD (state-space duality) stack.

[arXiv:2405.21060] 48L d_model=1024 (attn-free) d_ff=0 vocab=50280,
ssm_state=128.  Pure mixer layers (no FFN sublayer), tied embeddings."""

from repro.models import LayerSpec, ModelConfig

SUBQUADRATIC = True  # constant-size SSM state → long_500k runs


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50280,
        layer_period=(LayerSpec(mixer="mamba", ffn=False),),
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-reduced",
        family="ssm",
        n_layers=4,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=512,
        layer_period=(LayerSpec(mixer="mamba", ffn=False),),
        ssm_state=16,
        ssm_head_dim=16,
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
    )
