"""StarCoder2 15B — dense GQA transformer with RoPE.

[arXiv:2402.19173; hf] 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152."""

from repro.models import ModelConfig

SUBQUADRATIC = False


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24576,
        vocab=49152,
        mlp_act="gelu",
        fsdp=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-reduced",
        family="dense",
        n_layers=4,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        mlp_act="gelu",
        param_dtype="float32",
        compute_dtype="float32",
    )
