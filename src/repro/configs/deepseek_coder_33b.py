"""DeepSeek-Coder 33B — dense llama-arch GQA transformer.

[arXiv:2401.14196; hf] 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256."""

from repro.models import ModelConfig

SUBQUADRATIC = False


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab=32256,
        fsdp=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-reduced",
        family="dense",
        n_layers=4,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab=512,
        param_dtype="float32",
        compute_dtype="float32",
    )
