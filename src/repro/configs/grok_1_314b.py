"""Grok-1 314B — MoE transformer, 8 experts top-2 on every layer.

[hf:xai-org/grok-1] 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2."""

from repro.models import LayerSpec, ModelConfig

SUBQUADRATIC = False


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32768,
        vocab=131072,
        layer_period=(LayerSpec(moe=True),),
        num_experts=8,
        top_k=2,
        fsdp=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="grok-1-reduced",
        family="moe",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        layer_period=(LayerSpec(moe=True),),
        num_experts=4,
        top_k=2,
        capacity_factor=8.0,
        param_dtype="float32",
        compute_dtype="float32",
    )
