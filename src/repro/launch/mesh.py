"""Production mesh construction.

Importing this module never touches jax device state; call
:func:`make_production_mesh` only after the launcher has configured the
platform (the dry-run sets ``--xla_force_host_platform_device_count=512``
before any jax import)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (single pod) or 2×16×16 = 512 chips.

    Axes: ``pod`` — data-parallel across the cross-pod (DCN-class) links;
    ``data`` — batch / FSDP / ZeRO axis; ``model`` — tensor/expert
    parallel axis, kept innermost so its collectives ride the fastest ICI
    neighborhoods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """A mesh over whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"))
