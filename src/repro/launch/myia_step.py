"""Myia-compiled train/serve steps for the launch drivers (SPMD tier).

``launch/train.py --compiler myia`` and ``launch/serve.py --compiler
myia`` run an LM whose loss is written in the Myia subset and compiled
through the *whole* paper pipeline — parse → ST-AD → infer → worklist-
optimize → fuse → (SPMD partition) → lower — instead of ``jax.grad``.
Under an active mesh context the optimized+fused adjoint executes as a
per-shard program under ``shard_map`` (``repro.core.spmd``); with no mesh
the identical graph runs on the single-device tier.  That makes the e2e
step the integration point the ROADMAP asks for: the compiler IS the
execution engine, on 1 and N devices.

The model is a deliberately small tanh-MLP LM (embedding → two hidden
matmuls → vocab projection → stable log-softmax cross-entropy): every op
is a Myia primitive, and the sharding story is the classic one — batch
data-parallel, Megatron-style column/row split on the hidden pair, and a
vocab-parallel projection whose softmax reduces with ``pmax``/``psum``
over the model axis.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import api
import repro.core.primitives as P

__all__ = [
    "MyiaLMDims",
    "build_lm_loss",
    "build_lm_logits",
    "lm_in_specs",
    "init_lm_params",
    "make_myia_train_step",
]

_take = P.take
_tanh = P.tanh
_exp = P.exp
_log = P.log
_rsum = P.reduce_sum
_rmax = P.reduce_max
_onehot = P.one_hot
_F32 = np.dtype("float32")


class MyiaLMDims:
    """The tiny LM's dimensions, derived from a ModelConfig when given."""

    __slots__ = ("vocab", "d_model", "d_hidden")

    def __init__(self, vocab: int, d_model: int, d_hidden: int | None = None) -> None:
        self.vocab = int(vocab)
        self.d_model = int(d_model)
        self.d_hidden = int(d_hidden if d_hidden is not None else 4 * d_model)

    @classmethod
    def from_config(cls, cfg) -> "MyiaLMDims":
        return cls(cfg.vocab, cfg.d_model)


def build_lm_logits(dims: MyiaLMDims):
    """Myia-subset forward: tokens → logits (B, S, V)."""

    def lm_logits(emb, w1, w2, wout, tokens):
        h = _take(emb, tokens)
        h = _tanh(h @ w1)
        h = _tanh(h @ w2)
        return h @ wout

    return lm_logits


def build_lm_loss(dims: MyiaLMDims, batch: int, seq: int):
    """Myia-subset mean cross-entropy over a (batch, seq) token grid.

    The log-softmax is the numerically stable spelling (max-shifted) so
    the SPMD tier exercises both collective kinds on the vocab axis:
    ``pmax`` for the shift, ``psum`` for the normalizer.
    """
    vocab = dims.vocab
    denom = float(batch * seq)

    def lm_loss(emb, w1, w2, wout, tokens, labels):
        h = _take(emb, tokens)
        h = _tanh(h @ w1)
        h = _tanh(h @ w2)
        logits = h @ wout
        m = _rmax(logits, (2,), True)
        z = logits - m
        lse = _log(_rsum(_exp(z), (2,), True)) + m
        logp = logits - lse
        oh = _onehot(labels, vocab, _F32)
        return -_rsum(oh * logp, (0, 1, 2), False) / denom

    return lm_loss


def lm_in_specs(*, with_labels: bool = True) -> tuple:
    """Canonical sharding for the LM's arguments: batch data-parallel
    activations, Megatron column/row split on the hidden pair, a
    vocab-parallel output projection, replicated embedding table."""
    specs = (
        None,                  # emb (V, D): replicated (take indexes dim 0)
        (None, "model"),       # w1 (D, H): column-parallel
        ("model", None),       # w2 (H, D): row-parallel (psum after)
        (None, "model"),       # wout (D, V): vocab-parallel
        ("data",),             # tokens (B, S)
    )
    return specs + (("data",),) if with_labels else specs


def init_lm_params(dims: MyiaLMDims, rng: jax.Array) -> tuple:
    k0, k1, k2, k3 = jax.random.split(rng, 4)
    scale = 0.1
    return (
        jax.random.normal(k0, (dims.vocab, dims.d_model), jnp.float32) * scale,
        jax.random.normal(k1, (dims.d_model, dims.d_hidden), jnp.float32) * scale,
        jax.random.normal(k2, (dims.d_hidden, dims.d_model), jnp.float32) * scale,
        jax.random.normal(k3, (dims.d_model, dims.vocab), jnp.float32) * scale,
    )


def make_myia_train_step(
    dims: MyiaLMDims, batch: int, seq: int, lr: float, *, fuse: bool = True
):
    """(step_fn, init_fn) for ``runtime.train_loop``.

    The loss+adjoint is one Myia graph (`value_and_grad` through the ST
    transform); the SGD update is a handful of jax ops outside it.  The
    MyiaFunction carries ``lm_in_specs`` — under an active mesh context
    the step transparently switches to the sharded compilation tier.
    """
    vag = api.value_and_grad(
        build_lm_loss(dims, batch, seq),
        wrt=(0, 1, 2, 3),
        fuse=fuse,
        in_specs=lm_in_specs(),
    )

    @jax.jit
    def _update(params, grads):
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
        new_params = tuple(p - lr * g for p, g in zip(params, grads))
        return new_params, gnorm

    def step_fn(state, batch_dict):
        params = state["params"]
        loss, grads = vag(*params, batch_dict["tokens"], batch_dict["labels"])
        new_params, gnorm = _update(params, grads)
        return (
            {"params": new_params, "step": state["step"] + 1},
            {"loss": loss, "gnorm": gnorm},
        )

    def init_fn(rng=None):
        rng = jax.random.PRNGKey(0) if rng is None else rng
        return {"params": init_lm_params(dims, rng), "step": jnp.zeros((), jnp.int32)}

    step_fn.vag = vag  # introspection: tests/benchmarks reach the runner
    return step_fn, init_fn
