"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(...).compile()`` must succeed on the
production meshes for every cell, and the compiled artifact yields the
memory analysis (fits?), FLOP/byte counts and the collective schedule
that §Roofline consumes.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --cell train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # 512-chip pass

Artifacts: one JSON per cell under ``artifacts/dryrun/``.
"""

# The VERY FIRST statements — before ANY other import, jax locks the device
# count on first init (brief, MULTI-POD DRY-RUN step 0):
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCHS, cells_for, get_config, input_specs  # noqa: E402
from repro.distributed import (  # noqa: E402
    jit_decode_step,
    jit_prefill,
    jit_train_step,
    make_rules,
    make_train_state_fn,
    make_train_step,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import ModelConfig, cache_init, init_params  # noqa: E402
from repro.optim import OptConfig, make_optimizer  # noqa: E402
from repro.parallel import mesh_context  # noqa: E402

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
}

_SHAPE_RE = re.compile(r"(f32|bf16|f16|f64|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in the (post-SPMD)
    HLO.  Output bytes ≈ wire bytes per participating device for gather/
    scatter; a recognized over-estimate for all-reduce (counted 1×)."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shapes_part, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes_part):
            numel = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        numel *= int(d)
            nbytes += numel * _DTYPE_BYTES[dt]
        out[op] += nbytes
    return out


def _np_floats(d):
    return {
        k: (float(v) if isinstance(v, (int, float, np.floating)) else v)
        for k, v in d.items()
    }


def run_cell(arch: str, cell, mesh, mesh_name: str, out_dir: str) -> dict:
    cfg = get_config(arch)
    rules = make_rules(cfg)
    record: dict = {
        "arch": arch,
        "cell": cell.name,
        "kind": cell.kind,
        "mesh": mesh_name,
        "mesh_shape": list(mesh.devices.shape),
        "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
    }
    t0 = time.monotonic()
    with mesh_context(mesh, rules) as ctx:
        params_sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        in_sds = input_specs(cfg, cell)

        if cell.kind == "train":
            opt_name = "adafactor" if _param_count(params_sds) > 1e11 else "adamw"
            opt = make_optimizer(OptConfig(name=opt_name, state_dtype="float32"))
            record["optimizer"] = opt_name
            state_sds = jax.eval_shape(make_train_state_fn(cfg, opt))
            batch_sds = in_sds
            step_jit, _ = jit_train_step(cfg, opt, ctx, state_sds, batch_sds)
            lowered = step_jit.lower(state_sds, batch_sds)
        elif cell.kind == "prefill":
            tok_sds = in_sds["tokens"]
            extras = {k: v for k, v in in_sds.items() if k != "tokens"}
            max_len = cell.seq_len
            if extras:
                from repro.distributed import make_serve_fns
                from repro.distributed.sharding import param_specs
                from jax.sharding import NamedSharding

                prefill_fn, _ = make_serve_fns(cfg, max_len)
                p_sh = jax.tree.map(
                    lambda s: NamedSharding(ctx.mesh, s),
                    param_specs(cfg, params_sds, ctx),
                    is_leaf=lambda x: not isinstance(x, (dict, list)),
                )
                fn = jax.jit(lambda p, t, e: prefill_fn(p, t, e), in_shardings=(p_sh, None, None))
                lowered = fn.lower(params_sds, tok_sds, extras)
            else:
                fn, _ = jit_prefill(cfg, ctx, max_len, params_sds, {"tokens": tok_sds})
                lowered = fn.lower(params_sds, tok_sds)
        else:  # decode
            from repro.configs import cache_specs

            cache_sds = cache_specs(cfg, cell)
            fn, _, _ = jit_decode_step(
                cfg, ctx, cell.seq_len, params_sds, cache_sds, cell.global_batch
            )
            lowered = fn.lower(params_sds, cache_sds, in_sds["token"], in_sds["pos"])

        record["trace_s"] = round(time.monotonic() - t0, 2)
        t1 = time.monotonic()
        compiled = lowered.compile()
        record["compile_s"] = round(time.monotonic() - t1, 2)

        mem = compiled.memory_analysis()
        record["memory"] = {
            "argument_size_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_size_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_size_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_size_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
        cost = _cost_dict(compiled.cost_analysis())
        record["cost"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        }
        hlo = compiled.as_text()
        record["collective_bytes"] = collective_bytes(hlo)
        record["hlo_lines"] = hlo.count("\n")
        record["param_count"] = _param_count(params_sds)

    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{cell.name}__{mesh_name}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1)
    return record


def _param_count(params_sds) -> float:
    return float(sum(np.prod(l.shape) for l in jax.tree.leaves(params_sds)))


def _cost_dict(cost) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions: older
    returns a per-device LIST of dicts, newer a single dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


# ---------------------------------------------------------------------------
# Cost probe: true global HLO FLOPs/bytes by depth extrapolation.
#
# The scanned production program counts each lax.scan body ONCE in XLA's
# cost_analysis, so its flops/bytes under-report.  Per-period HLO is
# IDENTICAL at every repetition (same shapes) ⇒ cost is exactly linear in
# the period count.  We compile two shallow *unrolled single-device*
# variants (1 and 2 periods), take slope+intercept, and extrapolate to the
# full depth: exact for period-divisible depths (9 of 10 archs; gemma's
# 2-layer remainder ≈ local layers are charged at the period-average,
# <2% error, noted in EXPERIMENTS.md).
# ---------------------------------------------------------------------------


def _probe_cfg(cfg: ModelConfig, n_periods: int) -> ModelConfig:
    import dataclasses

    period = len(cfg.layer_period or (None,))
    if cfg.cross_attn_period:
        period = cfg.cross_attn_period
    enc = (
        max(1, int(cfg.n_enc_layers * n_periods * period / max(cfg.n_layers, 1)))
        if cfg.enc_dec else 0
    )
    return dataclasses.replace(
        cfg,
        n_layers=period * n_periods,
        n_enc_layers=enc,
        scan_layers=False,
    )


def _cost_of(cfg: ModelConfig, cell, kind: str) -> tuple[float, float]:
    """Compile one shallow unrolled variant on a single host device
    (global shapes, no SPMD — global flops don't depend on sharding)."""
    params_sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    in_sds = input_specs(cfg, cell)
    if kind == "train":
        opt = make_optimizer(OptConfig())
        state_sds = jax.eval_shape(make_train_state_fn(cfg, opt))
        # donate like the production step: buffer aliasing elides the
        # whole-state copy that would otherwise inflate bytes-accessed
        lowered = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,)).lower(
            state_sds, in_sds
        )
    elif kind == "prefill":
        from repro.distributed import make_serve_fns

        prefill_fn, _ = make_serve_fns(cfg, cell.seq_len)
        extras = {k: v for k, v in in_sds.items() if k != "tokens"}
        lowered = jax.jit(lambda p, t, e: prefill_fn(p, t, e)).lower(
            params_sds, in_sds["tokens"], extras
        )
    else:
        from repro.configs import cache_specs
        from repro.distributed import make_serve_fns

        cache_sds = cache_specs(cfg, cell)
        _, decode_fn = make_serve_fns(cfg, cell.seq_len)
        # donate the caches (as the production serve step does): the KV
        # update is in-place, not a full-cache copy per token
        lowered = jax.jit(decode_fn, donate_argnums=(1,)).lower(
            params_sds, cache_sds, in_sds["token"], in_sds["pos"]
        )
    c = _cost_dict(lowered.compile().cost_analysis())
    return float(c.get("flops", 0.0)), float(c.get("bytes accessed", 0.0))


def cost_probe(cfg: ModelConfig, cell) -> dict:
    period = len(cfg.layer_period or (None,))
    if cfg.cross_attn_period:
        period = cfg.cross_attn_period
    f1, b1 = _cost_of(_probe_cfg(cfg, 1), cell, cell.kind)
    f2, b2 = _cost_of(_probe_cfg(cfg, 2), cell, cell.kind)
    n_periods = cfg.n_layers / period
    flops = f1 + (f2 - f1) * (n_periods - 1)
    bytes_ = b1 + (b2 - b1) * (n_periods - 1)
    return {
        "period": period,
        "flops_1p": f1,
        "flops_2p": f2,
        "hlo_flops_global": flops,
        "hlo_bytes_global": bytes_,
    }


def run_probe(arch: str, cell, out_dir: str) -> dict:
    cfg = get_config(arch)
    t0 = time.monotonic()
    rec = {"arch": arch, "cell": cell.name, **cost_probe(cfg, cell)}
    rec["probe_s"] = round(time.monotonic() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{arch}__{cell.name}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--cell", default=None, help="one shape cell (default: all)")
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument(
        "--probe",
        action="store_true",
        help="run the depth-extrapolation cost probes instead of the SPMD dry-run",
    )
    ap.add_argument(
        "--kernel-mode",
        default="ref",
        choices=("ref", "chunked"),
        help="ref = paper-faithful naive lowering (baseline); chunked = "
        "flash/SSD-chunked lowering (the TPU kernels' XLA twins)",
    )
    args = ap.parse_args(argv)
    from repro.kernels import set_kernel_mode

    set_kernel_mode(args.kernel_mode)

    if args.probe:
        out_dir = "artifacts/probe"
        failures = []
        for arch in [args.arch] if args.arch else sorted(ARCHS):
            for cell in cells_for(arch):
                if args.cell and cell.name != args.cell:
                    continue
                path = os.path.join(out_dir, f"{arch}__{cell.name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] probe {arch} × {cell.name}")
                    continue
                try:
                    rec = run_probe(arch, cell, out_dir)
                    print(
                        f"[ok]  probe {arch} × {cell.name}: "
                        f"flops {rec['hlo_flops_global']:.4g} "
                        f"bytes {rec['hlo_bytes_global']:.4g} ({rec['probe_s']}s)"
                    )
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, cell.name, e))
                    print(f"[FAIL] probe {arch} × {cell.name}: {e}")
                    traceback.print_exc()
        print(f"\n{len(failures)} probe failures")
        return 1 if failures else 0

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else sorted(ARCHS)
    failures = []
    for arch in archs:
        for cell in cells_for(arch):
            if args.cell and cell.name != args.cell:
                continue
            for mesh_name, mesh in meshes:
                tag = f"{arch} × {cell.name} × {mesh_name}"
                path = os.path.join(args.out, f"{arch}__{cell.name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                try:
                    rec = run_cell(arch, cell, mesh, mesh_name, args.out)
                    mem_gb = rec["memory"]["argument_size_bytes"] / 2**30
                    print(
                        f"[ok]  {tag}: trace {rec['trace_s']}s compile {rec['compile_s']}s "
                        f"args/device {mem_gb:.2f} GiB flops {rec['cost']['flops']:.3g}"
                    )
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, e))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
    print(f"\n{len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
