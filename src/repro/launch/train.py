"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \\
        --reduced --steps 200 --batch 8 --seq 256

Wires together every subsystem: config → model → sharded data pipeline →
optimizer → fault-tolerant runtime loop (checkpoint/restart, straggler
watchdog) → metrics.  On this CPU container use ``--reduced``; on a real
cluster drop it and point ``--mesh`` at the production topology.

``--compiler myia`` swaps the jax-AD train step for the Myia-compiled one
(``launch/myia_step.py``): the loss+adjoint is one graph through the
paper pipeline (parse → ST-AD → infer → optimize → fuse → lower), and
under ``--data-mesh``/``--model-mesh`` > 1 it executes as a per-shard
program under ``shard_map`` (the SPMD tier, ``repro.core.spmd``).  To
simulate a mesh on CPU, force host devices before launch:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
    PYTHONPATH=src python -m repro.launch.train --compiler myia \\
        --reduced --data-mesh 2 --model-mesh 2 --steps 20
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.data import DataConfig, SyntheticLM
from repro.distributed import (
    jit_train_step,
    make_rules,
    make_train_state_fn,
    make_train_step,
)
from repro.launch.mesh import make_local_mesh
from repro.optim import OptConfig, make_optimizer
from repro.parallel import mesh_context
from repro.runtime import TrainLoopConfig, train_loop


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=("adamw", "adafactor"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-mesh", type=int, default=1, help="data axis size (local devices)")
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument(
        "--compiler",
        default="jax",
        choices=("jax", "myia"),
        help="jax: production jax-AD step; myia: the paper pipeline "
        "(optimized+fused graph, shard_map under a mesh)",
    )
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    ds = SyntheticLM(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    )

    use_mesh = args.data_mesh * args.model_mesh > 1
    mesh = make_local_mesh(args.data_mesh, args.model_mesh) if use_mesh else None

    if args.compiler == "myia":
        return _train_myia(args, cfg, ds, mesh)

    opt = make_optimizer(
        OptConfig(name=args.optimizer, lr=args.lr, warmup_steps=args.steps // 10,
                  total_steps=args.steps)
    )
    with mesh_context(mesh, make_rules(cfg)) as ctx:
        init_fn = make_train_state_fn(cfg, opt)
        if ctx is not None:
            state_sds = jax.eval_shape(init_fn)
            batch_sds = {
                k: jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)
                for k in ("tokens", "labels")
            }
            step_jit, st_sh = jit_train_step(cfg, opt, ctx, state_sds, batch_sds)
            shardings = st_sh
        else:
            step_jit = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
            shardings = None

        t_start = time.monotonic()

        def on_step(step, metrics):
            if step % 10 == 0:
                print(
                    f"step {step:5d} loss {float(metrics['loss']):.4f} "
                    f"gnorm {float(metrics['gnorm']):.3f} "
                    f"({(time.monotonic()-t_start):.1f}s)"
                )

        result = train_loop(
            TrainLoopConfig(
                total_steps=args.steps,
                checkpoint_every=args.ckpt_every,
                checkpoint_dir=args.ckpt_dir,
            ),
            step_jit,
            init_fn,
            lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()},
            shardings=shardings,
            on_step=on_step,
        )

    first = np.mean(result.losses[:10]) if len(result.losses) >= 10 else result.losses[0]
    last = np.mean(result.losses[-10:])
    print(
        f"\ndone: {result.final_step} steps, loss {first:.4f} → {last:.4f}, "
        f"{result.restarts} restarts, {len(result.straggler_events)} straggler flags"
    )
    return 0


def _train_myia(args, cfg, ds, mesh) -> int:
    """The Myia-compiled e2e step: same train_loop, same checkpointing —
    the loss+adjoint runs through the paper pipeline, sharded under an
    active mesh, on the single-device tier otherwise."""
    from repro.launch.myia_step import MyiaLMDims, make_myia_train_step

    if args.optimizer != "adamw":  # adamw is the argparse default
        print(
            f"warning: --compiler myia uses plain SGD; --optimizer {args.optimizer} ignored"
        )

    dims = MyiaLMDims.from_config(cfg)
    step_fn, init_fn = make_myia_train_step(
        dims, args.batch, args.seq, lr=args.lr, fuse=True
    )

    t_start = time.monotonic()

    def on_step(step, metrics):
        if step % 10 == 0:
            print(
                f"step {step:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['gnorm']):.3f} "
                f"({(time.monotonic()-t_start):.1f}s)"
            )

    with mesh_context(mesh, {}):
        result = train_loop(
            TrainLoopConfig(
                total_steps=args.steps,
                checkpoint_every=args.ckpt_every,
                checkpoint_dir=args.ckpt_dir,
            ),
            step_fn,
            init_fn,
            lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()},
            on_step=on_step,
        )

    tier = "shard_map" if mesh is not None else "single-device"
    first = result.losses[0]
    last = np.mean(result.losses[-10:])
    print(
        f"\ndone [myia/{tier}]: {result.final_step} steps, "
        f"loss {first:.4f} → {last:.4f}, {result.restarts} restarts"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
