"""Batched serving driver: prefill + decode with KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \\
        --batch 4 --prompt-len 32 --gen 32

Runs a continuous-batch of requests through prefill, then step-decodes
with greedy sampling.  The same ``decode_step`` is what the decode_32k /
long_500k dry-run cells lower at production shapes.

``--compiler myia`` serves the Myia-compiled LM through the serving
runtime (``repro.serve``): requests are admitted into power-of-two shape
buckets, the KV/prefix cache is threaded *functionally* through the
compiled decode graph as a tuple carry, and compiled programs persist in
the AOT program cache (``--cache-dir``) — a warm process restart replays
the serialized executables with zero recompilation.  Decode is O(T):
one single-token specialization per bucket, not one per generated
length.  ``--full-prefix`` keeps the old O(T²) full-prefix-recompute
path (one specialization per length) as the differential oracle;
``--check-oracle`` runs both and asserts the token streams are
identical.  Under ``--data-mesh``/``--model-mesh`` > 1 the full-prefix
path runs the train-side LM as a per-shard program under ``shard_map``
(the SPMD tier), unchanged.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import decode_step, init_params, prefill


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument(
        "--compiler",
        default="jax",
        choices=("jax", "myia"),
        help="jax: cached prefill/decode; myia: the serving runtime over "
        "the optimized+fused graph (bucketed continuous batching + AOT "
        "program cache); add --full-prefix for the per-length oracle path",
    )
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument(
        "--full-prefix",
        action="store_true",
        help="myia: serve by full-prefix recompute (the pre-runtime path; "
        "one specialization per generated length) instead of the engine",
    )
    ap.add_argument(
        "--check-oracle",
        action="store_true",
        help="myia: run the engine AND the full-prefix oracle, assert "
        "identical token streams",
    )
    ap.add_argument("--slots", type=int, default=4, help="myia: engine batch lanes")
    ap.add_argument("--min-bucket", type=int, default=32)
    ap.add_argument(
        "--cache-dir",
        default="artifacts/progcache",
        help="myia: persistent AOT program cache directory ('' disables)",
    )
    ap.add_argument(
        "--deadline",
        type=float,
        default=None,
        help="myia: per-request deadline in seconds (requests past it "
        "finish with status 'timeout', partial tokens kept)",
    )
    ap.add_argument(
        "--max-queue",
        type=int,
        default=None,
        help="myia: admission-control bound on queued requests; submits "
        "past it are rejected with reason 'queue_full' instead of queued",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="OUT.json",
        help="myia: record compile + per-request lifecycle spans and write "
        "a Chrome trace-event file (open in https://ui.perfetto.dev); also "
        "prints one telemetry summary line per request",
    )
    ap.add_argument(
        "--metrics-out",
        default=None,
        metavar="FILE",
        help="myia: after the run, write the engine's metrics registry "
        "plus the serve/cache stats snapshot as Prometheus text exposition "
        "(scrape-file / node_exporter textfile-collector format)",
    )
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)

    if args.compiler == "myia":
        if args.full_prefix or args.data_mesh * args.model_mesh > 1:
            return _serve_myia_full_prefix(args, cfg)
        return _serve_myia_engine(args, cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    extras = {}
    if cfg.enc_dec:
        extras["enc_frames"] = jnp.asarray(
            rng.standard_normal((args.batch, 64, cfg.d_model)), cfg.cdtype
        )
    if cfg.cross_attn_period and not cfg.enc_dec:
        extras["image_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_image_tokens, cfg.d_model)), cfg.cdtype
        )

    t0 = time.monotonic()
    prefill_jit = jax.jit(lambda p, t: prefill(cfg, p, t, max_len, batch_extras=extras))
    logits, caches = prefill_jit(params, prompts)
    logits.block_until_ready()
    t_prefill = time.monotonic() - t0

    decode_jit = jax.jit(lambda p, tok, pos, c: decode_step(cfg, p, tok, pos, c))
    out_tokens = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t1 = time.monotonic()
    for i in range(args.gen):
        out_tokens.append(tok)
        logits, caches = decode_step_jit_call(decode_jit, params, tok, args.prompt_len + i, caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tok.block_until_ready()
    t_decode = time.monotonic() - t1

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"prefill: {args.batch}×{args.prompt_len} tokens in {t_prefill:.3f}s")
    print(
        f"decode:  {args.gen} steps × batch {args.batch} in {t_decode:.3f}s "
        f"({args.gen * args.batch / max(t_decode, 1e-9):.1f} tok/s)"
    )
    print("sample generations (token ids):")
    for row in gen[:2]:
        print("  ", row[:16].tolist())
    return 0


def decode_step_jit_call(decode_jit, params, tok, pos, caches):
    return decode_jit(params, tok, jnp.int32(pos), caches)


def _serve_myia_engine(args, cfg) -> int:
    """The serving runtime: bucketed continuous batching, incremental
    decode (tuple-carried KV cache), persistent AOT program cache."""
    from repro.core.jax_backend import ProgramCache
    from repro.obs import trace as obs_trace
    from repro.serve import ServeEngine, ServeLMDims, init_serve_params, oracle_generate
    from repro.serve.engine import request_telemetry

    tracer = obs_trace.Tracer() if args.trace else None
    dims = ServeLMDims.from_config(cfg)
    params = init_serve_params(dims, jax.random.PRNGKey(0))
    cache = ProgramCache(args.cache_dir) if args.cache_dir else None
    engine = ServeEngine(
        dims,
        params,
        n_slots=args.slots,
        min_bucket=args.min_bucket,
        program_cache=cache,
        default_deadline_s=args.deadline,
        max_queue=args.max_queue,
        trace=tracer,
    )

    rng = np.random.default_rng(0)
    submitted = []
    for _ in range(args.batch):
        prompt = rng.integers(0, dims.vocab, args.prompt_len).tolist()
        submitted.append((engine.submit(prompt, args.gen), prompt))

    t0 = time.monotonic()
    results = engine.run()
    wall = time.monotonic() - t0

    stats = engine.stats()
    ttfts = [r["ttft_s"] for r in results.values() if r["ttft_s"] is not None]
    ttft_txt = f"ttft {min(ttfts) * 1e3:.1f}ms" if ttfts else "ttft n/a"
    print(
        f"[myia/engine] {args.batch} reqs × (prompt {args.prompt_len} + gen "
        f"{args.gen}) in {wall:.3f}s ({stats['tokens_generated'] / max(wall, 1e-9):.1f} tok/s, "
        f"{ttft_txt})"
    )
    print(
        f"[myia/engine] buckets {stats['buckets_in_use']}, compilations "
        f"{stats['compilations']} (floor {stats['compilation_floor']})"
    )
    print(
        f"[myia/engine] statuses {stats['statuses']}, rejected "
        f"{stats['rejected']}, queue peak {stats['queue_peak']}"
    )
    if cache is not None:
        cs = cache.stats.as_dict()
        print(f"[myia/engine] program cache: {cs}")
        degraded = {
            k: cs[k]
            for k in ("corrupt_entries", "quarantined", "compile_retries", "vm_fallbacks")
            if cs.get(k)
        }
        if degraded:
            print(f"[myia/engine] DEGRADED-MODE events: {degraded}")
    print("sample generations (token ids):")
    for rid, _prompt in submitted[:2]:
        print("  ", results[rid]["tokens"][:16])

    if tracer is not None:
        # one line per request, reconstructed purely from lifecycle spans
        tel = request_telemetry(tracer)
        for rid, _prompt in submitted:
            t = tel.get(rid)
            if t is None:
                continue
            n_tok = len(results[rid]["tokens"])
            tok_s = (
                n_tok / (t["gen_ms"] / 1e3)
                if t["gen_ms"] and n_tok
                else None
            )
            fmt = lambda v, suf="": "n/a" if v is None else f"{v:.1f}{suf}"
            print(
                f"[myia/telemetry] rid={rid} status={t['status']} "
                f"bucket={t['bucket']} ttft={fmt(t['ttft_ms'], 'ms')} "
                f"queue={fmt(t['queue_ms'], 'ms')} tok/s={fmt(tok_s)}"
            )
        tracer.write_chrome_trace(args.trace)
        print(
            f"[myia/telemetry] wrote {len(tracer.events)} spans to "
            f"{args.trace} (open in https://ui.perfetto.dev)"
        )

    if args.metrics_out:
        from repro.obs import snapshot, to_prometheus

        text = to_prometheus(
            engine.telemetry,
            extra=snapshot(
                serve={k: v for k, v in stats.items() if k != "telemetry"},
                cache=cache.stats if cache is not None else None,
            ),
        )
        with open(args.metrics_out, "w", encoding="utf-8") as f:
            f.write(text)
        print(
            f"[myia/metrics] wrote {len(text.splitlines())} exposition "
            f"lines to {args.metrics_out}"
        )

    if args.check_oracle:
        fns: dict = {}
        for rid, prompt in submitted:
            if results[rid]["status"] != "ok":
                continue  # timeout/failed streams are partial by contract
            want = oracle_generate(dims, params, prompt, args.gen, fns=fns)
            got = results[rid]["tokens"]
            assert got == want, f"engine diverged from full-prefix oracle on rid {rid}"
        print(f"[myia/engine] oracle check passed ({len(submitted)} requests)")
    return 0


def _serve_myia_full_prefix(args, cfg) -> int:
    """Greedy decode off the Myia-compiled LM forward (SPMD tier when a
    mesh is active).  Batch stays data-parallel; the vocab projection is
    model-parallel — the same specs the train step uses.  Decode
    recomputes the full prefix per step (one specialization per length):
    this is the serving runtime's differential oracle."""
    from repro.launch.mesh import make_local_mesh
    from repro.launch.myia_step import (
        MyiaLMDims,
        build_lm_logits,
        init_lm_params,
        lm_in_specs,
    )
    from repro.core import api
    from repro.parallel import mesh_context

    dims = MyiaLMDims.from_config(cfg)
    params = init_lm_params(dims, jax.random.PRNGKey(0))
    logits_fn = api.myia(
        build_lm_logits(dims), fuse=True, in_specs=lm_in_specs(with_labels=False)
    )

    rng = np.random.default_rng(0)
    tokens = jnp.asarray(
        rng.integers(0, dims.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    use_mesh = args.data_mesh * args.model_mesh > 1
    mesh = make_local_mesh(args.data_mesh, args.model_mesh) if use_mesh else None

    with mesh_context(mesh, {}):
        t0 = time.monotonic()
        logits = logits_fn(*params, tokens)
        jax.block_until_ready(logits)
        t_prefill = time.monotonic() - t0
        out_tokens = []
        t1 = time.monotonic()
        for i in range(args.gen):
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            out_tokens.append(tok)
            if i + 1 == args.gen:
                break  # the last sample needs no further forward pass
            tokens = jnp.concatenate([tokens, tok[:, None]], axis=1)
            logits = logits_fn(*params, tokens)
        if out_tokens:
            jax.block_until_ready(out_tokens[-1])
        t_decode = time.monotonic() - t1

    tier = "shard_map" if mesh is not None else "single-device"
    print(f"[myia/{tier}] prefill: {args.batch}×{args.prompt_len} in {t_prefill:.3f}s")
    print(
        f"[myia/{tier}] decode: {args.gen} steps × batch {args.batch} in "
        f"{t_decode:.3f}s (full-prefix recompute, one specialization per length)"
    )
    if out_tokens:
        gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
        print("sample generations (token ids):")
        for row in gen[:2]:
            print("  ", row[:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
