"""Batched serving driver: prefill + decode with KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \\
        --batch 4 --prompt-len 32 --gen 32

Runs a continuous-batch of requests through prefill, then step-decodes
with greedy sampling.  The same ``decode_step`` is what the decode_32k /
long_500k dry-run cells lower at production shapes.

``--compiler myia`` serves the Myia-compiled LM instead: logits come from
the optimized+fused graph (``launch/myia_step.build_lm_logits``), and
under ``--data-mesh``/``--model-mesh`` > 1 each forward runs as a
per-shard program under ``shard_map`` (the SPMD tier).  Decode recomputes
the full prefix per step (no KV cache in the Myia subset yet), so each
generated length is its own specialization — keep ``--gen`` small.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import decode_step, init_params, prefill


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument(
        "--compiler",
        default="jax",
        choices=("jax", "myia"),
        help="jax: cached prefill/decode; myia: the optimized+fused graph, "
        "sharded under a mesh (full-prefix recompute per step)",
    )
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)

    if args.compiler == "myia":
        return _serve_myia(args, cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    extras = {}
    if cfg.enc_dec:
        extras["enc_frames"] = jnp.asarray(
            rng.standard_normal((args.batch, 64, cfg.d_model)), cfg.cdtype
        )
    if cfg.cross_attn_period and not cfg.enc_dec:
        extras["image_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_image_tokens, cfg.d_model)), cfg.cdtype
        )

    t0 = time.monotonic()
    prefill_jit = jax.jit(lambda p, t: prefill(cfg, p, t, max_len, batch_extras=extras))
    logits, caches = prefill_jit(params, prompts)
    logits.block_until_ready()
    t_prefill = time.monotonic() - t0

    decode_jit = jax.jit(lambda p, tok, pos, c: decode_step(cfg, p, tok, pos, c))
    out_tokens = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t1 = time.monotonic()
    for i in range(args.gen):
        out_tokens.append(tok)
        logits, caches = decode_step_jit_call(decode_jit, params, tok, args.prompt_len + i, caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tok.block_until_ready()
    t_decode = time.monotonic() - t1

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"prefill: {args.batch}×{args.prompt_len} tokens in {t_prefill:.3f}s")
    print(
        f"decode:  {args.gen} steps × batch {args.batch} in {t_decode:.3f}s "
        f"({args.gen * args.batch / max(t_decode, 1e-9):.1f} tok/s)"
    )
    print("sample generations (token ids):")
    for row in gen[:2]:
        print("  ", row[:16].tolist())
    return 0


def decode_step_jit_call(decode_jit, params, tok, pos, caches):
    return decode_jit(params, tok, jnp.int32(pos), caches)


def _serve_myia(args, cfg) -> int:
    """Greedy decode off the Myia-compiled LM forward (SPMD tier when a
    mesh is active).  Batch stays data-parallel; the vocab projection is
    model-parallel — the same specs the train step uses."""
    from repro.launch.mesh import make_local_mesh
    from repro.launch.myia_step import (
        MyiaLMDims,
        build_lm_logits,
        init_lm_params,
        lm_in_specs,
    )
    from repro.core import api
    from repro.parallel import mesh_context

    dims = MyiaLMDims.from_config(cfg)
    params = init_lm_params(dims, jax.random.PRNGKey(0))
    logits_fn = api.myia(
        build_lm_logits(dims), fuse=True, in_specs=lm_in_specs(with_labels=False)
    )

    rng = np.random.default_rng(0)
    tokens = jnp.asarray(
        rng.integers(0, dims.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    use_mesh = args.data_mesh * args.model_mesh > 1
    mesh = make_local_mesh(args.data_mesh, args.model_mesh) if use_mesh else None

    with mesh_context(mesh, {}):
        t0 = time.monotonic()
        logits = logits_fn(*params, tokens)
        jax.block_until_ready(logits)
        t_prefill = time.monotonic() - t0
        out_tokens = []
        t1 = time.monotonic()
        for i in range(args.gen):
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            out_tokens.append(tok)
            if i + 1 == args.gen:
                break  # the last sample needs no further forward pass
            tokens = jnp.concatenate([tokens, tok[:, None]], axis=1)
            logits = logits_fn(*params, tokens)
        if out_tokens:
            jax.block_until_ready(out_tokens[-1])
        t_decode = time.monotonic() - t1

    tier = "shard_map" if mesh is not None else "single-device"
    print(f"[myia/{tier}] prefill: {args.batch}×{args.prompt_len} in {t_prefill:.3f}s")
    print(
        f"[myia/{tier}] decode: {args.gen} steps × batch {args.batch} in "
        f"{t_decode:.3f}s (full-prefix recompute, one specialization per length)"
    )
    if out_tokens:
        gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
        print("sample generations (token ids):")
        for row in gen[:2]:
            print("  ", row[:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
