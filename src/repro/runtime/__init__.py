"""Training runtime: fault tolerance, straggler mitigation, elastic resume.

The loop is deliberately boring: build step fn → restore-if-possible →
step/checkpoint/watchdog forever.  Every failure path is exercised by
tests (tests/substrate):

* **Crash-restart**: any exception in a step triggers restore from the
  newest committed checkpoint and replay (data is a pure function of the
  step index, so replay is bit-exact).
* **Straggler watchdog**: per-step deadline derived from a running median;
  steps that exceed ``deadline_factor × median`` are logged and counted —
  on real clusters this feeds the controller that evicts the slow host;
  here the hook is a callback.
* **Elastic resume**: ``restore`` re-shards onto whatever mesh is active,
  so a job restarted with a different pod count continues from the same
  step (tested by saving under one mesh and restoring under another).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

__all__ = ["TrainLoopConfig", "StragglerWatchdog", "train_loop", "TrainResult"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    max_restarts: int = 3
    deadline_factor: float = 5.0  # straggler threshold × median step time


class StragglerWatchdog:
    """Flags steps slower than ``factor ×`` the running median."""

    def __init__(self, factor: float = 5.0, warmup: int = 5) -> None:
        self.factor = factor
        self.warmup = warmup
        self.times: list[float] = []
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = False
        if len(self.times) >= self.warmup:
            med = float(np.median(self.times[-50:]))
            if dt > self.factor * med:
                self.flagged.append((step, dt))
                slow = True
        self.times.append(dt)
        return slow


@dataclasses.dataclass
class TrainResult:
    final_step: int
    losses: list[float]
    restarts: int
    straggler_events: list[tuple[int, float]]
    state: Any


def train_loop(
    cfg: TrainLoopConfig,
    step_fn: Callable[[Any, Any], tuple[Any, dict]],
    init_state: Callable[[], Any],
    batch_fn: Callable[[int], Any],
    *,
    shardings: Any | None = None,
    on_step: Callable[[int, dict], None] | None = None,
    fault_injector: Callable[[int], None] | None = None,
) -> TrainResult:
    """Run the fault-tolerant loop.

    ``step_fn(state, batch) -> (state, metrics)`` (jitted by the caller);
    ``init_state()`` builds fresh state; ``batch_fn(step)`` is the pure
    data function; ``fault_injector(step)`` may raise to simulate crashes.
    """
    mgr = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep)
    watchdog = StragglerWatchdog(cfg.deadline_factor)
    losses: list[float] = []
    restarts = 0

    def start_or_resume():
        state = init_state()
        if mgr.has_checkpoint():
            step, state = mgr.restore_latest(state, shardings)
            return step + 1, state
        return 0, state

    step, state = start_or_resume()
    while step < cfg.total_steps:
        try:
            if fault_injector is not None:
                fault_injector(step)
            t0 = time.monotonic()
            state, metrics = step_fn(state, batch_fn(step))
            loss = metrics.get("loss")
            if loss is not None:
                loss = float(jax.device_get(loss))
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}: {loss}")
                losses.append(loss)
            watchdog.observe(step, time.monotonic() - t0)
            if on_step is not None:
                on_step(step, metrics)
            if cfg.checkpoint_every and (step + 1) % cfg.checkpoint_every == 0:
                mgr.save(step, state)
            step += 1
        except KeyboardInterrupt:  # pragma: no cover
            raise
        except Exception:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            step, state = start_or_resume()
    mgr.save(step - 1, state, blocking=True)
    return TrainResult(step, losses, restarts, watchdog.flagged, state)
