"""Fault-tolerant checkpointing.

* **Atomic**: leaves are written into ``step_<n>.tmp/`` and the directory
  is committed with a single ``rename`` after the manifest is fsynced — a
  crash mid-write can never yield a half checkpoint that restore would
  pick up.
* **Async**: ``save_async`` snapshots device arrays to host
  (``jax.device_get``) and hands serialization to a background thread —
  the train loop resumes immediately (one step of staging overlap).
* **Keep-k** retention, **auto-resume** from the newest valid manifest.
* **Elastic restore**: leaves are loaded host-side and ``device_put`` with
  *target* shardings, so a checkpoint taken on one mesh restores onto any
  other mesh shape (re-sharding happens in ``device_put``).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "CheckpointManager"]

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(directory: str, step: int, tree: Any) -> str:
    """Synchronous atomic save.  Returns the committed directory."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    return _write(directory, step, host_tree)


def _write(directory: str, step: int, host_tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, treedef = _flatten_with_paths(host_tree)
    manifest = {"step": step, "leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), leaf)
        manifest["leaves"].append(
            {"path": p, "file": fname, "shape": list(np.shape(leaf)), "dtype": str(leaf.dtype)}
        )
    manifest["treedef"] = jax.tree_util.tree_structure(host_tree).serialize_using_proto().hex()
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # the commit point
    return final


def latest_step(directory: str) -> int | None:
    """Newest step with a committed (valid-manifest) checkpoint."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            mpath = os.path.join(directory, name, _MANIFEST)
            if os.path.exists(mpath):
                try:
                    with open(mpath) as f:
                        json.load(f)
                    steps.append(int(name[len("step_"):]))
                except (json.JSONDecodeError, ValueError):  # torn write: skip
                    continue
    return max(steps) if steps else None


def restore(
    directory: str,
    step: int | None = None,
    *,
    target: Any | None = None,
    shardings: Any | None = None,
) -> tuple[int, Any]:
    """Restore (step, tree).  ``target`` (a pytree of arrays or
    ShapeDtypeStructs) provides the structure; ``shardings`` (same
    structure, NamedShardings) re-shards onto the current mesh — elastic
    restore across different mesh shapes."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    leaves = [np.load(os.path.join(d, e["file"])) for e in manifest["leaves"]]
    if target is None:
        raise ValueError("restore requires a target pytree for structure")
    treedef = jax.tree_util.tree_structure(target)
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(shardings)
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, shard_leaves)]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return step, tree


class CheckpointManager:
    """Async save + keep-k retention + auto-resume."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        if self._error is not None:  # surface background failures
            raise self._error
        self.wait()  # at most one in-flight save
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                _write(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:  # pragma: no cover
                self._error = e

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            raise self._error

    def _gc(self) -> None:
        steps = sorted(
            int(n[len("step_"):])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def restore_latest(self, target: Any, shardings: Any | None = None):
        self.wait()
        return restore(self.directory, target=target, shardings=shardings)

    def has_checkpoint(self) -> bool:
        return latest_step(self.directory) is not None
