"""Distributed execution: jitted train/serve steps with full sharding.

``make_train_step``/``make_serve_fns`` close over a ModelConfig and build
the pure step functions; ``jit_train_step`` etc. attach in/out shardings
derived from :mod:`repro.distributed.sharding` under an active
MeshContext and donate the state buffers.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import ModelConfig, decode_step, init_params, loss_fn, prefill
from repro.optim import Optimizer
from repro.parallel import MeshContext
from .sharding import batch_specs, make_rules, param_specs, tree_specs

__all__ = [
    "make_train_state_fn",
    "make_train_step",
    "make_serve_fns",
    "state_shardings",
    "jit_train_step",
    "jit_prefill",
    "jit_decode_step",
    "make_rules",
]


def make_train_state_fn(cfg: ModelConfig, opt: Optimizer):
    def init_state(rng=None):
        rng = jax.random.PRNGKey(0) if rng is None else rng
        params = init_params(cfg, rng)
        return {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}

    return init_state


def make_train_step(cfg: ModelConfig, opt: Optimizer):
    def train_step(state, batch):
        def lossf(p):
            loss, metrics = loss_fn(cfg, p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(lossf, has_aux=True)(state["params"])
        new_params, new_opt, opt_metrics = opt.update(
            grads, state["opt"], state["params"], state["step"]
        )
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def make_serve_fns(cfg: ModelConfig, max_len: int):
    def prefill_fn(params, tokens, extras=None):
        return prefill(cfg, params, tokens, max_len, batch_extras=extras)

    def decode_fn(params, caches, token, pos):
        logits, new_caches = decode_step(cfg, params, token, pos, caches)
        return logits, new_caches

    return prefill_fn, decode_fn


# ---------------------------------------------------------------------------
# Sharded jit wrappers
# ---------------------------------------------------------------------------


def state_shardings(cfg: ModelConfig, ctx: MeshContext, state: Any) -> Any:
    """NamedShardings for a full train state (params + optimizer + step)."""
    pspecs = param_specs(cfg, state["params"], ctx)
    ospecs = tree_specs(pspecs, state["opt"], state["params"])
    specs = {"params": pspecs, "opt": ospecs, "step": P()}
    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def jit_train_step(cfg: ModelConfig, opt: Optimizer, ctx: MeshContext, state_sds, batch_sds):
    """AOT-shardable train step: returns (jitted_fn, state_shardings)."""
    step = make_train_step(cfg, opt)
    st_sh = state_shardings(cfg, ctx, state_sds)
    b_sh = jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s),
        batch_specs(ctx, batch_sds),
        is_leaf=lambda x: isinstance(x, P),
    )
    return (
        jax.jit(
            step,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
        ),
        st_sh,
    )


def jit_prefill(cfg: ModelConfig, ctx: MeshContext, max_len: int, params_sds, batch_sds):
    prefill_fn, _ = make_serve_fns(cfg, max_len)
    p_sh = jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s),
        param_specs(cfg, params_sds, ctx),
        is_leaf=lambda x: isinstance(x, P),
    )
    b_sh = jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s),
        batch_specs(ctx, batch_sds),
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.jit(prefill_fn, in_shardings=(p_sh, b_sh["tokens"]), static_argnums=()), p_sh


def cache_shardings(cfg: ModelConfig, ctx: MeshContext, cache_sds) -> Any:
    """Decode caches: KV on (batch, kv_heads, seq-or-kv_seq, head_dim);
    conv/ssm state on batch — mirrors the constrain() calls in the model.
    Resolved structurally: rank-4 f32/bf16 leaves with head_dim last are KV."""

    def one(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        nd = len(leaf.shape)
        if "ssm" in keys:
            base = ("batch", "ssm_heads", None, None)
        elif "conv" in keys:
            base = ("batch", None, "ssm_proj")
        elif "cross" in keys:
            base = ("batch", "kv_heads", None, "head_dim")
        else:  # self-attention KV; big caches shard on the sequence dim
            big = nd >= 4 and leaf.shape[-2] > 8192
            base = ("batch", "kv_heads", "kv_seq" if big else None, "head_dim")
        # right-align under scan-stacking dims; divisibility-checked
        aligned = (None,) * (nd - len(base)) + base[-nd:] if nd < len(base) else (
            (None,) * (nd - len(base)) + base
        )
        return ctx.spec(aligned, leaf.shape)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_sds)
    return jax.tree_util.tree_unflatten(
        treedef, [NamedSharding(ctx.mesh, one(p, l)) for p, l in flat]
    )


def jit_decode_step(
    cfg: ModelConfig, ctx: MeshContext, max_len: int, params_sds, cache_sds, batch: int
):
    _, decode_fn = make_serve_fns(cfg, max_len)
    p_sh = jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s),
        param_specs(cfg, params_sds, ctx),
        is_leaf=lambda x: isinstance(x, P),
    )
    c_sh = cache_shardings(cfg, ctx, cache_sds)
    tok_sh = NamedSharding(ctx.mesh, ctx.spec(("batch",), (batch,)))
    pos_sh = NamedSharding(ctx.mesh, P())
    return (
        jax.jit(
            decode_fn,
            in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(1,),
        ),
        p_sh,
        c_sh,
    )
