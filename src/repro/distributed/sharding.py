"""Parameter/state sharding: pytree-path → logical axes → PartitionSpec.

The resolver walks the parameter pytree produced by ``repro.models`` and
assigns *logical* axes by path (wq → ("embed", "heads", "head_dim"), MoE
wi → ("experts", "embed", "expert_mlp"), …), then maps logical → physical
through the active mesh rules with a **divisibility check**: a dim that
does not divide by its mesh axis falls back to replication (e.g. kv=8
heads on a 16-way model axis — Megatron-style KV replication).

MoE fallback: when ``num_experts`` does not divide the model axis (grok:
8e on 16 chips) the expert-parallel axis moves to the expert FFN width
instead, so the big tensors stay sharded.

ZeRO/FSDP: optimizer state mirrors parameters, so ``tree_specs`` applied
to the optimizer pytree shards it identically; with ``cfg.fsdp`` the
``embed_fsdp`` logical axis additionally shards the embed dim of the big
matrices over the data axis.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import ModelConfig
from repro.parallel import MeshContext

__all__ = ["param_specs", "param_shardings", "tree_specs", "batch_specs", "make_rules"]


def make_rules(cfg: ModelConfig) -> dict:
    """Config-dependent logical-axis rules layered over the defaults."""
    return {
        "embed_fsdp": "data" if cfg.fsdp else None,
        # when experts don't divide the model axis, expert_mlp picks it up
        "expert_mlp": None,
        "experts": "model",
    }


def _keyname(k: Any) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _base_axes(cfg: ModelConfig, keys: list[str], ndim: int) -> tuple:
    """Logical axes (right-aligned) for a parameter path."""
    if keys[0] == "encoder":
        keys = keys[1:]
    head = keys[0]
    if head == "embed":
        return ("vocab", "embed_fsdp")
    if head == "lm_head":
        return ("embed_fsdp", "vocab")
    if head == "final_norm":
        return (None,)
    # segments/<i>/layers/<j>/<section>/.../<leaf>
    assert head == "segments", keys
    section = keys[4]
    leaf = keys[-1]
    if section in ("norm1", "norm2", "norm_x"):
        return (None,)
    if section in ("mixer", "cross"):
        if leaf == "wq":
            return ("embed_fsdp", "heads", "head_dim")
        if leaf in ("wk", "wv"):
            return ("embed_fsdp", "kv_heads", "head_dim")
        if leaf == "wo":
            return ("heads", "head_dim", "embed_fsdp")
        # mamba mixer
        if leaf == "in_proj":
            return ("embed_fsdp", "ssm_proj")
        if leaf == "out_proj":
            return ("ssm_proj", "embed_fsdp")
        if leaf == "conv_w":
            return (None, "ssm_proj")
        if leaf in ("A_log", "D_skip", "dt_bias"):
            return ("ssm_heads",)
        if leaf == "gate_norm":
            return (None,)
        raise KeyError(f"no rule for mixer leaf {leaf!r} ({keys})")
    if section == "ffn":
        if leaf == "router":
            return (None, None)
        moe = "shared" not in keys and cfg.num_experts > 0 and _is_moe_leaf(keys, ndim)
        if moe:
            if leaf in ("wi", "wg"):
                return ("experts", "embed_fsdp", "expert_mlp")
            if leaf == "wo":
                return ("experts", "expert_mlp", "embed_fsdp")
        if leaf in ("wi", "wg"):
            return ("embed_fsdp", "mlp")
        if leaf == "wo":
            return ("mlp", "embed_fsdp")
        raise KeyError(f"no rule for ffn leaf {leaf!r} ({keys})")
    raise KeyError(f"no rule for path {keys}")


def _is_moe_leaf(keys: list[str], ndim: int) -> bool:
    # dense mlp leaves under a moe layer live at ffn/shared/...
    return "shared" not in keys


def _physical(
    ctx: MeshContext, logical: Sequence[str | None], shape: tuple[int, ...]
) -> P:
    """Map logical axes → mesh axes with divisibility fallback; guarantees
    no two dims claim the same mesh axis."""
    used: set[str] = set()
    out: list = []
    sizes = dict(ctx.mesh.shape)
    for dim, name in zip(shape, logical):
        phys = None if name is None else ctx.rules.get(name)
        if phys is None:
            out.append(None)
            continue
        cand = phys if isinstance(phys, tuple) else (phys,)
        cand = tuple(a for a in cand if a in sizes and a not in used)
        total = int(np.prod([sizes[a] for a in cand])) if cand else 1
        if cand and dim % total == 0:
            out.append(cand if len(cand) > 1 else cand[0])
            used.update(cand)
        else:
            out.append(None)  # replicate: not divisible / axis taken
    return P(*out)


def _moe_fallback(cfg: ModelConfig, ctx: MeshContext, logical: tuple, shape: tuple) -> tuple:
    """grok-style: 8 experts on a 16-way model axis — move the model axis
    from the expert dim to the expert-FFN width."""
    if "experts" not in logical:
        return logical
    sizes = dict(ctx.mesh.shape)
    model = ctx.rules.get("experts")
    if model is None or model not in sizes:
        return logical
    e_dim = shape[len(shape) - len(logical) + logical.index("experts")]
    if e_dim % sizes[model] == 0:
        return logical
    # experts → replicated; expert_mlp (the F dim) picks up the model axis
    swapped = tuple(
        None if a == "experts" else ("mlp" if a == "expert_mlp" else a) for a in logical
    )
    return swapped


def param_specs(cfg: ModelConfig, params: Any, ctx: MeshContext) -> Any:
    """PartitionSpec pytree matching ``params`` (arrays or SDS)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        keys = [_keyname(k) for k in path]
        shape = tuple(leaf.shape)
        base = _base_axes(cfg, keys, len(shape))
        base = _moe_fallback(cfg, ctx, base, shape)
        aligned = (None,) * (len(shape) - len(base)) + tuple(base)
        specs.append(_physical(ctx, aligned, shape))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(cfg: ModelConfig, params: Any, ctx: MeshContext) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s),
        param_specs(cfg, params, ctx),
        is_leaf=lambda x: isinstance(x, P),
    )


def tree_specs(specs_of_params: Any, tree: Any, params: Any) -> Any:
    """Broadcast parameter specs onto a state pytree that *mirrors* the
    parameter tree below some wrapper prefix (optimizer m/v, adafactor
    dicts) — ZeRO: optimizer state shards exactly like its parameter.
    Leaves with no matching parameter (scalars, factored adafactor rows)
    are replicated."""
    lookup: dict[tuple, tuple] = {}
    pflat = jax.tree_util.tree_flatten_with_path(params)[0]
    sleaves = jax.tree_util.tree_leaves(
        specs_of_params, is_leaf=lambda x: isinstance(x, P)
    )
    for (path, leaf), spec in zip(pflat, sleaves):
        lookup[tuple(_keyname(k) for k in path)] = (tuple(leaf.shape), spec)

    def resolve(path, leaf):
        keys = tuple(_keyname(k) for k in path)
        shape = tuple(leaf.shape)
        # contiguous sub-path match (strips wrapper keys like "m"/"v"),
        # accepted only when the shape matches the parameter's
        for start in range(len(keys)):
            for end in range(len(keys), start, -1):
                hit = lookup.get(keys[start:end])
                if hit and hit[0] == shape:
                    return hit[1]
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return jax.tree_util.tree_unflatten(treedef, [resolve(p, l) for p, l in flat])


def batch_specs(ctx: MeshContext, batch: Any) -> Any:
    """Input batch: batch dim → ('pod','data'); everything else replicated.
    Divisibility-checked (a global_batch=1 long-context cell replicates)."""

    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return ctx.spec(("batch",) + (None,) * (nd - 1), leaf.shape)

    return jax.tree.map(one, batch)
