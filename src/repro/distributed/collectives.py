"""Explicit collectives for distributed optimization (shard_map level).

* :func:`int8_allreduce` — bandwidth-compressed gradient all-reduce with
  error feedback: 4× fewer wire bytes than f32 (2× vs bf16).  Two-phase
  reduce-scatter/all-gather, both phases carrying int8 on the wire with
  per-shard f32 scales; the stage-1 quantization error is returned for
  error-feedback accumulation (carried in the optimizer loop, so the bias
  vanishes over steps).
* :func:`ring_reduce_scatter_matmul` — collective matmul: y = x·W with
  both operands sharded on the contraction dim; the reduce-scatter is
  unrolled into a ring of ``ppermute`` steps, each overlapped with one
  row-block partial matmul — the compute/communication-overlap trick
  XLA's async collectives perform, expressed manually so the schedule is
  explicit and tunable.

Both are used through ``jax.shard_map`` and verified numerically on a
host-device mesh (tests/distributed).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["int8_allreduce", "ring_reduce_scatter_matmul", "compressed_psum_grads"]


def _axis_size(axis_name: str) -> int:
    """``jax.lax.axis_size`` appeared in newer jax; under shard_map,
    ``psum(1, axis)`` constant-folds to the same concrete int everywhere."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def _pvary(x: jax.Array, axis_names: tuple[str, ...]) -> jax.Array:
    """``jax.lax.pvary`` (varying-type annotation for the newer shard_map
    type system) is a semantic no-op where it does not exist."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_allreduce(
    x: jax.Array, axis_name: str, err: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """All-reduce ``x`` (identical shape per device) over ``axis_name``
    with int8 wire traffic.  Returns (reduced, new_error_feedback).

    Phase 1 (reduce-scatter): quantize locally, ``all_to_all`` int8 so
    device d receives everyone's d-th chunk, dequantize+sum.
    Phase 2 (all-gather): re-quantize the reduced chunk, ``all_gather``
    int8 + scales, dequantize.
    """
    n = _axis_size(axis_name)
    orig_shape = x.shape
    xf = x.reshape(-1).astype(jnp.float32)
    if err is not None:
        xf = xf + err.reshape(-1)
    pad = (-xf.size) % n
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad,), xf.dtype)])

    q, scale = _quantize(xf)
    new_err = xf - q.astype(jnp.float32) * scale  # stage-1 EF residual

    chunks = q.reshape(n, -1)  # (n, chunk)
    recv = jax.lax.all_to_all(chunks, axis_name, split_axis=0, concat_axis=0, tiled=False)
    scales = jax.lax.all_gather(scale, axis_name)  # (n,)
    partial = jnp.sum(recv.astype(jnp.float32) * scales[:, None], axis=0)  # (chunk,)

    q2, s2 = _quantize(partial)
    qs = jax.lax.all_gather(q2, axis_name)  # (n, chunk)
    ss = jax.lax.all_gather(s2, axis_name)  # (n,)
    out = (qs.astype(jnp.float32) * ss[:, None]).reshape(-1)
    if pad:
        out = out[:-pad]
        new_err = new_err[:-pad]
    return out.reshape(orig_shape).astype(x.dtype), new_err.reshape(orig_shape)


def ring_reduce_scatter_matmul(
    x_shard: jax.Array, w_shard: jax.Array, axis_name: str
) -> jax.Array:
    """Collective matmul (Megatron row-parallel with overlap):
    ``y = X @ W`` where X (m, K) and W (K, N) are both sharded on the
    contraction dim K.  Devices hold x_shard (m, K/n) and w_shard (K/n, N);
    the result is returned *row-sharded*: device d gets rows
    ``[d·m/n, (d+1)·m/n)`` of y, fully reduced.

    Instead of a monolithic partial-matmul + reduce-scatter, each ring
    step matmuls ONE row-block against the local W while the accumulator
    for another block is in flight (``ppermute``) — the transfer of step
    s hides behind the matmul of step s+1.
    """
    n = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = x_shard.shape[0]
    assert m % n == 0, (m, n)
    mb = m // n
    perm = [(i, (i - 1) % n) for i in range(n)]  # accumulator moves "down"

    def body(s, acc):
        # the accumulator visiting this device at step s is the one that
        # finishes (after its remaining hops) at device (idx + s) % n —
        # contribute the local partial for that block, then pass it down.
        blk = (idx + s) % n
        rows = jax.lax.dynamic_slice_in_dim(x_shard, blk * mb, mb, axis=0)
        part = jax.lax.dot_general(
            rows, w_shard, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc = acc + part
        return jax.lax.ppermute(acc, axis_name, perm)

    acc0 = _pvary(jnp.zeros((mb, w_shard.shape[1]), jnp.float32), (axis_name,))
    acc = jax.lax.fori_loop(0, n, body, acc0)
    return acc.astype(jnp.promote_types(x_shard.dtype, w_shard.dtype))


def compressed_psum_grads(grads, axis_name: str, errs=None):
    """Tree-wide int8 error-feedback all-reduce (mean) for gradients."""
    n = _axis_size(axis_name)
    if errs is None:
        errs = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(
        lambda g, e: int8_allreduce(g, axis_name, e), grads, errs
    )
    reduced = jax.tree.map(lambda o: o[0] / n, out, is_leaf=lambda x: isinstance(x, tuple))
    new_errs = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return reduced, new_errs
