"""Sharded synthetic data pipeline with background prefetch.

Real clusters stream tokenized shards from object storage; offline we
generate a *deterministic, host-shardable* synthetic LM stream: Zipf
unigram draws mixed with copy/induction segments (so a real model can
actually reduce loss on it), keyed by (seed, host_shard, step) — every
host computes only its slice, restart at step k reproduces the same batch
(checkpoint-exact resume), and no coordination is needed.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import jax
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "Prefetcher", "make_batch_iterator"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 32_000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    zipf_a: float = 1.2
    copy_frac: float = 0.3  # fraction of each row that is induction/copy
    host_shard: int = 0  # this host's index
    num_host_shards: int = 1


class SyntheticLM:
    """Deterministic synthetic LM batches; ``batch(step)`` is a pure
    function of (config, step) — the elastic-resume property."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        if cfg.global_batch % cfg.num_host_shards:
            raise ValueError("global_batch must divide evenly across host shards")
        self.local_batch = cfg.global_batch // cfg.num_host_shards
        # precompute the Zipf CDF once
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        w = ranks ** -cfg.zipf_a
        self._cdf = np.cumsum(w / w.sum())

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, cfg.host_shard, step])
        )
        B, S = self.local_batch, cfg.seq_len
        u = rng.random((B, S + 1))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        # induction segments: copy an earlier span forward so that
        # attention/state models have learnable structure
        span = max(4, int(S * cfg.copy_frac) // 2)
        if span * 2 < S:
            start = rng.integers(0, S - 2 * span, size=B)
            for b in range(B):
                s = start[b]
                toks[b, s + span : s + 2 * span] = toks[b, s : s + span]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-bounded) — overlaps host batch
    synthesis/IO with device compute."""

    def __init__(self, it: Iterator[Any], depth: int = 2) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: BaseException | None = None

        def work():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # pragma: no cover
                self._err = e
                self._q.put(None)

        self._t = threading.Thread(target=work, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None and self._err is not None:  # pragma: no cover
            raise self._err
        return item


def make_batch_iterator(
    cfg: DataConfig,
    sharding: Any | None = None,
    start_step: int = 0,
    prefetch: int = 2,
):
    """Iterator of device-resident batches.  ``sharding`` is a NamedSharding
    for (B, S) arrays (batch → ('pod','data')); None keeps them on host."""
    ds = SyntheticLM(cfg)

    def gen():
        step = start_step
        while True:
            b = ds.batch(step)
            if sharding is not None:
                b = {k: jax.device_put(v, sharding) for k, v in b.items()}
            yield b
            step += 1

    return Prefetcher(gen(), depth=prefetch) if prefetch else gen()
