"""Closure-based source-transformation reverse-mode AD (paper §3.2).

Following Pearlmutter & Siskind's "Lambda the ultimate backpropagator" as
adopted by the paper:

* ``J(g)`` transforms graph ``g`` into ``▶g`` ("forward graph"): every call
  inside returns an **additional value**, a closure called the
  *backpropagator* (``◀``); ``▶g`` itself returns ``(value, ◀g)``.
* ``◀g(dout)`` calls the backpropagators of the body in reverse order and
  returns ``(env, dparam_1, …, dparam_n)`` where ``env`` carries the partial
  derivatives w.r.t. ``g``'s **free variables** keyed by symbolic keys
  (see ``repro.core.values``).  The backpropagator of the scope that
  *created* a closure unpacks that env — "this unpacking being the adjoint
  of closure creation" (paper §3.2).
* Because the transform's output is ordinary IR (closures included), it can
  be applied to itself: **reverse-over-reverse** gives higher-order
  derivatives.  No tape anywhere.

There is no runtime machinery here: the result is a program, amenable to
ahead-of-time optimization (``repro.core.opt``) — the paper's central
argument for ST over operator overloading.
"""

from __future__ import annotations

import inspect

import numpy as np

from . import primitives as P
from .ir import (
    Apply,
    Constant,
    Graph,
    Node,
    Parameter,
    dfs_nodes,
    free_variables,
    graph_and_descendants,
    is_constant_graph,
)
from .primitives import LOOP_NAMES, Primitive
from .values import SymbolicKey, newenv

__all__ = ["J", "Jprim", "build_grad_graph", "build_value_and_grad_graph", "build_vjp_graph"]


# ---------------------------------------------------------------------------
# J of primitives
# ---------------------------------------------------------------------------

_JPRIM_CACHE: dict[tuple[int, int], Graph] = {}


def _prim_arity(p: Primitive) -> int:
    if callable(p.bprop):
        return len(inspect.signature(p.bprop).parameters) - 2
    try:
        sig = inspect.signature(p.impl)
    except (TypeError, ValueError):  # pragma: no cover
        raise TypeError(f"cannot determine arity of primitive {p.name}")
    if any(
        prm.kind in (prm.VAR_POSITIONAL, prm.VAR_KEYWORD) for prm in sig.parameters.values()
    ):
        raise TypeError(f"variadic primitive {p.name} needs an explicit arity")
    return len(sig.parameters)


def Jprim(p: Primitive, arity: int | None = None) -> Graph:
    """``▶p``: a graph ``(j1..jn) -> (p(j1..jn), ◀p)`` built from the
    primitive's registered backpropagator definition."""
    if arity is None:
        arity = _prim_arity(p)
    key = (id(p), arity)
    if key in _JPRIM_CACHE:
        return _JPRIM_CACHE[key]

    jp = Graph(f"▶{p.name}")
    jp.flags["is_jprim"] = p.name
    params = [jp.add_parameter(f"j{i}") for i in range(arity)]
    out = jp.apply(p, *params, debug_name=f"{p.name}_out")

    bg = Graph(f"◀{p.name}")
    bg.flags["is_bprop_of_prim"] = p.name
    dout = bg.add_parameter("dout")

    if p is P.make_tuple:
        items = [bg.apply(P.tuple_getitem, dout, i) for i in range(arity)]
    elif p.bprop == "zeros":
        items = [bg.apply(P.zeros_like, prm) for prm in params]
    elif callable(p.bprop):
        from .parser import parse_function

        bpg = parse_function(p.bprop)
        tup = bg.apply(bpg, *params, out, dout)
        items = [bg.apply(P.tuple_getitem, tup, i) for i in range(arity)]
    else:
        raise TypeError(f"primitive {p.name} has no backpropagator")

    bg.set_return(bg.apply(P.make_tuple, newenv, *items))
    jp.set_return(jp.apply(P.make_tuple, out, Constant(bg)))
    _JPRIM_CACHE[key] = jp
    return jp


# ---------------------------------------------------------------------------
# J of graphs (family-wide transform)
# ---------------------------------------------------------------------------


#: ``checkpoint_policy`` → number of checkpoint slots ``S`` in the
#: while-loop adjoint's segmented scheme (the stack is a static-shape
#: loop-carried array of ``S`` saved carries; the backward pass recomputes
#: at most ``ceil(T/S)-1`` steps per adjoint step from the nearest slot).
#: ``T <= S`` degenerates to exact saved-carry recording (zero recompute);
#: ``recompute`` (S=1) stores only the initial carry — O(T²) step work,
#: O(1) memory.  An int policy is used as ``S`` directly.  ``scan_loop``
#: adjoints ignore the policy: their trip count is static, so the stack is
#: exact by construction.  See docs/pipeline.md ("Loop adjoints").
_CHECKPOINT_SLOTS = {"auto": 128, "save_all": 1024, "recompute": 1}


def _policy_slots(policy) -> int:
    if policy is None:
        policy = "auto"
    if isinstance(policy, bool):
        raise ValueError(f"invalid checkpoint_policy {policy!r}")
    if isinstance(policy, int):
        if policy < 1:
            raise ValueError("checkpoint_policy slot count must be >= 1")
        return policy
    try:
        return _CHECKPOINT_SLOTS[policy]
    except KeyError:
        raise ValueError(
            f"invalid checkpoint_policy {policy!r} "
            f"(expected one of {sorted(_CHECKPOINT_SLOTS)} or an int slot count)"
        ) from None


def _carry_meta(node: Node, what: str) -> tuple[tuple[int, ...], np.dtype]:
    """(shape, dtype) of a loop-carry argument, read from its abstract.

    The adjoint allocates the saved-carry stack as a static-shape array,
    so the carry's shape/dtype must be statically known — which is exactly
    what the pre-grad pipeline's inference pass annotates."""
    from .infer import AArray, AScalar

    ab = node.abstract
    if isinstance(ab, AArray):
        return ab.shape, ab.dtype
    if isinstance(ab, AScalar):
        dt = {"int": "int32", "float": "float32", "bool": "bool"}.get(ab.kind)
        if dt is not None:
            return (), np.dtype(dt)
    if isinstance(node, Constant) and ab is None:
        # literal / folded-array inits (trip counters, accumulator seeds)
        # may predate inference or be emitted by a rewrite without an
        # abstract — derive the meta from the constant's value itself
        from .infer import InferenceError, abstract_of_value

        v = node.value
        if isinstance(v, bool):
            return (), np.dtype("bool")
        if isinstance(v, int):
            return (), np.dtype("int32")
        if isinstance(v, float):
            return (), np.dtype("float32")
        try:
            vab = abstract_of_value(v)
        except InferenceError:
            vab = None
        if isinstance(vab, AArray):
            return vab.shape, vab.dtype
    raise TypeError(
        f"cannot differentiate loop: carry {what} has abstract {ab!r} "
        "(need a type-inferred array/scalar carry — pass example_args so "
        "the primal runs the pipeline before grad)"
    )


def _tuple_exit(name: str, n_params: int, sel: list[int]) -> Graph:
    """A loop exit graph returning ``make_tuple(params[i] for i in sel)``."""
    g = Graph(name)
    ps = [g.add_parameter(f"a{i}") for i in range(n_params)]
    g.set_return(g.apply(P.make_tuple, *[ps[i] for i in sel]))
    return g


class JTransformer:
    def __init__(self, root: Graph, checkpoint_policy="auto") -> None:
        self.root = root
        self.checkpoint_slots = _policy_slots(checkpoint_policy)
        self.family = graph_and_descendants(root)
        self.graph_map: dict[Graph, Graph] = {}  # g -> ▶g
        self.bprop_graphs: dict[Graph, Graph] = {}  # g -> ◀g
        self.node_map: dict[int, Node] = {}  # primal node id -> forward-value node
        self.bprop_map: dict[int, Node] = {}  # primal apply id -> backpropagator node
        self._fv_cache: dict[Graph, list[Node]] = {}

    # -- public ---------------------------------------------------------
    def transform(self) -> Graph:
        cached = self.root.transforms.get("J")
        if cached is not None:
            return cached
        for g in self.family:
            jg = Graph(f"▶{g.name}")
            jg.primal = g
            jg.flags["is_j"] = True
            self.graph_map[g] = jg
            for prm in g.parameters:
                jp = jg.add_parameter(prm.debug_name)
                self.node_map[prm._id] = jp
            bg = Graph(f"◀{g.name}")
            bg.primal = g
            bg.flags["is_bprop"] = True
            self.bprop_graphs[g] = bg
        for g in self.family:
            self._build_forward(g)
        for g in self.family:
            self._build_backward(g)
        for g in self.family:
            g.transforms["J"] = self.graph_map[g]
        return self.graph_map[self.root]

    # -- forward ----------------------------------------------------------
    def _fwd_fn(self, node: Node, call_arity: int | None) -> Node:
        """Transform a node used in *function position*."""
        if isinstance(node, Constant):
            v = node.value
            if isinstance(v, Primitive):
                return Constant(Jprim(v, call_arity))
            if isinstance(v, Graph):
                return Constant(self.graph_map[v])
            raise TypeError(f"cannot call non-function constant {v!r}")
        return self._fwd(node)

    def _fwd(self, node: Node) -> Node:
        """Forward-value node for a primal node (iterative post-order)."""
        if node._id in self.node_map:
            return self.node_map[node._id]
        stack: list[tuple[Node, bool]] = [(node, False)]
        while stack:
            cur, ready = stack.pop()
            if cur._id in self.node_map:
                continue
            if isinstance(cur, Constant):
                v = cur.value
                if isinstance(v, Graph):
                    new: Node = Constant(self.graph_map[v], cur.debug_name)
                elif isinstance(v, Primitive):
                    # primitive passed as a value (e.g. HOF argument)
                    new = Constant(Jprim(v, None), cur.debug_name)
                else:
                    new = Constant(v, cur.debug_name)
                self.node_map[cur._id] = new
                continue
            if isinstance(cur, Parameter):
                raise RuntimeError(f"parameter {cur!r} not pre-mapped (outside family?)")
            assert isinstance(cur, Apply)
            if not ready:
                stack.append((cur, True))
                for inp in cur.inputs[1:]:
                    if inp._id not in self.node_map:
                        stack.append((inp, False))
                fn = cur.inputs[0]
                if not isinstance(fn, Constant) and fn._id not in self.node_map:
                    stack.append((fn, False))
                continue
            fn0 = cur.inputs[0]
            if (
                isinstance(fn0, Constant)
                and isinstance(fn0.value, Primitive)
                and fn0.value.name in LOOP_NAMES
            ):
                # structured loop: tape-free loop adjoint (see _j_loop)
                self._j_loop(cur)
                continue
            jg = self.graph_map[cur.graph]
            jf = self._fwd_fn(cur.inputs[0], len(cur.inputs) - 1)
            jargs = [self.node_map[a._id] for a in cur.inputs[1:]]
            japp = Apply([jf, *jargs], jg, debug_name=f"J_{cur.debug_name}")
            fw = Apply([Constant(P.tuple_getitem), japp, Constant(0)], jg, cur.debug_name)
            bp = Apply(
                [Constant(P.tuple_getitem), japp, Constant(1)], jg, f"bprop_{cur.debug_name}"
            )
            self.node_map[cur._id] = fw
            self.bprop_map[cur._id] = bp
        return self.node_map[node._id]

    def _build_forward(self, g: Graph) -> None:
        jg = self.graph_map[g]
        ret = self._fwd(g.return_)
        # also force-transform applies only reachable through nested graphs
        for n in dfs_nodes(g.return_):
            if isinstance(n, Apply) and n.graph in self.family:
                self._fwd(n)
        jg.set_return(jg.apply(P.make_tuple, ret, Constant(self.bprop_graphs[g])))

    # -- structured loops -------------------------------------------------
    #
    # Reverse-mode rules for the loop primitives (after Innes, "Don't
    # Unroll Adjoint"): instead of unrolling or taping, the adjoint of a
    # loop is itself a loop.
    #
    # * ``scan_loop`` (static trip count L): the forward pass is replaced
    #   by an *augmented* scan whose carry additionally threads one
    #   saved-carry stack per carry slot — an ordinary loop-carried array
    #   of shape ``(L, *carry.shape)``, not a runtime tape — plus the
    #   iteration index.  The backpropagator is a reversed scan over those
    #   stacks, calling the VJP of the step graph (itself built by this
    #   same transform, so reverse-over-reverse composes).
    #
    # * ``while_loop`` (dynamic trip count): phase 1 reruns the loop with
    #   a trip counter to obtain T; the backpropagator then reruns the
    #   forward once more, checkpointing every ``k_seg = ceil(T/S)``-th
    #   carry into an S-slot stack (S from ``checkpoint_policy``), and the
    #   backward while-loop recomputes at most ``k_seg - 1`` steps from
    #   the nearest checkpoint per adjoint step.  ``T <= S`` degenerates
    #   to exact recording; ``S == 1`` is full recomputation.
    #
    # Every graph built here is closed and first-order (direct calls of
    # the closed step/exit graphs, inlined by the optimizer on the next
    # pipeline wave), so loop adjoints lower, fuse, shard and AOT-cache
    # exactly like hand-written loops.

    def _loop_operands(self, cur: Apply, k: int):
        carries_p = list(cur.inputs[5 : 5 + k])
        extras_p = list(cur.inputs[5 + k :])
        carries = [self.node_map[a._id] for a in carries_p]
        extras = [self.node_map[a._id] for a in extras_p]
        metas = [
            _carry_meta(a, a.debug_name or f"#{i}") for i, a in enumerate(carries_p)
        ]
        return carries, extras, metas

    def _zero_stack(self, host: Graph, length: int, shape: tuple, dtype) -> Node:
        z = host.apply(P.cast, 0, Constant(dtype))
        return host.apply(P.broadcast_to, z, Constant((length, *shape)))

    def _j_loop(self, cur: Apply) -> None:
        prim = cur.inputs[0].value
        raw = cur.inputs[1:]
        n_sub = 2 if prim.name == "scan_loop" else 3
        subs = raw[:n_sub]
        if not all(is_constant_graph(s) for s in subs) or not isinstance(
            raw[n_sub], Constant
        ):
            raise TypeError(
                f"cannot differentiate {prim.name}: sub-graphs are not "
                "constant graphs (graph not in lowered canonical form)"
            )
        if prim.name == "scan_loop":
            self._j_scan(cur)
        else:
            self._j_while(cur)

    def _j_scan(self, cur: Apply) -> None:
        jg = self.graph_map[cur.graph]
        sg, eg = cur.inputs[1].value, cur.inputs[2].value
        L = int(cur.inputs[3].value)
        k = int(cur.inputs[4].value)
        carries, extras, metas = self._loop_operands(cur, k)
        m = len(extras)

        # augmented forward: carry (c..., stk..., t); each iteration saves
        # its incoming carry into row t of the stacks
        asg = Graph(f"{sg.name}:aug")
        ac = [asg.add_parameter(f"c{i}") for i in range(k)]
        astk = [asg.add_parameter(f"s{i}") for i in range(k)]
        at = asg.add_parameter("t")
        ae = [asg.add_parameter(f"e{j}") for j in range(m)]
        tup = asg.apply(Constant(sg), *ac, *ae)
        ncs = [asg.apply(P.tuple_getitem, tup, i) for i in range(k)]
        nss = [asg.apply(P.index_add, astk[i], at, ac[i]) for i in range(k)]
        asg.set_return(
            asg.apply(P.make_tuple, *ncs, *nss, asg.apply(P.add, at, 1))
        )
        aeg = _tuple_exit(f"{sg.name}:aug_exit", 2 * k + 1 + m, list(range(2 * k)))

        zstks = [self._zero_stack(jg, L, sh, dt) for sh, dt in metas]
        aug = jg.apply(
            P.scan_loop, Constant(asg), Constant(aeg), L, 2 * k + 1,
            *carries, *zstks, 0, *extras,
            debug_name=f"J_{cur.debug_name}",
        )
        fins = [jg.apply(P.tuple_getitem, aug, i) for i in range(k)]
        stks = [jg.apply(P.tuple_getitem, aug, k + i) for i in range(k)]
        self.node_map[cur._id] = jg.apply(
            Constant(eg), *fins, *extras, debug_name=cur.debug_name
        )

        vjp_sg = build_vjp_graph(sg)
        vjp_eg = build_vjp_graph(eg)

        # backward: reversed scan over the saved-carry stacks; carry
        # (t, dc..., dacc_e...), extras (stk..., e...)
        bsg = Graph(f"{sg.name}:bwd")
        bt = bsg.add_parameter("t")
        bdc = [bsg.add_parameter(f"dc{i}") for i in range(k)]
        bda = [bsg.add_parameter(f"da{j}") for j in range(m)]
        bstk = [bsg.add_parameter(f"s{i}") for i in range(k)]
        bex = [bsg.add_parameter(f"e{j}") for j in range(m)]
        tm1 = bsg.apply(P.sub, bt, 1)
        cs = [bsg.apply(P.take, bstk[i], tm1) for i in range(k)]
        gr = bsg.apply(
            Constant(vjp_sg), *cs, *bex, bsg.apply(P.make_tuple, *bdc)
        )
        ndc = [bsg.apply(P.tuple_getitem, gr, i) for i in range(k)]
        nda = [
            bsg.apply(P.gadd, bda[j], bsg.apply(P.tuple_getitem, gr, k + j))
            for j in range(m)
        ]
        bsg.set_return(bsg.apply(P.make_tuple, tm1, *ndc, *nda))
        beg = _tuple_exit(
            f"{sg.name}:bwd_exit", (1 + k + m) + (k + m), list(range(1 + k + m))
        )

        b = Graph(f"◀{cur.debug_name or 'scan_loop'}")
        b.flags["is_loop_bprop"] = True
        dout = b.add_parameter("dout")
        egr = b.apply(Constant(vjp_eg), *fins, *extras, dout)
        dfc = [b.apply(P.tuple_getitem, egr, i) for i in range(k)]
        dex = [b.apply(P.tuple_getitem, egr, k + j) for j in range(m)]
        zda = [b.apply(P.zeros_like, extras[j]) for j in range(m)]
        bres = b.apply(
            P.scan_loop, Constant(bsg), Constant(beg), L, 1 + k + m,
            L, *dfc, *zda, *stks, *extras,
        )
        dcs = [b.apply(P.tuple_getitem, bres, 1 + i) for i in range(k)]
        des = [
            b.apply(P.gadd, dex[j], b.apply(P.tuple_getitem, bres, 1 + k + j))
            for j in range(m)
        ]
        zero = Constant(0)
        b.set_return(
            b.apply(P.make_tuple, Constant(newenv), zero, zero, zero, zero, *dcs, *des)
        )
        self.bprop_map[cur._id] = Constant(b)

    def _j_while(self, cur: Apply) -> None:
        jg = self.graph_map[cur.graph]
        cg, sg, eg = (cur.inputs[i].value for i in (1, 2, 3))
        k = int(cur.inputs[4].value)
        carries, extras, metas = self._loop_operands(cur, k)
        m = len(extras)
        S = self.checkpoint_slots

        def call_sub(host: Graph, sub: Graph, cs: list, es: list) -> Node:
            return host.apply(Constant(sub), *cs, *es)

        # phase 1: forward with a trip counter; carry (c..., t)
        acg = Graph(f"{cg.name}:aug")
        pc = [acg.add_parameter(f"c{i}") for i in range(k)]
        acg.add_parameter("t")
        pe = [acg.add_parameter(f"e{j}") for j in range(m)]
        acg.set_return(call_sub(acg, cg, pc, pe))

        asg = Graph(f"{sg.name}:aug")
        sc = [asg.add_parameter(f"c{i}") for i in range(k)]
        st = asg.add_parameter("t")
        se = [asg.add_parameter(f"e{j}") for j in range(m)]
        tup = call_sub(asg, sg, sc, se)
        ncs = [asg.apply(P.tuple_getitem, tup, i) for i in range(k)]
        asg.set_return(
            asg.apply(P.make_tuple, *ncs, asg.apply(P.add, st, 1))
        )
        aeg = _tuple_exit(f"{sg.name}:aug_exit", k + 1 + m, list(range(k + 1)))

        p1 = jg.apply(
            P.while_loop, Constant(acg), Constant(asg), Constant(aeg), k + 1,
            *carries, 0, *extras,
            debug_name=f"J_{cur.debug_name}",
        )
        fins = [jg.apply(P.tuple_getitem, p1, i) for i in range(k)]
        trip = jg.apply(P.tuple_getitem, p1, k)
        self.node_map[cur._id] = jg.apply(
            Constant(eg), *fins, *extras, debug_name=cur.debug_name
        )

        vjp_sg = build_vjp_graph(sg)
        vjp_eg = build_vjp_graph(eg)

        b = Graph(f"◀{cur.debug_name or 'while_loop'}")
        b.flags["is_loop_bprop"] = True
        dout = b.add_parameter("dout")
        # segment length: ceil(T / S), at least 1 (S static, T dynamic)
        kseg = b.apply(
            P.maximum, 1, b.apply(P.floordiv, b.apply(P.add, trip, S - 1), S)
        )

        # phase 2 (grad-only): rerun the forward, checkpointing every
        # kseg-th carry into slot t // kseg of an S-slot stack.  The write
        # is masked (add 0 elsewhere), so the stack stays a plain carry.
        rcg = Graph(f"{cg.name}:rec")
        rc = [rcg.add_parameter(f"c{i}") for i in range(k)]
        for i in range(k):
            rcg.add_parameter(f"s{i}")
        rcg.add_parameter("t")
        re_ = [rcg.add_parameter(f"e{j}") for j in range(m)]
        rcg.add_parameter("kseg")
        rcg.set_return(call_sub(rcg, cg, rc, re_))

        rsg = Graph(f"{sg.name}:rec")
        xc = [rsg.add_parameter(f"c{i}") for i in range(k)]
        xs = [rsg.add_parameter(f"s{i}") for i in range(k)]
        xt = rsg.add_parameter("t")
        xe = [rsg.add_parameter(f"e{j}") for j in range(m)]
        xk = rsg.add_parameter("kseg")
        slot = rsg.apply(P.floordiv, xt, xk)
        hit = rsg.apply(P.eq, rsg.apply(P.mod, xt, xk), 0)
        nss = [
            rsg.apply(
                P.index_add, xs[i], slot,
                rsg.apply(P.mul, xc[i], rsg.apply(P.cast, hit, Constant(metas[i][1]))),
            )
            for i in range(k)
        ]
        tup = call_sub(rsg, sg, xc, xe)
        ncs = [rsg.apply(P.tuple_getitem, tup, i) for i in range(k)]
        rsg.set_return(
            rsg.apply(P.make_tuple, *ncs, *nss, rsg.apply(P.add, xt, 1))
        )
        reg = _tuple_exit(
            f"{sg.name}:rec_exit", 2 * k + 1 + m + 1, list(range(k, 2 * k))
        )
        zstks = [self._zero_stack(b, S, sh, dt) for sh, dt in metas]
        p2 = b.apply(
            P.while_loop, Constant(rcg), Constant(rsg), Constant(reg), 2 * k + 1,
            *carries, *zstks, 0, *extras, kseg,
        )
        stks = [b.apply(P.tuple_getitem, p2, i) for i in range(k)]

        # inner recompute: replay r = (t-1) - seg*kseg steps from the
        # checkpointed carry; carry (c..., j), extras (e..., r)
        icg = Graph(f"{sg.name}:replay_cond")
        for i in range(k):
            icg.add_parameter(f"c{i}")
        ij = icg.add_parameter("j")
        for j in range(m):
            icg.add_parameter(f"e{j}")
        ir = icg.add_parameter("r")
        icg.set_return(icg.apply(P.lt, ij, ir))

        isg = Graph(f"{sg.name}:replay")
        yc = [isg.add_parameter(f"c{i}") for i in range(k)]
        yj = isg.add_parameter("j")
        ye = [isg.add_parameter(f"e{j}") for j in range(m)]
        isg.add_parameter("r")
        tup = call_sub(isg, sg, yc, ye)
        ncs = [isg.apply(P.tuple_getitem, tup, i) for i in range(k)]
        isg.set_return(
            isg.apply(P.make_tuple, *ncs, isg.apply(P.add, yj, 1))
        )
        ieg = _tuple_exit(f"{sg.name}:replay_exit", k + 1 + m + 1, list(range(k)))

        # backward while: carry (t, dc..., dacc_e...),
        # extras (stk..., e..., kseg)
        bwcg = Graph(f"{sg.name}:bwd_cond")
        wt = bwcg.add_parameter("t")
        for i in range(k + m):
            bwcg.add_parameter(f"d{i}")
        for i in range(k + m + 1):
            bwcg.add_parameter(f"x{i}")
        bwcg.set_return(bwcg.apply(P.gt, wt, 0))

        bwsg = Graph(f"{sg.name}:bwd")
        bt = bwsg.add_parameter("t")
        bdc = [bwsg.add_parameter(f"dc{i}") for i in range(k)]
        bda = [bwsg.add_parameter(f"da{j}") for j in range(m)]
        bstk = [bwsg.add_parameter(f"s{i}") for i in range(k)]
        bex = [bwsg.add_parameter(f"e{j}") for j in range(m)]
        bk = bwsg.add_parameter("kseg")
        tm1 = bwsg.apply(P.sub, bt, 1)
        seg = bwsg.apply(P.floordiv, tm1, bk)
        c0 = [bwsg.apply(P.take, bstk[i], seg) for i in range(k)]
        r = bwsg.apply(P.sub, tm1, bwsg.apply(P.mul, seg, bk))
        inner = bwsg.apply(
            P.while_loop, Constant(icg), Constant(isg), Constant(ieg), k + 1,
            *c0, 0, *bex, r,
        )
        cs = [bwsg.apply(P.tuple_getitem, inner, i) for i in range(k)]
        gr = bwsg.apply(
            Constant(vjp_sg), *cs, *bex, bwsg.apply(P.make_tuple, *bdc)
        )
        ndc = [bwsg.apply(P.tuple_getitem, gr, i) for i in range(k)]
        nda = [
            bwsg.apply(P.gadd, bda[j], bwsg.apply(P.tuple_getitem, gr, k + j))
            for j in range(m)
        ]
        bwsg.set_return(bwsg.apply(P.make_tuple, tm1, *ndc, *nda))
        bweg = _tuple_exit(
            f"{sg.name}:bwd_exit", (1 + k + m) + (k + m + 1), list(range(1 + k + m))
        )

        egr = b.apply(Constant(vjp_eg), *fins, *extras, dout)
        dfc = [b.apply(P.tuple_getitem, egr, i) for i in range(k)]
        dex = [b.apply(P.tuple_getitem, egr, k + j) for j in range(m)]
        zda = [b.apply(P.zeros_like, extras[j]) for j in range(m)]
        bres = b.apply(
            P.while_loop, Constant(bwcg), Constant(bwsg), Constant(bweg), 1 + k + m,
            trip, *dfc, *zda, *stks, *extras, kseg,
        )
        dcs = [b.apply(P.tuple_getitem, bres, 1 + i) for i in range(k)]
        des = [
            b.apply(P.gadd, dex[j], b.apply(P.tuple_getitem, bres, 1 + k + j))
            for j in range(m)
        ]
        zero = Constant(0)
        b.set_return(
            b.apply(P.make_tuple, Constant(newenv), zero, zero, zero, zero, *dcs, *des)
        )
        self.bprop_map[cur._id] = Constant(b)

    # -- backward ---------------------------------------------------------
    def _fvs(self, g: Graph) -> list[Node]:
        if g not in self._fv_cache:
            self._fv_cache[g] = free_variables(g)
        return self._fv_cache[g]

    def _adjoint_order(self, g: Graph) -> list[Apply]:
        """g-owned apply nodes, topo-sorted with closure-capture edges:
        an apply that references a nested graph depends on the g-owned free
        variables that graph captures (closure creation 'uses' them)."""
        owned = [
            n
            for n in dfs_nodes(g.return_)
            if isinstance(n, Apply) and n.graph is g
        ]
        deps: dict[int, list[Node]] = {}
        for u in owned:
            d: list[Node] = []
            for inp in u.inputs:
                if inp.graph is g:
                    d.append(inp)
                elif is_constant_graph(inp) and inp.value in self.family:
                    d.extend(v for v in self._fvs(inp.value) if v.graph is g)
            deps[u._id] = d
        order: list[Apply] = []
        state: dict[int, int] = {}  # 0 visiting, 1 done

        for root in owned:
            if root._id in state:
                continue
            stack: list[tuple[Node, bool]] = [(root, False)]
            while stack:
                cur, ready = stack.pop()
                if ready:
                    state[cur._id] = 1
                    order.append(cur)  # type: ignore[arg-type]
                    continue
                st = state.get(cur._id)
                if st is not None:
                    continue
                state[cur._id] = 0
                stack.append((cur, True))
                for dep in deps.get(cur._id, ()):
                    if isinstance(dep, Apply) and dep.graph is g and state.get(dep._id) is None:
                        stack.append((dep, False))
        return order

    def _build_backward(self, g: Graph) -> None:
        bg = self.bprop_graphs[g]
        dout = bg.add_parameter("dout")
        contribs: dict[int, list[Node]] = {}
        env_contribs: dict[int, tuple[Node, list[Node]]] = {}
        sens_memo: dict[int, Node] = {}

        def fold(vals: list[Node]) -> Node:
            acc = vals[0]
            for v in vals[1:]:
                acc = bg.apply(P.gadd, acc, v)
            return acc

        def sens_of(primal: Node) -> Node:
            if primal._id in sens_memo:
                return sens_memo[primal._id]
            lst = contribs.get(primal._id)
            if lst:
                s = fold(lst)
            else:
                s = bg.apply(P.zeros_like, self.node_map[primal._id])
            sens_memo[primal._id] = s
            return s

        def route(primal: Node, val: Node) -> None:
            if isinstance(primal, Constant):
                v = primal.value
                if isinstance(v, Graph) and v in self.family:
                    # adjoint of closure creation: unpack free-var grads
                    for fv in self._fvs(v):
                        fw_fv = self.node_map[fv._id]
                        key = Constant(SymbolicKey(fw_fv))
                        dflt = bg.apply(P.zeros_like, fw_fv)
                        dv = bg.apply(P.env_getitem, val, key, dflt)
                        route(fv, dv)
                return  # sensitivities of data/primitive constants: discarded
            if primal.graph is g:
                contribs.setdefault(primal._id, []).append(val)
            else:
                # free variable of g: goes into the returned env
                ec = env_contribs.setdefault(primal._id, (primal, []))
                ec[1].append(val)

        route(g.return_, dout)

        for u in reversed(self._adjoint_order(g)):
            du = sens_of(u)
            ct = bg.apply(self.bprop_map[u._id], du, debug_name=f"d_{u.debug_name}")
            for i, inp in enumerate(u.inputs):
                route(inp, bg.apply(P.tuple_getitem, ct, i))

        env_node: Node = Constant(newenv)
        for nid in sorted(env_contribs):
            primal, vals = env_contribs[nid]
            fw = self.node_map[primal._id]
            env_node = bg.apply(
                P.env_setitem, env_node, Constant(SymbolicKey(fw)), fold(vals)
            )
        param_sens = [sens_of(prm) for prm in g.parameters]
        bg.set_return(bg.apply(P.make_tuple, env_node, *param_sens))


def J(g: Graph, checkpoint_policy="auto") -> Graph:
    """Transform ``g`` into ``▶g`` (cached on the graph)."""
    cached = g.transforms.get("J")
    if cached is not None:
        return cached
    return JTransformer(g, checkpoint_policy).transform()


# ---------------------------------------------------------------------------
# User-facing graph builders
# ---------------------------------------------------------------------------


def _needs_loop_pipeline(root: Graph) -> bool:
    """True when ``root``'s family still holds recursion (parser-canonical
    loops not yet lowered) or already-lowered loop primitive applies —
    either way the primal must run the pipeline (inference + lower_loops)
    before J so the loop AD rules see typed loop primitives instead of raw
    recursion."""
    for g in graph_and_descendants(root):
        if g.return_ is None:
            continue
        for n in dfs_nodes(g.return_):
            if is_constant_graph(n) and n.value is g:
                return True
            if isinstance(n, Apply):
                f = n.inputs[0]
                if (
                    isinstance(f, Constant)
                    and isinstance(f.value, Primitive)
                    and f.value.name in LOOP_NAMES
                ):
                    return True
    return False


def _prepare_primal(g: Graph, example_args) -> Graph:
    """Pre-grad pipeline: when the primal needs loop lowering and example
    arguments are available, run ``compile_pipeline`` (inline → infer →
    optimize → lower_loops) so grad-of-loop sees ``while_loop`` /
    ``scan_loop`` primitives with inferred carry types.  Straight-line
    primals (and calls without example args — e.g. the parse-time grad
    macro) keep the direct J path."""
    if example_args is None or not _needs_loop_pipeline(g):
        return g
    from .api import compile_pipeline
    from .infer import AbstractValue, abstract_of_value

    example = tuple(
        a if isinstance(a, AbstractValue) else abstract_of_value(a)
        for a in example_args
    )
    return compile_pipeline(g, example)


def _seed_cotangent(gg: Graph, out: Node) -> Node:
    """The seed ``d(out)``: ones *at the output's shape*.  A bare scalar
    1.0 relies on broadcasting through every backpropagator — sound for
    scalar outputs, but under reverse-over-reverse the outer adjoint's
    output is an array and a scalar seed leaves shape-mismatched zero
    terms that the optimizer's ``gadd_zero`` must then treat as
    broadcasts.  ``broadcast_to(cast(1, dtype), shape)`` is exact and
    folds to a no-op for scalar outputs (the ``broadcast_noop`` rule)."""
    one = gg.apply(P.cast, 1.0, gg.apply(P.dtype_of, out))
    return gg.apply(P.broadcast_to, one, gg.apply(P.shape, out))


def build_grad_graph(
    g: Graph,
    wrt: int | tuple[int, ...] = 0,
    *,
    example_args=None,
    checkpoint_policy="auto",
) -> Graph:
    """``grad(f)``: a graph computing df/dx_wrt for a scalar-output ``f``.

    ``example_args`` (values or abstracts, one per primal parameter) arms
    the pre-grad pipeline for loop-containing primals; ``checkpoint_policy``
    selects the while-loop adjoint's memory/recompute tradeoff (see
    ``repro.core.api.CompileOptions``)."""
    from repro.obs import trace as obs_trace

    with obs_trace.span("ad.grad", graph=g.name):
        g = _prepare_primal(g, example_args)
        return _build_grad_graph_body(g, wrt, checkpoint_policy)


def _build_grad_graph_body(
    g: Graph, wrt: int | tuple[int, ...], checkpoint_policy="auto"
) -> Graph:
    jg = J(g, checkpoint_policy)
    gg = Graph(f"grad_{g.name}")
    params = [gg.add_parameter(p.debug_name) for p in g.parameters]
    japp = gg.apply(jg, *params)
    out = gg.apply(P.tuple_getitem, japp, 0)
    bp = gg.apply(P.tuple_getitem, japp, 1)
    grads = gg.apply(bp, _seed_cotangent(gg, out))
    if isinstance(wrt, int):
        gg.set_return(gg.apply(P.tuple_getitem, grads, wrt + 1))
    else:
        items = [gg.apply(P.tuple_getitem, grads, i + 1) for i in wrt]
        gg.set_return(gg.apply(P.make_tuple, *items))
    gg.primal = g
    return gg


def build_value_and_grad_graph(
    g: Graph,
    wrt: int | tuple[int, ...] = 0,
    *,
    example_args=None,
    checkpoint_policy="auto",
) -> Graph:
    g = _prepare_primal(g, example_args)
    jg = J(g, checkpoint_policy)
    gg = Graph(f"value_and_grad_{g.name}")
    params = [gg.add_parameter(p.debug_name) for p in g.parameters]
    japp = gg.apply(jg, *params)
    out = gg.apply(P.tuple_getitem, japp, 0)
    bp = gg.apply(P.tuple_getitem, japp, 1)
    grads = gg.apply(bp, _seed_cotangent(gg, out))
    if isinstance(wrt, int):
        gnode = gg.apply(P.tuple_getitem, grads, wrt + 1)
    else:
        gnode = gg.apply(P.make_tuple, *[gg.apply(P.tuple_getitem, grads, i + 1) for i in wrt])
    gg.set_return(gg.apply(P.make_tuple, out, gnode))
    gg.primal = g
    return gg


def build_vjp_graph(
    g: Graph, *, example_args=None, checkpoint_policy="auto"
) -> Graph:
    """``vjp(f)``: graph ``(x1..xn, dout) -> (dx1..dxn)`` — arbitrary output
    cotangent (non-scalar outputs)."""
    g = _prepare_primal(g, example_args)
    jg = J(g, checkpoint_policy)
    gg = Graph(f"vjp_{g.name}")
    params = [gg.add_parameter(p.debug_name) for p in g.parameters]
    dout = gg.add_parameter("dout")
    japp = gg.apply(jg, *params)
    bp = gg.apply(P.tuple_getitem, japp, 1)
    grads = gg.apply(bp, dout)
    items = [gg.apply(P.tuple_getitem, grads, i + 1) for i in range(len(params))]
    gg.set_return(gg.apply(P.make_tuple, *items))
    gg.primal = g
    return gg
