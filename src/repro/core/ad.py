"""Closure-based source-transformation reverse-mode AD (paper §3.2).

Following Pearlmutter & Siskind's "Lambda the ultimate backpropagator" as
adopted by the paper:

* ``J(g)`` transforms graph ``g`` into ``▶g`` ("forward graph"): every call
  inside returns an **additional value**, a closure called the
  *backpropagator* (``◀``); ``▶g`` itself returns ``(value, ◀g)``.
* ``◀g(dout)`` calls the backpropagators of the body in reverse order and
  returns ``(env, dparam_1, …, dparam_n)`` where ``env`` carries the partial
  derivatives w.r.t. ``g``'s **free variables** keyed by symbolic keys
  (see ``repro.core.values``).  The backpropagator of the scope that
  *created* a closure unpacks that env — "this unpacking being the adjoint
  of closure creation" (paper §3.2).
* Because the transform's output is ordinary IR (closures included), it can
  be applied to itself: **reverse-over-reverse** gives higher-order
  derivatives.  No tape anywhere.

There is no runtime machinery here: the result is a program, amenable to
ahead-of-time optimization (``repro.core.opt``) — the paper's central
argument for ST over operator overloading.
"""

from __future__ import annotations

import inspect

from . import primitives as P
from .ir import (
    Apply,
    Constant,
    Graph,
    Node,
    Parameter,
    dfs_nodes,
    free_variables,
    graph_and_descendants,
    is_constant_graph,
)
from .primitives import Primitive
from .values import SymbolicKey, newenv

__all__ = ["J", "Jprim", "build_grad_graph", "build_value_and_grad_graph", "build_vjp_graph"]


# ---------------------------------------------------------------------------
# J of primitives
# ---------------------------------------------------------------------------

_JPRIM_CACHE: dict[tuple[int, int], Graph] = {}


def _prim_arity(p: Primitive) -> int:
    if callable(p.bprop):
        return len(inspect.signature(p.bprop).parameters) - 2
    try:
        sig = inspect.signature(p.impl)
    except (TypeError, ValueError):  # pragma: no cover
        raise TypeError(f"cannot determine arity of primitive {p.name}")
    if any(
        prm.kind in (prm.VAR_POSITIONAL, prm.VAR_KEYWORD) for prm in sig.parameters.values()
    ):
        raise TypeError(f"variadic primitive {p.name} needs an explicit arity")
    return len(sig.parameters)


def Jprim(p: Primitive, arity: int | None = None) -> Graph:
    """``▶p``: a graph ``(j1..jn) -> (p(j1..jn), ◀p)`` built from the
    primitive's registered backpropagator definition."""
    if arity is None:
        arity = _prim_arity(p)
    key = (id(p), arity)
    if key in _JPRIM_CACHE:
        return _JPRIM_CACHE[key]

    jp = Graph(f"▶{p.name}")
    jp.flags["is_jprim"] = p.name
    params = [jp.add_parameter(f"j{i}") for i in range(arity)]
    out = jp.apply(p, *params, debug_name=f"{p.name}_out")

    bg = Graph(f"◀{p.name}")
    bg.flags["is_bprop_of_prim"] = p.name
    dout = bg.add_parameter("dout")

    if p is P.make_tuple:
        items = [bg.apply(P.tuple_getitem, dout, i) for i in range(arity)]
    elif p.bprop == "zeros":
        items = [bg.apply(P.zeros_like, prm) for prm in params]
    elif callable(p.bprop):
        from .parser import parse_function

        bpg = parse_function(p.bprop)
        tup = bg.apply(bpg, *params, out, dout)
        items = [bg.apply(P.tuple_getitem, tup, i) for i in range(arity)]
    else:
        raise TypeError(f"primitive {p.name} has no backpropagator")

    bg.set_return(bg.apply(P.make_tuple, newenv, *items))
    jp.set_return(jp.apply(P.make_tuple, out, Constant(bg)))
    _JPRIM_CACHE[key] = jp
    return jp


# ---------------------------------------------------------------------------
# J of graphs (family-wide transform)
# ---------------------------------------------------------------------------


class JTransformer:
    def __init__(self, root: Graph) -> None:
        self.root = root
        self.family = graph_and_descendants(root)
        self.graph_map: dict[Graph, Graph] = {}  # g -> ▶g
        self.bprop_graphs: dict[Graph, Graph] = {}  # g -> ◀g
        self.node_map: dict[int, Node] = {}  # primal node id -> forward-value node
        self.bprop_map: dict[int, Node] = {}  # primal apply id -> backpropagator node
        self._fv_cache: dict[Graph, list[Node]] = {}

    # -- public ---------------------------------------------------------
    def transform(self) -> Graph:
        cached = self.root.transforms.get("J")
        if cached is not None:
            return cached
        for g in self.family:
            jg = Graph(f"▶{g.name}")
            jg.primal = g
            jg.flags["is_j"] = True
            self.graph_map[g] = jg
            for prm in g.parameters:
                jp = jg.add_parameter(prm.debug_name)
                self.node_map[prm._id] = jp
            bg = Graph(f"◀{g.name}")
            bg.primal = g
            bg.flags["is_bprop"] = True
            self.bprop_graphs[g] = bg
        for g in self.family:
            self._build_forward(g)
        for g in self.family:
            self._build_backward(g)
        for g in self.family:
            g.transforms["J"] = self.graph_map[g]
        return self.graph_map[self.root]

    # -- forward ----------------------------------------------------------
    def _fwd_fn(self, node: Node, call_arity: int | None) -> Node:
        """Transform a node used in *function position*."""
        if isinstance(node, Constant):
            v = node.value
            if isinstance(v, Primitive):
                return Constant(Jprim(v, call_arity))
            if isinstance(v, Graph):
                return Constant(self.graph_map[v])
            raise TypeError(f"cannot call non-function constant {v!r}")
        return self._fwd(node)

    def _fwd(self, node: Node) -> Node:
        """Forward-value node for a primal node (iterative post-order)."""
        if node._id in self.node_map:
            return self.node_map[node._id]
        stack: list[tuple[Node, bool]] = [(node, False)]
        while stack:
            cur, ready = stack.pop()
            if cur._id in self.node_map:
                continue
            if isinstance(cur, Constant):
                v = cur.value
                if isinstance(v, Graph):
                    new: Node = Constant(self.graph_map[v], cur.debug_name)
                elif isinstance(v, Primitive):
                    # primitive passed as a value (e.g. HOF argument)
                    new = Constant(Jprim(v, None), cur.debug_name)
                else:
                    new = Constant(v, cur.debug_name)
                self.node_map[cur._id] = new
                continue
            if isinstance(cur, Parameter):
                raise RuntimeError(f"parameter {cur!r} not pre-mapped (outside family?)")
            assert isinstance(cur, Apply)
            if not ready:
                stack.append((cur, True))
                for inp in cur.inputs[1:]:
                    if inp._id not in self.node_map:
                        stack.append((inp, False))
                fn = cur.inputs[0]
                if not isinstance(fn, Constant) and fn._id not in self.node_map:
                    stack.append((fn, False))
                continue
            jg = self.graph_map[cur.graph]
            jf = self._fwd_fn(cur.inputs[0], len(cur.inputs) - 1)
            jargs = [self.node_map[a._id] for a in cur.inputs[1:]]
            japp = Apply([jf, *jargs], jg, debug_name=f"J_{cur.debug_name}")
            fw = Apply([Constant(P.tuple_getitem), japp, Constant(0)], jg, cur.debug_name)
            bp = Apply(
                [Constant(P.tuple_getitem), japp, Constant(1)], jg, f"bprop_{cur.debug_name}"
            )
            self.node_map[cur._id] = fw
            self.bprop_map[cur._id] = bp
        return self.node_map[node._id]

    def _build_forward(self, g: Graph) -> None:
        jg = self.graph_map[g]
        ret = self._fwd(g.return_)
        # also force-transform applies only reachable through nested graphs
        for n in dfs_nodes(g.return_):
            if isinstance(n, Apply) and n.graph in self.family:
                self._fwd(n)
        jg.set_return(jg.apply(P.make_tuple, ret, Constant(self.bprop_graphs[g])))

    # -- backward ---------------------------------------------------------
    def _fvs(self, g: Graph) -> list[Node]:
        if g not in self._fv_cache:
            self._fv_cache[g] = free_variables(g)
        return self._fv_cache[g]

    def _adjoint_order(self, g: Graph) -> list[Apply]:
        """g-owned apply nodes, topo-sorted with closure-capture edges:
        an apply that references a nested graph depends on the g-owned free
        variables that graph captures (closure creation 'uses' them)."""
        owned = [
            n
            for n in dfs_nodes(g.return_)
            if isinstance(n, Apply) and n.graph is g
        ]
        deps: dict[int, list[Node]] = {}
        for u in owned:
            d: list[Node] = []
            for inp in u.inputs:
                if inp.graph is g:
                    d.append(inp)
                elif is_constant_graph(inp) and inp.value in self.family:
                    d.extend(v for v in self._fvs(inp.value) if v.graph is g)
            deps[u._id] = d
        order: list[Apply] = []
        state: dict[int, int] = {}  # 0 visiting, 1 done

        for root in owned:
            if root._id in state:
                continue
            stack: list[tuple[Node, bool]] = [(root, False)]
            while stack:
                cur, ready = stack.pop()
                if ready:
                    state[cur._id] = 1
                    order.append(cur)  # type: ignore[arg-type]
                    continue
                st = state.get(cur._id)
                if st is not None:
                    continue
                state[cur._id] = 0
                stack.append((cur, True))
                for dep in deps.get(cur._id, ()):
                    if isinstance(dep, Apply) and dep.graph is g and state.get(dep._id) is None:
                        stack.append((dep, False))
        return order

    def _build_backward(self, g: Graph) -> None:
        bg = self.bprop_graphs[g]
        dout = bg.add_parameter("dout")
        contribs: dict[int, list[Node]] = {}
        env_contribs: dict[int, tuple[Node, list[Node]]] = {}
        sens_memo: dict[int, Node] = {}

        def fold(vals: list[Node]) -> Node:
            acc = vals[0]
            for v in vals[1:]:
                acc = bg.apply(P.gadd, acc, v)
            return acc

        def sens_of(primal: Node) -> Node:
            if primal._id in sens_memo:
                return sens_memo[primal._id]
            lst = contribs.get(primal._id)
            if lst:
                s = fold(lst)
            else:
                s = bg.apply(P.zeros_like, self.node_map[primal._id])
            sens_memo[primal._id] = s
            return s

        def route(primal: Node, val: Node) -> None:
            if isinstance(primal, Constant):
                v = primal.value
                if isinstance(v, Graph) and v in self.family:
                    # adjoint of closure creation: unpack free-var grads
                    for fv in self._fvs(v):
                        fw_fv = self.node_map[fv._id]
                        key = Constant(SymbolicKey(fw_fv))
                        dflt = bg.apply(P.zeros_like, fw_fv)
                        dv = bg.apply(P.env_getitem, val, key, dflt)
                        route(fv, dv)
                return  # sensitivities of data/primitive constants: discarded
            if primal.graph is g:
                contribs.setdefault(primal._id, []).append(val)
            else:
                # free variable of g: goes into the returned env
                ec = env_contribs.setdefault(primal._id, (primal, []))
                ec[1].append(val)

        route(g.return_, dout)

        for u in reversed(self._adjoint_order(g)):
            du = sens_of(u)
            ct = bg.apply(self.bprop_map[u._id], du, debug_name=f"d_{u.debug_name}")
            for i, inp in enumerate(u.inputs):
                route(inp, bg.apply(P.tuple_getitem, ct, i))

        env_node: Node = Constant(newenv)
        for nid in sorted(env_contribs):
            primal, vals = env_contribs[nid]
            fw = self.node_map[primal._id]
            env_node = bg.apply(
                P.env_setitem, env_node, Constant(SymbolicKey(fw)), fold(vals)
            )
        param_sens = [sens_of(prm) for prm in g.parameters]
        bg.set_return(bg.apply(P.make_tuple, env_node, *param_sens))


def J(g: Graph) -> Graph:
    """Transform ``g`` into ``▶g`` (cached on the graph)."""
    cached = g.transforms.get("J")
    if cached is not None:
        return cached
    return JTransformer(g).transform()


# ---------------------------------------------------------------------------
# User-facing graph builders
# ---------------------------------------------------------------------------


def _seed_cotangent(gg: Graph, out: Node) -> Node:
    """The seed ``d(out)``: ones *at the output's shape*.  A bare scalar
    1.0 relies on broadcasting through every backpropagator — sound for
    scalar outputs, but under reverse-over-reverse the outer adjoint's
    output is an array and a scalar seed leaves shape-mismatched zero
    terms that the optimizer's ``gadd_zero`` must then treat as
    broadcasts.  ``broadcast_to(cast(1, dtype), shape)`` is exact and
    folds to a no-op for scalar outputs (the ``broadcast_noop`` rule)."""
    one = gg.apply(P.cast, 1.0, gg.apply(P.dtype_of, out))
    return gg.apply(P.broadcast_to, one, gg.apply(P.shape, out))


def build_grad_graph(g: Graph, wrt: int | tuple[int, ...] = 0) -> Graph:
    """``grad(f)``: a graph computing df/dx_wrt for a scalar-output ``f``."""
    from repro.obs import trace as obs_trace

    with obs_trace.span("ad.grad", graph=g.name):
        return _build_grad_graph_body(g, wrt)


def _build_grad_graph_body(g: Graph, wrt: int | tuple[int, ...]) -> Graph:
    jg = J(g)
    gg = Graph(f"grad_{g.name}")
    params = [gg.add_parameter(p.debug_name) for p in g.parameters]
    japp = gg.apply(jg, *params)
    out = gg.apply(P.tuple_getitem, japp, 0)
    bp = gg.apply(P.tuple_getitem, japp, 1)
    grads = gg.apply(bp, _seed_cotangent(gg, out))
    if isinstance(wrt, int):
        gg.set_return(gg.apply(P.tuple_getitem, grads, wrt + 1))
    else:
        items = [gg.apply(P.tuple_getitem, grads, i + 1) for i in wrt]
        gg.set_return(gg.apply(P.make_tuple, *items))
    gg.primal = g
    return gg


def build_value_and_grad_graph(g: Graph, wrt: int | tuple[int, ...] = 0) -> Graph:
    jg = J(g)
    gg = Graph(f"value_and_grad_{g.name}")
    params = [gg.add_parameter(p.debug_name) for p in g.parameters]
    japp = gg.apply(jg, *params)
    out = gg.apply(P.tuple_getitem, japp, 0)
    bp = gg.apply(P.tuple_getitem, japp, 1)
    grads = gg.apply(bp, _seed_cotangent(gg, out))
    if isinstance(wrt, int):
        gnode = gg.apply(P.tuple_getitem, grads, wrt + 1)
    else:
        gnode = gg.apply(P.make_tuple, *[gg.apply(P.tuple_getitem, grads, i + 1) for i in wrt])
    gg.set_return(gg.apply(P.make_tuple, out, gnode))
    gg.primal = g
    return gg


def build_vjp_graph(g: Graph) -> Graph:
    """``vjp(f)``: graph ``(x1..xn, dout) -> (dx1..dxn)`` — arbitrary output
    cotangent (non-scalar outputs)."""
    jg = J(g)
    gg = Graph(f"vjp_{g.name}")
    params = [gg.add_parameter(p.debug_name) for p in g.parameters]
    dout = gg.add_parameter("dout")
    japp = gg.apply(jg, *params)
    bp = gg.apply(P.tuple_getitem, japp, 1)
    grads = gg.apply(bp, dout)
    items = [gg.apply(P.tuple_getitem, grads, i + 1) for i in range(len(params))]
    gg.set_return(gg.apply(P.make_tuple, *items))
    gg.primal = g
    return gg
