"""Operator-overloading (OO) tape-based reverse AD — the paper's baseline.

Paper §2.1.1: "All primitives are overloaded so that they additionally
perform a tracing operation: The primitive is logged onto a 'tape', along
with its inputs … Derivatives can be calculated by walking this tape in
reverse."  And the criticism: "since the program is traced and reversed at
runtime, OO incurs overhead on each function call … OO also does not allow
for ahead-of-time optimizations on the adjoint program."

This module is that baseline, PyTorch/Autograd-style: a ``Box`` wrapper
with overloaded operators, a per-call tape, and an interpreted backward
walk.  ``benchmarks/bench_ad_overhead.py`` measures its per-call overhead
against the ST pipeline — reproducing the paper's OO-vs-ST comparison
(e.g. the scalar-workload pathology of footnote 1).
"""

from __future__ import annotations

from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from .primitives import _impl_unbroadcast

__all__ = [
    "Box", "oo_grad", "oo_value_and_grad", "tanh", "exp", "log", "sigmoid",
    "relu", "reduce_sum", "matmul",
]


class _Tape:
    __slots__ = ("entries",)

    def __init__(self) -> None:
        # (out_box, input_boxes, vjp) — vjp(dout) -> tuple of input grads
        self.entries: list[tuple["Box", tuple, Callable]] = []


class Box:
    """A traced value.  Every overloaded operation appends to the tape."""

    __slots__ = ("value", "tape")

    def __init__(self, value: Any, tape: _Tape) -> None:
        self.value = value
        self.tape = tape

    # -- binary ops ----------------------------------------------------
    def __add__(self, o):  # noqa: D105
        return _record(self.tape, _val(self) + _val(o), (self, o),
                       lambda d, x=self, y=o: (_unb(d, x), _unb(d, y)))

    __radd__ = __add__

    def __sub__(self, o):
        return _record(self.tape, _val(self) - _val(o), (self, o),
                       lambda d, x=self, y=o: (_unb(d, x), _unb(-d, y)))

    def __rsub__(self, o):
        return _record(self.tape, _val(o) - _val(self), (self, o),
                       lambda d, x=self, y=o: (_unb(-d, x), _unb(d, y)))

    def __mul__(self, o):
        return _record(self.tape, _val(self) * _val(o), (self, o),
                       lambda d, x=self, y=o: (_unb(d * _val(y), x), _unb(d * _val(x), y)))

    __rmul__ = __mul__

    def __truediv__(self, o):
        return _record(self.tape, _val(self) / _val(o), (self, o),
                       lambda d, x=self, y=o: (_unb(d / _val(y), x),
                                               _unb(-d * _val(x) / (_val(y) ** 2), y)))

    def __pow__(self, o):
        out = _val(self) ** _val(o)
        return _record(self.tape, out, (self, o),
                       lambda d, x=self, y=o, ov=out: (
                           _unb(d * _val(y) * _val(x) ** (_val(y) - 1), x),
                           _unb(d * ov * jnp.log(_val(x)), y)))

    def __neg__(self):
        return _record(self.tape, -_val(self), (self,), lambda d: (-d,))

    def __matmul__(self, o):
        return _record(self.tape, _val(self) @ _val(o), (self, o),
                       lambda d, x=self, y=o: (d @ jnp.swapaxes(_val(y), -1, -2),
                                               jnp.swapaxes(_val(x), -1, -2) @ d))

    # comparisons produce plain values (no gradient)
    def __lt__(self, o):
        return _val(self) < _val(o)

    def __gt__(self, o):
        return _val(self) > _val(o)

    def __le__(self, o):
        return _val(self) <= _val(o)

    def __ge__(self, o):
        return _val(self) >= _val(o)


def _val(x: Any) -> Any:
    return x.value if isinstance(x, Box) else x


def _unb(d: Any, x: Any) -> Any:
    """Reverse broadcasting for a gradient flowing to ``x``."""
    v = _val(x)
    shp = () if isinstance(v, (int, float)) else tuple(np.shape(v))
    return _impl_unbroadcast(d, shp)


def _record(tape: _Tape, value: Any, inputs: tuple, vjp: Callable) -> Box:
    out = Box(value, tape)
    tape.entries.append((out, inputs, vjp))
    return out


# -- function-style ops ------------------------------------------------------


def _unary(fn, dfn):
    def op(x):
        if not isinstance(x, Box):
            return fn(x)
        out = fn(x.value)
        return _record(x.tape, out, (x,), lambda d, xv=x.value, ov=out: (dfn(d, xv, ov),))

    return op


tanh = _unary(jnp.tanh, lambda d, x, o: d * (1 - o * o))
exp = _unary(jnp.exp, lambda d, x, o: d * o)
log = _unary(jnp.log, lambda d, x, o: d / x)
sigmoid = _unary(lambda x: 1 / (1 + jnp.exp(-x)), lambda d, x, o: d * o * (1 - o))
relu = _unary(lambda x: jnp.maximum(x, 0), lambda d, x, o: d * (x > 0))


def reduce_sum(x, axes=None, keepdims=False):
    if not isinstance(x, Box):
        return jnp.sum(x, axis=axes, keepdims=keepdims)
    out = jnp.sum(x.value, axis=axes, keepdims=keepdims)

    def vjp(d, xv=x.value):
        shp = np.shape(out) if keepdims else _kd_shape(xv, axes)
        return (jnp.broadcast_to(jnp.reshape(d, shp), np.shape(xv)),)

    return _record(x.tape, out, (x,), vjp)


def _kd_shape(x, axes):
    shp = list(np.shape(x))
    if axes is None:
        return tuple(1 for _ in shp)
    axes = (axes,) if isinstance(axes, int) else axes
    for a in axes:
        shp[a % len(shp)] = 1
    return tuple(shp)


def matmul(a, b):
    tape = a.tape if isinstance(a, Box) else b.tape
    return Box(0, tape).__class__.__matmul__(a if isinstance(a, Box) else Box(a, tape), b)


# -- driver -------------------------------------------------------------------


def oo_value_and_grad(fn: Callable, wrt: int | tuple[int, ...] = 0) -> Callable:
    """OO/tape value-and-gradient: traces at every call (that is the point)."""

    wrt_t = (wrt,) if isinstance(wrt, int) else tuple(wrt)

    def run(*args):
        tape = _Tape()
        boxes = [Box(a, tape) for a in args]
        out = fn(*boxes)
        out_v = _val(out)
        grads: dict[int, Any] = {id(out): jnp.ones_like(out_v) if hasattr(out_v, "shape") else 1.0}
        for out_box, inputs, vjp in reversed(tape.entries):
            d = grads.pop(id(out_box), None)
            if d is None:
                continue
            for inp, g in zip(inputs, vjp(d)):
                if not isinstance(inp, Box):
                    continue
                k = id(inp)
                grads[k] = g if k not in grads else grads[k] + g
        outs = tuple(grads.get(id(boxes[i]), _zeros_for(args[i])) for i in wrt_t)
        return out_v, (outs[0] if isinstance(wrt, int) else outs)

    return run


def _zeros_for(v):
    return jnp.zeros_like(v) if hasattr(v, "shape") else 0.0


def oo_grad(fn: Callable, wrt: int | tuple[int, ...] = 0) -> Callable:
    vag = oo_value_and_grad(fn, wrt)

    def run(*args):
        return vag(*args)[1]

    return run
