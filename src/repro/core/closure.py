"""Closure elimination: call-graph analysis, defunctionalization, and
structured-recursion lowering (the "compile the closures" tier).

The paper's argument for a closure-supporting graph IR is that ST-based AD
needs no tape *and* its output is an ordinary program, amenable to
ahead-of-time optimization — including adjoints of adjoints and programs
with control flow.  Before this module, any graph that kept a residual
graph value after optimization (recursion from parsed loops, higher-order
calls the inliner could not resolve) silently fell back to the reference
VM.  This module closes most of that gap:

* :func:`analyze_blockers` — the structured version of
  ``lowering.lowering_blockers``: every reason a graph cannot lower is a
  :class:`FallbackReason` with a machine-readable ``kind``
  (``recursion-shape`` / ``higher-order-residual`` / ``free-variable`` /
  ``non-array-param`` / ``no-return``), surfaced through ``OptStats`` and
  the benchmark JSON so the CI fallback counter is debuggable.

* :func:`specialize_recursive_calls` — defunctionalization (Shaikhha et
  al.): a call of a *recursive* graph that passes a graph- or
  primitive-valued constant gets a per-constant specialized clone with
  that parameter bound.  The interior call sites become first-order, the
  inliner resolves them on the next wave, and the loop lowering below can
  then compile the recursion (``iterate(f, x, n)``-style programs).

* :func:`lower_loops` — structured-recursion lowering (Innes, *Don't
  Unroll Adjoint*): tail-recursive families in the canonical shape the
  parser emits (``header: switch(cond, body, exit)()``; ``body`` tail-calls
  the header, possibly through argument-carrying shims and nested
  switch diamonds) are rewritten into ``while_loop`` / ``scan_loop``
  primitive applies whose cond/step/exit are *closed first-order graphs*.
  The loop-invariant free variables — the closure environment of the loop
  family — are threaded as trailing arguments, and the carry is exactly
  the header's parameter list.  ``scan_loop`` (→ ``jax.lax.scan``) is
  selected when the trip count is statically known (the fold-shaped
  ``for i in range(...)`` case); everything else becomes
  ``jax.lax.while_loop``.

Nested loops (the inner family tail-calls the outer header, so both
live in one SCC) lower by emitting the inner ``while_loop``/``scan_loop``
*inside* the outer step graph, and non-tail self-recursion in the
single-call affine shape (``x * f(x, n-1)``) lowers as a forward
trip-count loop plus a reversed accumulator loop.  What still genuinely
needs the VM: break-style conditional exits from a loop body, non-affine
or multi-call non-tail recursion, and closures selected by ``switch`` on
traced conditions.  ``docs/pipeline.md`` keeps the matrix.
"""

from __future__ import annotations

import math
from typing import Any

from . import primitives as P
from .infer import AArray, AScalar, ATuple, _widen
from .ir import (
    Apply,
    Constant,
    Graph,
    GraphCloner,
    Node,
    Parameter,
    dfs_nodes,
    free_variables,
    graph_and_descendants,
    is_apply,
    is_constant_graph,
)
from .primitives import LOOP_GRAPH_ARGS, Primitive

__all__ = [
    "FallbackReason",
    "analyze_blockers",
    "specialize_recursive_calls",
    "lower_loops",
    "LoopReport",
]


# ---------------------------------------------------------------------------
# Structured fallback reasons
# ---------------------------------------------------------------------------


class FallbackReason:
    """Why a graph stays on the VM: a machine-readable kind + detail."""

    #: the recursion is not in a shape the loop lowering recognizes
    RECURSION = "recursion-shape"
    #: a function value survived optimization (closure/higher-order call)
    HIGHER_ORDER = "higher-order-residual"
    #: a node owned by another graph (the graph is still nested)
    FREE_VARIABLE = "free-variable"
    #: a loop carry that is not an array/scalar value
    NON_ARRAY = "non-array-param"
    NO_RETURN = "no-return"

    __slots__ = ("kind", "detail")

    def __init__(self, kind: str, detail: str) -> None:
        self.kind = kind
        self.detail = detail

    def __str__(self) -> str:
        return f"[{self.kind}] {self.detail}"

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FallbackReason({self.kind!r}, {self.detail!r})"

    def as_dict(self) -> dict:
        return {"kind": self.kind, "detail": self.detail}


def _reaches_itself(g: Graph) -> bool:
    return any(is_constant_graph(n) and n.value is g for n in dfs_nodes(g.return_))


def _is_loop_graph_slot(user: Node, idx: int) -> bool:
    """True iff ``(user, idx)`` is a legal graph-valued slot: one of the
    leading sub-function arguments of a loop primitive apply."""
    if not isinstance(user, Apply):
        return False
    fn = user.fn
    if not (isinstance(fn, Constant) and isinstance(fn.value, Primitive)):
        return False
    n = LOOP_GRAPH_ARGS.get(fn.value.name)
    return n is not None and 1 <= idx <= n


def analyze_blockers(graph: Graph, _depth: int = 0) -> list[FallbackReason]:
    """Structured reasons ``graph`` cannot lower (empty list: lowerable).

    Mirrors what ``lowering.lower_graph`` can emit: straight-line applies
    of constant primitives over graph-owned nodes, plus loop primitive
    applies whose leading arguments are *closed, recursively lowerable*
    graphs.  De-duplicated (first occurrence wins)."""
    if graph.return_ is None:
        return [FallbackReason(FallbackReason.NO_RETURN, "graph has no return node")]
    if _depth > 8:
        return [
            FallbackReason(
                FallbackReason.RECURSION, f"loop nesting too deep below {graph.name!r}"
            )
        ]
    reasons: dict[str, FallbackReason] = {}

    def add(kind: str, detail: str) -> None:
        reasons.setdefault(f"{kind}:{detail}", FallbackReason(kind, detail))

    def classify_graph_value(g: Graph) -> None:
        if _reaches_itself(g):
            add(
                FallbackReason.RECURSION,
                f"graph-valued constant {g.name!r} survived optimization "
                "(residual recursion)",
            )
        else:
            add(
                FallbackReason.HIGHER_ORDER,
                f"graph-valued constant {g.name!r} survived optimization "
                "(closure value)",
            )

    seen: set[int] = set()
    stack: list[Node] = [graph.return_]
    while stack:
        node = stack.pop()
        if id(node) in seen:
            continue
        seen.add(id(node))
        if isinstance(node, Constant):
            if isinstance(node.value, Graph):
                if node.users and all(
                    _is_loop_graph_slot(u, i) for u, i in node.users
                ):
                    # loop sub-function: must itself be closed + lowerable
                    for sub in analyze_blockers(node.value, _depth + 1):
                        add(sub.kind, f"in loop graph {node.value.name!r}: {sub.detail}")
                else:
                    classify_graph_value(node.value)
            continue
        if isinstance(node, Parameter):
            if node.graph is not graph:
                add(
                    FallbackReason.FREE_VARIABLE,
                    f"free parameter {node!r} of graph {node.graph and node.graph.name!r}",
                )
            continue
        assert isinstance(node, Apply)
        if node.graph is not graph:
            add(
                FallbackReason.FREE_VARIABLE,
                f"free variable: apply node owned by nested graph "
                f"{node.graph and node.graph.name!r}",
            )
        fn = node.fn
        if not (isinstance(fn, Constant) and isinstance(fn.value, Primitive)):
            if is_constant_graph(fn):
                classify_graph_value(fn.value)
            else:
                add(
                    FallbackReason.HIGHER_ORDER,
                    f"non-primitive callee {fn!r} (higher-order or graph call)",
                )
        stack.extend(node.inputs)
    return list(reasons.values())


# ---------------------------------------------------------------------------
# Defunctionalization: specialize recursive calls on function constants
# ---------------------------------------------------------------------------


def _family_recursive(g: Graph, memo: dict[int, bool]) -> bool:
    hit = memo.get(g._id)
    if hit is None:
        hit = any(_reaches_itself(d) for d in graph_and_descendants(g))
        memo[g._id] = hit
    return hit


def _passes_through(h: Graph, i: int, value: Any) -> bool:
    """Every call of ``h`` inside its own family must keep argument ``i``
    stable: the parameter itself, or a constant equal to ``value``."""
    p = h.parameters[i]
    for n in dfs_nodes(h.return_):
        if isinstance(n, Apply) and is_constant_graph(n.fn) and n.fn.value is h:
            if i >= len(n.args):
                return False
            a = n.args[i]
            if a is p:
                continue
            if isinstance(a, Constant) and a.value is value:
                continue
            return False
    return True


def _drop_arg(call: Apply, i: int, root: Graph) -> None:
    new = Apply(
        [call.inputs[0]] + call.args[:i] + call.args[i + 1:],
        call.graph,
        call.debug_name,
    )
    new.abstract = call.abstract
    _replace(root, call, new)


def _replace(root: Graph, old: Node, new: Node) -> None:
    for user, idx in list(old.users):
        user.set_input(idx, new)
    for g in graph_and_descendants(root):
        if g.return_ is old:
            g.set_return(new)
    if isinstance(old, Apply):
        for i, inp in enumerate(old.inputs):
            inp.users.discard((old, i))


def specialize_recursive_calls(
    root: Graph, stats: Any = None, memo: dict | None = None
) -> bool:
    """Monomorphize recursive higher-order calls (defunctionalization).

    A call ``h(..., const_fn, ...)`` where ``h``'s family is recursive (so
    the inliner refuses it) and ``const_fn`` is a graph/primitive constant
    is rewritten to ``h′(...)`` — a clone of ``h``'s family with that
    parameter bound to the constant and dropped from every signature.  The
    now-constant interior call sites inline on the optimizer's next wave,
    which is what lets ``lower_loops`` compile higher-order recursion.

    ``memo`` caches specializations across calls (keyed by graph, position
    and constant identity); pass the same dict for one optimize run.
    """
    memo = memo if memo is not None else {}
    rec_memo: dict[int, bool] = {}
    changed = False
    for site in list(dfs_nodes(root.return_)):
        if not (isinstance(site, Apply) and is_constant_graph(site.fn)):
            continue
        h = site.fn.value
        if h.return_ is None or not _family_recursive(h, rec_memo):
            continue  # the plain inliner owns non-recursive calls
        if len(site.args) != len(h.parameters):
            continue
        for i, a in enumerate(site.args):
            if not (isinstance(a, Constant) and isinstance(a.value, (Graph, Primitive))):
                continue
            if isinstance(a.value, Graph) and a.value.return_ is None:
                continue
            if not _passes_through(h, i, a.value):
                continue
            key = (h._id, i, id(a.value))
            h2 = memo.get(key)
            if h2 is None:
                h2 = _specialize(h, i, a.value)
                memo[key] = h2
            new = Apply(
                [Constant(h2, h2.name)] + site.args[:i] + site.args[i + 1:],
                site.graph,
                site.debug_name,
            )
            new.abstract = site.abstract
            _replace(root, site, new)
            if stats is not None:
                stats.record_rule("defunctionalize_call")
            changed = True
            break  # site rewritten; further args handled on the next pass
    return changed


def _specialize(h: Graph, i: int, value: Any) -> Graph:
    label = getattr(value, "name", type(value).__name__)
    cloner = GraphCloner(h, relabel=f"[{label}]")
    h2 = cloner.clone()
    # the constant may be (a clone of) a family member — self-passing style
    if isinstance(value, Graph):
        value = cloner.graph_map.get(value, value)
    p = h2.parameters[i]
    const = Constant(value, p.debug_name)
    const.abstract = p.abstract
    for user, idx in list(p.users):
        user.set_input(idx, const)
    h2.parameters.pop(i)
    # drop the bound argument from every interior self-call
    for n in list(dfs_nodes(h2.return_)):
        if isinstance(n, Apply) and is_constant_graph(n.fn) and n.fn.value is h2:
            if i < len(n.args):
                _drop_arg(n, i, h2)
    return h2


# ---------------------------------------------------------------------------
# Structured-recursion lowering
# ---------------------------------------------------------------------------


class _LoopMismatch(Exception):
    """Internal signal: this recursive family is not loop-shaped."""

    def __init__(self, kind: str, detail: str) -> None:
        self.kind = kind
        self.detail = detail
        super().__init__(f"[{kind}] {detail}")


class LoopReport:
    __slots__ = ("lowered", "scans", "reasons")

    def __init__(self) -> None:
        self.lowered = 0
        self.scans = 0
        self.reasons: list[FallbackReason] = []


def _loop_family(h: Graph) -> set[Graph]:
    """Graphs mutually reachable with ``h`` through graph constants: the
    candidate loop family (header + body blocks that jump back to it)."""
    return {g for g in graph_and_descendants(h) if h in graph_and_descendants(g)}


def _widen_abstract(ab: Any) -> Any:
    if ab is None:
        return None
    try:
        return _widen(ab)
    except Exception:  # pragma: no cover - _widen is total on our domain
        return None


def _carryable(ab: Any) -> bool:
    """Can this value ride in a jax loop carry?  Arrays, numeric scalars,
    None units and tuples thereof; function values / environments / opaque
    statics cannot change identity per iteration."""
    if isinstance(ab, AArray):
        return True
    if isinstance(ab, AScalar):
        return ab.kind in ("int", "float", "bool", "none")
    if isinstance(ab, ATuple):
        return all(_carryable(e) for e in ab.elements)
    return False


class _CloneEnv:
    """Clone an expression DAG owned by loop-family graphs into ``target``,
    resolving header parameters and threaded free variables through
    ``env`` (node id → target-resident node; doubles as the memo).

    Graph constants from *outside* the family are kept, unless they
    capture family-owned (or remapped) nodes — nested closures like
    if-expression thunks — in which case the closure's family is
    deep-copied with its captures resolved into the target graph."""

    def __init__(
        self,
        target: Graph,
        fam: set[Graph],
        env: dict[int, Node],
        scope: set[Graph] | None = None,
    ) -> None:
        self.target = target
        self.fam = fam
        #: graphs whose owned nodes are cloned into ``target`` — the family
        #: plus the branch graph being traced (the exit block is *not* part
        #: of the mutually-recursive family but owns its own expression)
        self.scope = fam if scope is None else scope
        self.env = env
        self._captured_memo: dict[int, list[Node]] = {}

    def _captured(self, g: Graph) -> list[Node]:
        hit = self._captured_memo.get(g._id)
        if hit is None:
            hit = [
                n
                for n in free_variables(g)
                if (n.graph in self.scope) or (n._id in self.env)
            ]
            self._captured_memo[g._id] = hit
        return hit

    def clone(self, node: Node) -> Node:
        if node._id in self.env:
            return self.env[node._id]
        stack: list[tuple[Node, bool]] = [(node, False)]
        while stack:
            cur, ready = stack.pop()
            if cur._id in self.env:
                continue
            if isinstance(cur, Constant):
                v = cur.value
                if isinstance(v, Graph):
                    if v in self.fam:
                        raise _LoopMismatch(
                            FallbackReason.RECURSION,
                            f"loop graph {v.name!r} escapes as a first-class value",
                        )
                    captured = self._captured(v)
                    if captured and not ready:
                        stack.append((cur, True))
                        stack.extend(
                            (n, False) for n in captured if n._id not in self.env
                        )
                        continue
                    if captured:
                        new: Node = Constant(self._clone_closure(v), cur.debug_name)
                    else:
                        new = Constant(v, cur.debug_name)
                else:
                    new = Constant(v, cur.debug_name)
                new.abstract = cur.abstract
                self.env[cur._id] = new
                continue
            if isinstance(cur, Parameter):
                raise _LoopMismatch(
                    FallbackReason.RECURSION,
                    f"loop body references parameter {cur!r} of "
                    f"{cur.graph and cur.graph.name!r} outside its trace frame",
                )
            assert isinstance(cur, Apply)
            if cur.graph not in self.scope:
                raise _LoopMismatch(
                    FallbackReason.FREE_VARIABLE,
                    f"loop body references node {cur!r} outside the threaded "
                    "environment",
                )
            if ready:
                new_inputs = [self.env[i._id] for i in cur.inputs]
                new = Apply(new_inputs, self.target, cur.debug_name)
                new.abstract = _widen_abstract(cur.abstract)
                self.env[cur._id] = new
            else:
                stack.append((cur, True))
                for i in cur.inputs:
                    if i._id not in self.env:
                        stack.append((i, False))
        return self.env[node._id]

    def _clone_closure(self, g: Graph) -> Graph:
        cloner = GraphCloner(g, relabel="")
        for n in self._captured(g):
            cloner.node_map[n._id] = self.env[n._id]
        return cloner.clone()


def _graph_succs(g: Graph) -> set[Graph]:
    """Graphs referenced as constants by applies *owned* by ``g``."""
    out: set[Graph] = set()
    if g.return_ is None:
        return out
    for n in dfs_nodes(g.return_):
        if isinstance(n, Apply) and n.graph is g:
            for inp in n.inputs:
                if is_constant_graph(inp):
                    out.add(inp.value)
    return out


def _reach_excluding(starts: list[Graph], h: Graph) -> set[Graph]:
    """Graphs reachable from ``starts`` through graph constants, never
    entering ``h`` (the enclosing loop header)."""
    seen: set[Graph] = set()
    stack = list(starts)
    while stack:
        g = stack.pop()
        if g in seen or g is h:
            continue
        seen.add(g)
        stack.extend(_graph_succs(g))
    return seen


def _inner_family(c: Graph, h: Graph) -> set[Graph]:
    """The inner loop family headed by ``c``: graphs on a ``c``-cycle that
    avoids the enclosing header ``h``.  Empty when ``c`` only re-enters
    the outer loop (i.e. it is not itself a loop header)."""
    fwd = _reach_excluding(list(_graph_succs(c)), h)
    if c not in fwd:
        return set()
    return {g for g in fwd if c in _reach_excluding(list(_graph_succs(g)), h)}


def _family_free_vars(fam: set[Graph]) -> list[Node]:
    """Free variables of an *inner* loop family: nodes referenced from the
    family's bodies but owned outside it.  Unlike :func:`free_variables`
    this does not descend into graph constants outside ``fam`` (the
    continuation block that jumps back to the outer header is not part of
    the inner loop), so the outer back-edge never pollutes the capture
    set.  Deterministic order (DFS from each header, sorted by id)."""
    out: list[Node] = []
    seen: set[int] = set()
    stack: list[Node] = [
        g.return_ for g in sorted(fam, key=lambda g: g._id) if g.return_ is not None
    ]
    while stack:
        n = stack.pop()
        if n._id in seen:
            continue
        seen.add(n._id)
        if isinstance(n, Constant):
            if isinstance(n.value, Graph) and n.value in fam:
                if n.value.return_ is not None:
                    stack.append(n.value.return_)
            continue
        if n.graph not in fam:
            out.append(n)
            continue
        if isinstance(n, Apply):
            stack.extend(n.inputs)
    return out


def _match_header_switch(
    h: Graph, fam: set[Graph]
) -> tuple[Node, Graph, Graph, bool]:
    """Match the canonical loop-header shape ``return switch(c, tb, fb)()``
    and split the branches: returns ``(cond_node, loop_g, other_g,
    negate)`` where ``loop_g`` is the in-family branch and ``negate``
    records that the loop continues when the switch condition is false."""
    ret = h.return_
    if not (isinstance(ret, Apply) and len(ret.inputs) == 1):
        raise _LoopMismatch(
            FallbackReason.RECURSION, "header does not end in an applied switch"
        )
    sel = ret.inputs[0]
    if not (is_apply(sel, P.switch) and len(sel.args) == 3):
        raise _LoopMismatch(
            FallbackReason.RECURSION, "header does not end in an applied switch"
        )
    cond_node, tb, fb = sel.args
    if not (is_constant_graph(tb) and is_constant_graph(fb)):
        raise _LoopMismatch(
            FallbackReason.RECURSION, "switch branches are not graph constants"
        )
    t_loops = tb.value in fam
    f_loops = fb.value in fam
    if t_loops == f_loops:
        raise _LoopMismatch(
            FallbackReason.RECURSION,
            "both switch branches re-enter the loop"
            if t_loops
            else "no switch branch re-enters the loop",
        )
    loop_g, other_g = (tb.value, fb.value) if t_loops else (fb.value, tb.value)
    if loop_g.parameters or other_g.parameters:
        raise _LoopMismatch(FallbackReason.RECURSION, "switch branch takes parameters")
    return cond_node, loop_g, other_g, not t_loops


#: trace budget: loop-block entries per site (guards against irreducible
#: control flow — e.g. a nested loop whose family reaches this header)
_MAX_TRACE = 200


class _LoopBuilder:
    """Match one entry call of a tail-recursive family and build the
    closed cond/step/exit graphs for the loop primitives."""

    def __init__(
        self,
        site: Apply,
        h: Graph | None = None,
        fam: set[Graph] | None = None,
        fvs: list[Node] | None = None,
    ) -> None:
        self.site = site
        self.h: Graph = h if h is not None else site.fn.value
        self.fam = fam if fam is not None else _loop_family(self.h)
        #: dead-carry elimination: a header parameter with no users (the
        #: parser threads not-yet-bound variables as ``None`` placeholders
        #: that are written on the back-edge but never read) is dropped
        #: from the carry — it has no jax-typeable value and no effect
        self.live = [i for i, p in enumerate(self.h.parameters) if p.users]
        self.k = len(self.live)
        self.fvs = fvs if fvs is not None else free_variables(self.h)
        self._steps = 0

    def entry_args(self, args: list[Node]) -> list[Node]:
        """Filter an entry argument list down to the live carry slots."""
        return [args[i] for i in self.live]

    def _check_carries(self) -> None:
        for i in self.live:
            p = self.h.parameters[i]
            if not _carryable(p.abstract):
                raise _LoopMismatch(
                    FallbackReason.NON_ARRAY,
                    f"loop carry {p.debug_name or p!r} is not an array value "
                    f"({p.abstract!r})",
                )

    def build(self) -> tuple[Graph, Graph, Graph]:
        if len(self.site.args) != len(self.h.parameters):
            raise _LoopMismatch(FallbackReason.RECURSION, "entry call arity mismatch")
        cond_node, loop_g, exit_g, negate = _match_header_switch(self.h, self.fam)
        self._check_carries()
        cg = self._build_cond(cond_node, negate)
        sg = self._build_step(loop_g)
        eg = self._fresh("loop_exit")
        eg.set_return(
            _CloneEnv(
                eg, self.fam, self._base_env(eg), scope=self.fam | {exit_g}
            ).clone(exit_g.return_)
        )
        return cg, sg, eg

    def build_inner(self) -> tuple[Graph, Graph, Graph, Graph]:
        """Build cond/step graphs for an *inner* loop header reached while
        tracing an enclosing loop body.  The non-looping switch branch is
        not a value exit here — it is the continuation block that jumps
        back to the outer header — so the exit graph is an identity
        returning the final carry tuple, and the continuation is handed
        back to the outer trace."""
        cond_node, loop_g, cont_g, negate = _match_header_switch(self.h, self.fam)
        self._check_carries()
        cg = self._build_cond(cond_node, negate)
        sg = self._build_step(loop_g)
        eg = self._fresh("loop_exit")
        mt = eg.apply(P.make_tuple, *eg.parameters[: self.k])
        mt.abstract = ATuple(tuple(p.abstract for p in eg.parameters[: self.k]))
        eg.set_return(mt)
        return cg, sg, eg, cont_g

    def _build_cond(self, cond_node: Node, negate: bool) -> Graph:
        cg = self._fresh("loop_cond")
        c = _CloneEnv(cg, self.fam, self._base_env(cg)).clone(cond_node)
        if negate:
            neg = cg.apply(P.bool_not, c)
            neg.abstract = AScalar("bool")
            c = neg
        cg.set_return(c)
        return cg

    def _build_step(self, loop_g: Graph) -> Graph:
        sg = self._fresh("loop_step")
        exprs = self._trace(sg, self._base_env(sg), loop_g)
        mt = sg.apply(P.make_tuple, *exprs)
        mt.abstract = ATuple(
            tuple(
                e.abstract
                if e.abstract is not None
                else _widen_abstract(self.h.parameters[i].abstract)
                for e, i in zip(exprs, self.live)
            )
        )
        sg.set_return(mt)
        return sg

    def _fresh(self, tag: str) -> Graph:
        g = Graph(f"{self.h.name}:{tag}")
        for i in self.live:
            p = self.h.parameters[i]
            np_ = g.add_parameter(p.debug_name)
            np_.abstract = _widen_abstract(p.abstract)
        for j, v in enumerate(self.fvs):
            np_ = g.add_parameter(v.debug_name or f"fv{j}")
            np_.abstract = _widen_abstract(v.abstract)
        return g

    def _base_env(self, g: Graph) -> dict[int, Node]:
        env: dict[int, Node] = {}
        for i, np_ in zip(self.live, g.parameters[: self.k]):
            env[self.h.parameters[i]._id] = np_
        for v, np_ in zip(self.fvs, g.parameters[self.k:]):
            env[v._id] = np_
        return env

    def _trace(self, target: Graph, env: dict[int, Node], g: Graph) -> list[Node]:
        """Symbolically execute loop block ``g`` down to the back-edge,
        returning the k cloned next-carry expressions.  Handles chains of
        argument-carrying tail calls (the for-loop ``incr`` shim, if/else
        rejoin blocks) and switch diamonds whose branches both loop."""
        self._steps += 1
        if self._steps > _MAX_TRACE:
            raise _LoopMismatch(
                FallbackReason.RECURSION,
                "loop control flow too complex (trace budget exceeded — "
                "nested or irreducible recursion)",
            )
        ret = g.return_
        if not isinstance(ret, Apply):
            raise _LoopMismatch(
                FallbackReason.RECURSION, f"loop block {g.name!r} returns a non-call"
            )
        ce = _CloneEnv(target, self.fam, env)
        fn = ret.inputs[0]
        if is_constant_graph(fn):
            callee = fn.value
            if callee is self.h:
                if len(ret.args) != len(self.h.parameters):
                    raise _LoopMismatch(
                        FallbackReason.RECURSION, "back-edge arity mismatch"
                    )
                return [ce.clone(ret.args[i]) for i in self.live]
            if callee in self.fam:
                if len(ret.args) != len(callee.parameters):
                    raise _LoopMismatch(
                        FallbackReason.RECURSION, "tail-call arity mismatch"
                    )
                inner_fam = _inner_family(callee, self.h)
                if inner_fam:
                    return self._trace_inner(target, env, ce, ret, callee, inner_fam)
                env2 = dict(env)
                for p, a in zip(callee.parameters, [ce.clone(a) for a in ret.args]):
                    env2[p._id] = a
                return self._trace(target, env2, callee)
            raise _LoopMismatch(
                FallbackReason.RECURSION,
                f"loop body exits through {callee.name!r} "
                "(break-style control flow)",
            )
        if (
            isinstance(fn, Apply)
            and is_apply(fn, P.switch)
            and len(fn.args) == 3
            and len(ret.args) == 0
        ):
            c, t, f = fn.args
            if not (is_constant_graph(t) and is_constant_graph(f)):
                raise _LoopMismatch(
                    FallbackReason.RECURSION, "switch branches are not graph constants"
                )
            tg, fg = t.value, f.value
            if tg not in self.fam or fg not in self.fam:
                raise _LoopMismatch(
                    FallbackReason.RECURSION,
                    "conditional exit from the loop body (break-style control flow)",
                )
            if tg.parameters or fg.parameters:
                raise _LoopMismatch(
                    FallbackReason.RECURSION, "switch branch takes parameters"
                )
            cnode = ce.clone(c)
            ta = self._trace(target, dict(env), tg)
            fa = self._trace(target, dict(env), fg)
            out: list[Node] = []
            for i, (x, y) in enumerate(zip(ta, fa)):
                s = target.apply(P.switch, cnode, x, y)
                s.abstract = _widen_abstract(self.h.parameters[self.live[i]].abstract)
                out.append(s)
            return out
        raise _LoopMismatch(
            FallbackReason.RECURSION,
            f"unrecognized loop-block return in {g.name!r}",
        )

    def _trace_inner(
        self,
        target: Graph,
        env: dict[int, Node],
        ce: _CloneEnv,
        ret: Apply,
        callee: Graph,
        inner_fam: set[Graph],
    ) -> list[Node]:
        """The loop body tail-calls an *inner* loop header: build the inner
        loop's closed graphs, emit its ``while_loop``/``scan_loop`` apply
        inside the outer step graph, bind the inner carries to getitems of
        its result tuple, and continue the outer trace through the inner
        loop's continuation block (which holds the outer back-edge)."""
        ib = _LoopBuilder(ret, h=callee, fam=inner_fam, fvs=_family_free_vars(inner_fam))
        icg, isg, ieg, cont_g = ib.build_inner()
        if cont_g not in self.fam:
            raise _LoopMismatch(
                FallbackReason.RECURSION,
                f"inner loop {callee.name!r} continues into {cont_g.name!r} "
                "outside the loop family (break-style control flow)",
            )
        args = [ce.clone(a) for a in ib.entry_args(list(ret.args))]
        fv_args = [ce.clone(v) for v in ib.fvs]
        n_iters = _static_trip_count(ib.entry_args(list(ret.args)), icg, isg, ib.k)
        if n_iters is not None:
            inner = target.apply(
                P.scan_loop,
                Constant(isg, isg.name),
                Constant(ieg, ieg.name),
                n_iters,
                ib.k,
                *args,
                *fv_args,
                debug_name=f"scan_{callee.name}",
            )
        else:
            inner = target.apply(
                P.while_loop,
                Constant(icg, icg.name),
                Constant(isg, isg.name),
                Constant(ieg, ieg.name),
                ib.k,
                *args,
                *fv_args,
                debug_name=f"while_{callee.name}",
            )
        inner.abstract = _widen_abstract(ieg.return_.abstract)
        env2 = dict(env)
        for j, i in enumerate(ib.live):
            p = callee.parameters[i]
            gi = target.apply(P.tuple_getitem, inner, j)
            gi.abstract = _widen_abstract(p.abstract)
            env2[p._id] = gi
        return self._trace(target, env2, cont_g)


def _static_int(node: Node, args: list[Node], cg: Graph, k: int) -> int | None:
    """Resolve a cond/step operand to a static int: a literal constant, or
    a loop parameter whose binding at the entry site is statically known."""
    if isinstance(node, Constant):
        v = node.value
        return v if isinstance(v, int) and not isinstance(v, bool) else None
    if isinstance(node, Parameter) and node.graph is cg:
        j = cg.parameters.index(node)
        init = args[j] if j < k else None
        if init is None:
            return None
        if isinstance(init, Constant):
            v = init.value
            return v if isinstance(v, int) and not isinstance(v, bool) else None
        ab = init.abstract
        if isinstance(ab, AScalar) and ab.kind == "int" and ab.known():
            return int(ab.value)
    return None


def _static_trip_count(args: list[Node], cg: Graph, sg: Graph, k: int) -> int | None:
    """Trip count when the loop is an affine counting loop with static
    bounds (``for i in range(...)``): cond ``lt/gt(i, stop)``, step
    ``i + const``, static init — the scan-shaped case.  ``args`` is the
    entry argument list, already filtered to the live carry slots."""
    ret = cg.return_
    if not isinstance(ret, Apply) or len(ret.args) != 2:
        return None
    if is_apply(ret, P.lt):
        ascending = True
    elif is_apply(ret, P.gt):
        ascending = False
    else:
        return None
    iv, stop_n = ret.args
    if not (isinstance(iv, Parameter) and iv.graph is cg):
        return None
    idx = cg.parameters.index(iv)
    if idx >= k:
        return None  # comparing a loop invariant: not a counting loop
    mt = sg.return_
    if not is_apply(mt, P.make_tuple) or idx >= len(mt.args):
        return None
    if isinstance(stop_n, Parameter) and stop_n.graph is cg:
        # a carried stop bound is only static if the step keeps it
        # LOOP-INVARIANT (identity update) — `while i < n: ...; n = n - 1`
        # has a static init but a moving bound and must stay a while_loop
        j = cg.parameters.index(stop_n)
        if j < k:
            upd_j = mt.args[j] if j < len(mt.args) else None
            if not (
                isinstance(upd_j, Parameter)
                and upd_j.graph is sg
                and sg.parameters.index(upd_j) == j
            ):
                return None
    stop = _static_int(stop_n, args, cg, k)
    start = _static_int(cg.parameters[idx], args, cg, k)
    if stop is None or start is None:
        return None
    upd = mt.args[idx]
    if not (is_apply(upd, P.add) and len(upd.args) == 2):
        return None
    step = None
    for a, b in ((upd.args[0], upd.args[1]), (upd.args[1], upd.args[0])):
        if (
            isinstance(a, Parameter)
            and a.graph is sg
            and sg.parameters.index(a) == idx
            and isinstance(b, Constant)
            and isinstance(b.value, int)
            and not isinstance(b.value, bool)
        ):
            step = b.value
            break
    if step is None or step == 0:
        return None
    if ascending:
        if step < 0:
            return None
        return max(0, math.ceil((stop - start) / step))
    if step > 0:
        return None
    return max(0, math.ceil((start - stop) / (-step)))


class _NonTailBuilder:
    """Non-tail self-recursion in the single-call affine shape::

        def f(p):  return base(p) if done(p) else E[p, f(step(p))]

    where ``step`` advances each parameter by a constant integer delta
    (``n - 1``, passthrough, ...).  The recursion unwinds into two loops,
    both closed first-order graphs:

    1. a forward *count* loop running ``p`` to the base case while
       counting the recursion depth ``T``;
    2. a reversed *accumulator* loop stepping ``p`` back toward the entry
       (the inverse affine update) and folding ``acc = E[p, acc]`` — the
       order the call stack would unwind in.

    ``x * f(x, n - 1)`` — the canonical fold — becomes a trip-count loop
    plus ``acc = x * acc`` repeated ``T`` times.  Anything non-affine,
    with several self-calls, or with the call result feeding control flow
    stays a :class:`_LoopMismatch` and falls back to the VM."""

    def __init__(self, site: Apply) -> None:
        self.site = site
        self.h: Graph = site.fn.value
        self.fam = _loop_family(self.h)
        self.k = len(self.h.parameters)
        self.fvs = free_variables(self.h)

    # -- matching ----------------------------------------------------------

    def _resolve_chain(self, g: Graph) -> tuple[Graph, set[Graph]]:
        """Follow parameterless thunk tail-calls (``return block()``) down
        to the graph that owns the branch's value expression."""
        scope = {g}
        for _ in range(32):
            ret = g.return_
            if (
                isinstance(ret, Apply)
                and is_constant_graph(ret.inputs[0])
                and not ret.args
                and ret.inputs[0].value is not self.h
                and not ret.inputs[0].value.parameters
                and ret.inputs[0].value.return_ is not None
            ):
                g = ret.inputs[0].value
                scope.add(g)
                continue
            return g, scope
        raise _LoopMismatch(
            FallbackReason.RECURSION, "branch thunk chain too long"
        )

    @staticmethod
    def _int_const(n: Node) -> int | None:
        if isinstance(n, Constant):
            v = n.value
            if isinstance(v, int) and not isinstance(v, bool):
                return v
        return None

    def _match_self_call(self, expr: Node) -> tuple[Apply, list[int]]:
        """Find the unique self-call inside the recursive expression and
        the per-parameter affine deltas of its argument list."""
        calls: list[Apply] = []
        seen: set[int] = set()
        stack: list[Node] = [expr]
        while stack:
            n = stack.pop()
            if n._id in seen:
                continue
            seen.add(n._id)
            if isinstance(n, Constant):
                # any graph referenced here that calls h is in the family
                # (it is reachable from h through this very expression), so
                # out-of-family constants are safe leaves
                if isinstance(n.value, Graph) and n.value in self.fam:
                    raise _LoopMismatch(
                        FallbackReason.RECURSION,
                        "loop graph escapes the recursive expression as a value",
                    )
                continue
            if isinstance(n, Apply):
                fn = n.fn
                if is_constant_graph(fn) and fn.value in self.fam:
                    if fn.value is not self.h:
                        raise _LoopMismatch(
                            FallbackReason.RECURSION,
                            "recursive expression calls another family block",
                        )
                    calls.append(n)
                    stack.extend(n.args)  # skip the callee constant itself
                    continue
                stack.extend(n.inputs)
        if len(calls) != 1:
            raise _LoopMismatch(
                FallbackReason.RECURSION,
                "non-tail recursion is not a single direct self-call",
            )
        sc = calls[0]
        if len(sc.args) != self.k:
            raise _LoopMismatch(FallbackReason.RECURSION, "self-call arity mismatch")
        deltas: list[int] = []
        for i, a in enumerate(sc.args):
            p = self.h.parameters[i]
            d: int | None = None
            if a is p:
                d = 0
            elif is_apply(a, P.add) and len(a.args) == 2:
                x, y = a.args
                if x is p:
                    d = self._int_const(y)
                elif y is p:
                    d = self._int_const(x)
            elif is_apply(a, P.sub) and len(a.args) == 2:
                x, y = a.args
                if x is p:
                    c = self._int_const(y)
                    d = None if c is None else -c
            if d is None:
                raise _LoopMismatch(
                    FallbackReason.RECURSION,
                    f"self-call argument {i} is not an affine update of "
                    f"parameter {p.debug_name or i}",
                )
            deltas.append(d)
        return sc, deltas

    # -- graph construction ------------------------------------------------

    def _fresh(self, tag: str, extra: list[tuple[str, Any]]) -> Graph:
        g = Graph(f"{self.h.name}:{tag}")
        for p in self.h.parameters:
            np_ = g.add_parameter(p.debug_name)
            np_.abstract = _widen_abstract(p.abstract)
        for name, ab in extra:
            np_ = g.add_parameter(name)
            np_.abstract = ab
        for j, v in enumerate(self.fvs):
            np_ = g.add_parameter(v.debug_name or f"fv{j}")
            np_.abstract = _widen_abstract(v.abstract)
        return g

    def _env(self, g: Graph, n_extra: int) -> dict[int, Node]:
        env: dict[int, Node] = {}
        for p, np_ in zip(self.h.parameters, g.parameters[: self.k]):
            env[p._id] = np_
        for v, np_ in zip(self.fvs, g.parameters[self.k + n_extra:]):
            env[v._id] = np_
        return env

    def _tuple(self, g: Graph, parts: list[Node]) -> Apply:
        mt = g.apply(P.make_tuple, *parts)
        mt.abstract = ATuple(tuple(p.abstract for p in parts))
        return mt

    def build(self, caller: Graph) -> Apply:
        h = self.h
        k = self.k
        if len(self.site.args) != k:
            raise _LoopMismatch(FallbackReason.RECURSION, "entry call arity mismatch")
        cond_node, rec_g, base_g, negate = _match_header_switch(h, self.fam)
        for p in h.parameters:
            if not _carryable(p.abstract):
                raise _LoopMismatch(
                    FallbackReason.NON_ARRAY,
                    f"recursion carry {p.debug_name or p!r} is not an array "
                    f"value ({p.abstract!r})",
                )
        rec_owner, rec_scope = self._resolve_chain(rec_g)
        expr = rec_owner.return_
        sc, deltas = self._match_self_call(expr)
        base_owner, base_scope = self._resolve_chain(base_g)

        INT = AScalar("int")
        p_abs = [_widen_abstract(p.abstract) for p in h.parameters]
        acc_ab = _widen_abstract(self.site.abstract)

        # 1. count loop: run p to the base case, counting the depth T
        ccg = self._fresh("rec_count_cond", [("t", INT)])
        c = _CloneEnv(ccg, self.fam, self._env(ccg, 1)).clone(cond_node)
        if negate:
            neg = ccg.apply(P.bool_not, c)
            neg.abstract = AScalar("bool")
            c = neg
        ccg.set_return(c)

        csg = self._fresh("rec_count_step", [("t", INT)])
        ce = _CloneEnv(csg, self.fam, self._env(csg, 1))
        nps = [ce.clone(a) for a in sc.args]
        nt = csg.apply(P.add, csg.parameters[k], 1)
        nt.abstract = INT
        csg.set_return(self._tuple(csg, [*nps, nt]))

        ceg = self._fresh("rec_count_exit", [("t", INT)])
        ceg.set_return(self._tuple(ceg, list(ceg.parameters[: k + 1])))

        fv_nodes = list(self.fvs)
        p1 = caller.apply(
            P.while_loop,
            Constant(ccg, ccg.name),
            Constant(csg, csg.name),
            Constant(ceg, ceg.name),
            k + 1,
            *self.site.args,
            0,
            *fv_nodes,
            debug_name=f"count_{h.name}",
        )
        p1.abstract = ATuple((*p_abs, INT))
        pb: list[Node] = []
        for i in range(k):
            gi = caller.apply(P.tuple_getitem, p1, i)
            gi.abstract = p_abs[i]
            pb.append(gi)
        tnode = caller.apply(P.tuple_getitem, p1, k)
        tnode.abstract = INT

        # 2. base value at the fixed point
        benv: dict[int, Node] = {h.parameters[i]._id: pb[i] for i in range(k)}
        for v in fv_nodes:
            benv[v._id] = v
        acc0 = _CloneEnv(
            caller, self.fam, benv, scope=self.fam | base_scope
        ).clone(base_owner.return_)

        # 3. reversed accumulator loop: invert the affine step, fold E
        extra = [("acc", acc_ab), ("j", INT), ("T", INT)]
        rcg = self._fresh("rec_acc_cond", extra)
        lt = rcg.apply(P.lt, rcg.parameters[k + 1], rcg.parameters[k + 2])
        lt.abstract = AScalar("bool")
        rcg.set_return(lt)

        rsg = self._fresh("rec_acc_step", extra)
        prev: list[Node] = []
        for i, d in enumerate(deltas):
            p = rsg.parameters[i]
            if d == 0:
                prev.append(p)
            else:
                inv = rsg.apply(P.sub, p, d)
                inv.abstract = p.abstract
                prev.append(inv)
        eenv: dict[int, Node] = {h.parameters[i]._id: prev[i] for i in range(k)}
        for v, np_ in zip(self.fvs, rsg.parameters[k + 3:]):
            eenv[v._id] = np_
        eenv[sc._id] = rsg.parameters[k]  # the unwound recursive result
        nacc = _CloneEnv(
            rsg, self.fam, eenv, scope=self.fam | rec_scope
        ).clone(expr)
        nj = rsg.apply(P.add, rsg.parameters[k + 1], 1)
        nj.abstract = INT
        rsg.set_return(self._tuple(rsg, [*prev, nacc, nj]))

        reg = self._fresh("rec_acc_exit", extra)
        reg.set_return(reg.parameters[k])

        new = caller.apply(
            P.while_loop,
            Constant(rcg, rcg.name),
            Constant(rsg, rsg.name),
            Constant(reg, reg.name),
            k + 2,
            *pb,
            acc0,
            0,
            tnode,
            *fv_nodes,
            debug_name=f"unwind_{h.name}",
        )
        new.abstract = acc_ab
        return new


def _find_site(root: Graph, failed: set[int]) -> Apply | None:
    """First live entry call of a recursive header (a call from *outside*
    the header's own family — back-edges don't count)."""
    for n in dfs_nodes(root.return_):
        if not (isinstance(n, Apply) and is_constant_graph(n.fn)):
            continue
        h = n.fn.value
        if h._id in failed or h.return_ is None or not _reaches_itself(h):
            continue
        if n.graph in _loop_family(h):
            continue  # interior back-edge, not an entry
        return n
    return None


def lower_loops(root: Graph, stats: Any = None) -> LoopReport:
    """Rewrite every recognizable tail-recursive family below ``root``
    into ``while_loop`` / ``scan_loop`` applies (in place).  One site is
    rewritten per scan so later sites see the updated graph; headers that
    fail to match are recorded once in the report and skipped."""
    from repro.obs import trace as obs_trace

    report = LoopReport()
    failed: set[int] = set()
    sp = obs_trace.span("closure.lower_loops", graph=root.name)
    with sp:
        _lower_loops_body(root, report, failed, stats)
        sp.set(lowered=report.lowered, scans=report.scans, failed=len(failed))
    return report


def _lower_loops_body(
    root: Graph, report: LoopReport, failed: set[int], stats: Any = None
) -> None:
    for _ in range(64):
        site = _find_site(root, failed)
        synthetic = False
        if site is None:
            # A root-recursive function (``def f(x, n): ... f(x, n - 1)``)
            # IS its own header, so no external entry call exists below
            # root.  Synthesize one — args are the root's own parameters —
            # and splice the loop in as the root's new return value.
            if (
                root._id not in failed
                and root.return_ is not None
                and _reaches_itself(root)
            ):
                site = Apply([Constant(root, root.name), *root.parameters], root)
                site.abstract = root.return_.abstract
                synthetic = True
            else:
                break
        h = site.fn.value

        def splice(new: Apply) -> None:
            if synthetic:
                root.set_return(new)
            else:
                _replace(root, site, new)

        try:
            builder = _LoopBuilder(site)
            cg, sg, eg = builder.build()
        except _LoopMismatch as e:
            try:
                new = _NonTailBuilder(site).build(site.graph)
            except _LoopMismatch:
                failed.add(h._id)
                report.reasons.append(
                    FallbackReason(e.kind, f"{h.name}: {e.detail}")
                )
                continue
            splice(new)
            report.lowered += 1
            if stats is not None:
                stats.record_rule("lower_loop_nontail")
            continue
        caller = site.graph
        fv_nodes = list(builder.fvs)
        args = builder.entry_args(list(site.args))
        n_iters = _static_trip_count(args, cg, sg, builder.k)
        if n_iters is not None:
            new = caller.apply(
                P.scan_loop,
                Constant(sg, sg.name),
                Constant(eg, eg.name),
                n_iters,
                builder.k,
                *args,
                *fv_nodes,
                debug_name=f"scan_{h.name}",
            )
            report.scans += 1
            if stats is not None:
                stats.record_rule("lower_loop_scan")
        else:
            new = caller.apply(
                P.while_loop,
                Constant(cg, cg.name),
                Constant(sg, sg.name),
                Constant(eg, eg.name),
                builder.k,
                *args,
                *fv_nodes,
                debug_name=f"while_{h.name}",
            )
            if stats is not None:
                stats.record_rule("lower_loop_while")
        new.abstract = _widen_abstract(eg.return_.abstract)
        splice(new)
        report.lowered += 1
