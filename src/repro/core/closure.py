"""Closure elimination: call-graph analysis, defunctionalization, and
structured-recursion lowering (the "compile the closures" tier).

The paper's argument for a closure-supporting graph IR is that ST-based AD
needs no tape *and* its output is an ordinary program, amenable to
ahead-of-time optimization — including adjoints of adjoints and programs
with control flow.  Before this module, any graph that kept a residual
graph value after optimization (recursion from parsed loops, higher-order
calls the inliner could not resolve) silently fell back to the reference
VM.  This module closes most of that gap:

* :func:`analyze_blockers` — the structured version of
  ``lowering.lowering_blockers``: every reason a graph cannot lower is a
  :class:`FallbackReason` with a machine-readable ``kind``
  (``recursion-shape`` / ``higher-order-residual`` / ``free-variable`` /
  ``non-array-param`` / ``no-return``), surfaced through ``OptStats`` and
  the benchmark JSON so the CI fallback counter is debuggable.

* :func:`specialize_recursive_calls` — defunctionalization (Shaikhha et
  al.): a call of a *recursive* graph that passes a graph- or
  primitive-valued constant gets a per-constant specialized clone with
  that parameter bound.  The interior call sites become first-order, the
  inliner resolves them on the next wave, and the loop lowering below can
  then compile the recursion (``iterate(f, x, n)``-style programs).

* :func:`lower_loops` — structured-recursion lowering (Innes, *Don't
  Unroll Adjoint*): tail-recursive families in the canonical shape the
  parser emits (``header: switch(cond, body, exit)()``; ``body`` tail-calls
  the header, possibly through argument-carrying shims and nested
  switch diamonds) are rewritten into ``while_loop`` / ``scan_loop``
  primitive applies whose cond/step/exit are *closed first-order graphs*.
  The loop-invariant free variables — the closure environment of the loop
  family — are threaded as trailing arguments, and the carry is exactly
  the header's parameter list.  ``scan_loop`` (→ ``jax.lax.scan``) is
  selected when the trip count is statically known (the fold-shaped
  ``for i in range(...)`` case); everything else becomes
  ``jax.lax.while_loop``.

What still genuinely needs the VM: non-tail self-calls (the recursive
result feeds another op — ``x * f(x, n-1)``), break-style conditional
exits from a loop body, nested loops (the inner family tail-calls the
outer header, so both live in one SCC), and closures selected by
``switch`` on traced conditions.  ``docs/pipeline.md`` keeps the matrix.
"""

from __future__ import annotations

import math
from typing import Any

from . import primitives as P
from .infer import AArray, AScalar, ATuple, _widen
from .ir import (
    Apply,
    Constant,
    Graph,
    GraphCloner,
    Node,
    Parameter,
    dfs_nodes,
    free_variables,
    graph_and_descendants,
    is_apply,
    is_constant_graph,
)
from .primitives import LOOP_GRAPH_ARGS, Primitive

__all__ = [
    "FallbackReason",
    "analyze_blockers",
    "specialize_recursive_calls",
    "lower_loops",
    "LoopReport",
]


# ---------------------------------------------------------------------------
# Structured fallback reasons
# ---------------------------------------------------------------------------


class FallbackReason:
    """Why a graph stays on the VM: a machine-readable kind + detail."""

    #: the recursion is not in a shape the loop lowering recognizes
    RECURSION = "recursion-shape"
    #: a function value survived optimization (closure/higher-order call)
    HIGHER_ORDER = "higher-order-residual"
    #: a node owned by another graph (the graph is still nested)
    FREE_VARIABLE = "free-variable"
    #: a loop carry that is not an array/scalar value
    NON_ARRAY = "non-array-param"
    NO_RETURN = "no-return"

    __slots__ = ("kind", "detail")

    def __init__(self, kind: str, detail: str) -> None:
        self.kind = kind
        self.detail = detail

    def __str__(self) -> str:
        return f"[{self.kind}] {self.detail}"

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FallbackReason({self.kind!r}, {self.detail!r})"

    def as_dict(self) -> dict:
        return {"kind": self.kind, "detail": self.detail}


def _reaches_itself(g: Graph) -> bool:
    return any(is_constant_graph(n) and n.value is g for n in dfs_nodes(g.return_))


def _is_loop_graph_slot(user: Node, idx: int) -> bool:
    """True iff ``(user, idx)`` is a legal graph-valued slot: one of the
    leading sub-function arguments of a loop primitive apply."""
    if not isinstance(user, Apply):
        return False
    fn = user.fn
    if not (isinstance(fn, Constant) and isinstance(fn.value, Primitive)):
        return False
    n = LOOP_GRAPH_ARGS.get(fn.value.name)
    return n is not None and 1 <= idx <= n


def analyze_blockers(graph: Graph, _depth: int = 0) -> list[FallbackReason]:
    """Structured reasons ``graph`` cannot lower (empty list: lowerable).

    Mirrors what ``lowering.lower_graph`` can emit: straight-line applies
    of constant primitives over graph-owned nodes, plus loop primitive
    applies whose leading arguments are *closed, recursively lowerable*
    graphs.  De-duplicated (first occurrence wins)."""
    if graph.return_ is None:
        return [FallbackReason(FallbackReason.NO_RETURN, "graph has no return node")]
    if _depth > 8:
        return [
            FallbackReason(
                FallbackReason.RECURSION, f"loop nesting too deep below {graph.name!r}"
            )
        ]
    reasons: dict[str, FallbackReason] = {}

    def add(kind: str, detail: str) -> None:
        reasons.setdefault(f"{kind}:{detail}", FallbackReason(kind, detail))

    def classify_graph_value(g: Graph) -> None:
        if _reaches_itself(g):
            add(
                FallbackReason.RECURSION,
                f"graph-valued constant {g.name!r} survived optimization "
                "(residual recursion)",
            )
        else:
            add(
                FallbackReason.HIGHER_ORDER,
                f"graph-valued constant {g.name!r} survived optimization "
                "(closure value)",
            )

    seen: set[int] = set()
    stack: list[Node] = [graph.return_]
    while stack:
        node = stack.pop()
        if id(node) in seen:
            continue
        seen.add(id(node))
        if isinstance(node, Constant):
            if isinstance(node.value, Graph):
                if node.users and all(
                    _is_loop_graph_slot(u, i) for u, i in node.users
                ):
                    # loop sub-function: must itself be closed + lowerable
                    for sub in analyze_blockers(node.value, _depth + 1):
                        add(sub.kind, f"in loop graph {node.value.name!r}: {sub.detail}")
                else:
                    classify_graph_value(node.value)
            continue
        if isinstance(node, Parameter):
            if node.graph is not graph:
                add(
                    FallbackReason.FREE_VARIABLE,
                    f"free parameter {node!r} of graph {node.graph and node.graph.name!r}",
                )
            continue
        assert isinstance(node, Apply)
        if node.graph is not graph:
            add(
                FallbackReason.FREE_VARIABLE,
                f"free variable: apply node owned by nested graph "
                f"{node.graph and node.graph.name!r}",
            )
        fn = node.fn
        if not (isinstance(fn, Constant) and isinstance(fn.value, Primitive)):
            if is_constant_graph(fn):
                classify_graph_value(fn.value)
            else:
                add(
                    FallbackReason.HIGHER_ORDER,
                    f"non-primitive callee {fn!r} (higher-order or graph call)",
                )
        stack.extend(node.inputs)
    return list(reasons.values())


# ---------------------------------------------------------------------------
# Defunctionalization: specialize recursive calls on function constants
# ---------------------------------------------------------------------------


def _family_recursive(g: Graph, memo: dict[int, bool]) -> bool:
    hit = memo.get(g._id)
    if hit is None:
        hit = any(_reaches_itself(d) for d in graph_and_descendants(g))
        memo[g._id] = hit
    return hit


def _passes_through(h: Graph, i: int, value: Any) -> bool:
    """Every call of ``h`` inside its own family must keep argument ``i``
    stable: the parameter itself, or a constant equal to ``value``."""
    p = h.parameters[i]
    for n in dfs_nodes(h.return_):
        if isinstance(n, Apply) and is_constant_graph(n.fn) and n.fn.value is h:
            if i >= len(n.args):
                return False
            a = n.args[i]
            if a is p:
                continue
            if isinstance(a, Constant) and a.value is value:
                continue
            return False
    return True


def _drop_arg(call: Apply, i: int, root: Graph) -> None:
    new = Apply(
        [call.inputs[0]] + call.args[:i] + call.args[i + 1:],
        call.graph,
        call.debug_name,
    )
    new.abstract = call.abstract
    _replace(root, call, new)


def _replace(root: Graph, old: Node, new: Node) -> None:
    for user, idx in list(old.users):
        user.set_input(idx, new)
    for g in graph_and_descendants(root):
        if g.return_ is old:
            g.set_return(new)
    if isinstance(old, Apply):
        for i, inp in enumerate(old.inputs):
            inp.users.discard((old, i))


def specialize_recursive_calls(
    root: Graph, stats: Any = None, memo: dict | None = None
) -> bool:
    """Monomorphize recursive higher-order calls (defunctionalization).

    A call ``h(..., const_fn, ...)`` where ``h``'s family is recursive (so
    the inliner refuses it) and ``const_fn`` is a graph/primitive constant
    is rewritten to ``h′(...)`` — a clone of ``h``'s family with that
    parameter bound to the constant and dropped from every signature.  The
    now-constant interior call sites inline on the optimizer's next wave,
    which is what lets ``lower_loops`` compile higher-order recursion.

    ``memo`` caches specializations across calls (keyed by graph, position
    and constant identity); pass the same dict for one optimize run.
    """
    memo = memo if memo is not None else {}
    rec_memo: dict[int, bool] = {}
    changed = False
    for site in list(dfs_nodes(root.return_)):
        if not (isinstance(site, Apply) and is_constant_graph(site.fn)):
            continue
        h = site.fn.value
        if h.return_ is None or not _family_recursive(h, rec_memo):
            continue  # the plain inliner owns non-recursive calls
        if len(site.args) != len(h.parameters):
            continue
        for i, a in enumerate(site.args):
            if not (isinstance(a, Constant) and isinstance(a.value, (Graph, Primitive))):
                continue
            if isinstance(a.value, Graph) and a.value.return_ is None:
                continue
            if not _passes_through(h, i, a.value):
                continue
            key = (h._id, i, id(a.value))
            h2 = memo.get(key)
            if h2 is None:
                h2 = _specialize(h, i, a.value)
                memo[key] = h2
            new = Apply(
                [Constant(h2, h2.name)] + site.args[:i] + site.args[i + 1:],
                site.graph,
                site.debug_name,
            )
            new.abstract = site.abstract
            _replace(root, site, new)
            if stats is not None:
                stats.record_rule("defunctionalize_call")
            changed = True
            break  # site rewritten; further args handled on the next pass
    return changed


def _specialize(h: Graph, i: int, value: Any) -> Graph:
    label = getattr(value, "name", type(value).__name__)
    cloner = GraphCloner(h, relabel=f"[{label}]")
    h2 = cloner.clone()
    # the constant may be (a clone of) a family member — self-passing style
    if isinstance(value, Graph):
        value = cloner.graph_map.get(value, value)
    p = h2.parameters[i]
    const = Constant(value, p.debug_name)
    const.abstract = p.abstract
    for user, idx in list(p.users):
        user.set_input(idx, const)
    h2.parameters.pop(i)
    # drop the bound argument from every interior self-call
    for n in list(dfs_nodes(h2.return_)):
        if isinstance(n, Apply) and is_constant_graph(n.fn) and n.fn.value is h2:
            if i < len(n.args):
                _drop_arg(n, i, h2)
    return h2


# ---------------------------------------------------------------------------
# Structured-recursion lowering
# ---------------------------------------------------------------------------


class _LoopMismatch(Exception):
    """Internal signal: this recursive family is not loop-shaped."""

    def __init__(self, kind: str, detail: str) -> None:
        self.kind = kind
        self.detail = detail
        super().__init__(f"[{kind}] {detail}")


class LoopReport:
    __slots__ = ("lowered", "scans", "reasons")

    def __init__(self) -> None:
        self.lowered = 0
        self.scans = 0
        self.reasons: list[FallbackReason] = []


def _loop_family(h: Graph) -> set[Graph]:
    """Graphs mutually reachable with ``h`` through graph constants: the
    candidate loop family (header + body blocks that jump back to it)."""
    return {g for g in graph_and_descendants(h) if h in graph_and_descendants(g)}


def _widen_abstract(ab: Any) -> Any:
    if ab is None:
        return None
    try:
        return _widen(ab)
    except Exception:  # pragma: no cover - _widen is total on our domain
        return None


def _carryable(ab: Any) -> bool:
    """Can this value ride in a jax loop carry?  Arrays, numeric scalars,
    None units and tuples thereof; function values / environments / opaque
    statics cannot change identity per iteration."""
    if isinstance(ab, AArray):
        return True
    if isinstance(ab, AScalar):
        return ab.kind in ("int", "float", "bool", "none")
    if isinstance(ab, ATuple):
        return all(_carryable(e) for e in ab.elements)
    return False


class _CloneEnv:
    """Clone an expression DAG owned by loop-family graphs into ``target``,
    resolving header parameters and threaded free variables through
    ``env`` (node id → target-resident node; doubles as the memo).

    Graph constants from *outside* the family are kept, unless they
    capture family-owned (or remapped) nodes — nested closures like
    if-expression thunks — in which case the closure's family is
    deep-copied with its captures resolved into the target graph."""

    def __init__(
        self,
        target: Graph,
        fam: set[Graph],
        env: dict[int, Node],
        scope: set[Graph] | None = None,
    ) -> None:
        self.target = target
        self.fam = fam
        #: graphs whose owned nodes are cloned into ``target`` — the family
        #: plus the branch graph being traced (the exit block is *not* part
        #: of the mutually-recursive family but owns its own expression)
        self.scope = fam if scope is None else scope
        self.env = env
        self._captured_memo: dict[int, list[Node]] = {}

    def _captured(self, g: Graph) -> list[Node]:
        hit = self._captured_memo.get(g._id)
        if hit is None:
            hit = [
                n
                for n in free_variables(g)
                if (n.graph in self.scope) or (n._id in self.env)
            ]
            self._captured_memo[g._id] = hit
        return hit

    def clone(self, node: Node) -> Node:
        if node._id in self.env:
            return self.env[node._id]
        stack: list[tuple[Node, bool]] = [(node, False)]
        while stack:
            cur, ready = stack.pop()
            if cur._id in self.env:
                continue
            if isinstance(cur, Constant):
                v = cur.value
                if isinstance(v, Graph):
                    if v in self.fam:
                        raise _LoopMismatch(
                            FallbackReason.RECURSION,
                            f"loop graph {v.name!r} escapes as a first-class value",
                        )
                    captured = self._captured(v)
                    if captured and not ready:
                        stack.append((cur, True))
                        stack.extend(
                            (n, False) for n in captured if n._id not in self.env
                        )
                        continue
                    if captured:
                        new: Node = Constant(self._clone_closure(v), cur.debug_name)
                    else:
                        new = Constant(v, cur.debug_name)
                else:
                    new = Constant(v, cur.debug_name)
                new.abstract = cur.abstract
                self.env[cur._id] = new
                continue
            if isinstance(cur, Parameter):
                raise _LoopMismatch(
                    FallbackReason.RECURSION,
                    f"loop body references parameter {cur!r} of "
                    f"{cur.graph and cur.graph.name!r} outside its trace frame",
                )
            assert isinstance(cur, Apply)
            if cur.graph not in self.scope:
                raise _LoopMismatch(
                    FallbackReason.FREE_VARIABLE,
                    f"loop body references node {cur!r} outside the threaded "
                    "environment",
                )
            if ready:
                new_inputs = [self.env[i._id] for i in cur.inputs]
                new = Apply(new_inputs, self.target, cur.debug_name)
                new.abstract = _widen_abstract(cur.abstract)
                self.env[cur._id] = new
            else:
                stack.append((cur, True))
                for i in cur.inputs:
                    if i._id not in self.env:
                        stack.append((i, False))
        return self.env[node._id]

    def _clone_closure(self, g: Graph) -> Graph:
        cloner = GraphCloner(g, relabel="")
        for n in self._captured(g):
            cloner.node_map[n._id] = self.env[n._id]
        return cloner.clone()


#: trace budget: loop-block entries per site (guards against irreducible
#: control flow — e.g. a nested loop whose family reaches this header)
_MAX_TRACE = 200


class _LoopBuilder:
    """Match one entry call of a tail-recursive family and build the
    closed cond/step/exit graphs for the loop primitives."""

    def __init__(self, site: Apply) -> None:
        self.site = site
        self.h: Graph = site.fn.value
        self.fam = _loop_family(self.h)
        self.k = len(self.h.parameters)
        self.fvs = free_variables(self.h)
        self._steps = 0

    def build(self) -> tuple[Graph, Graph, Graph]:
        h = self.h
        if len(self.site.args) != self.k:
            raise _LoopMismatch(FallbackReason.RECURSION, "entry call arity mismatch")
        ret = h.return_
        if not (isinstance(ret, Apply) and len(ret.inputs) == 1):
            raise _LoopMismatch(
                FallbackReason.RECURSION,
                "header does not end in an applied switch",
            )
        sel = ret.inputs[0]
        if not (is_apply(sel, P.switch) and len(sel.args) == 3):
            raise _LoopMismatch(
                FallbackReason.RECURSION,
                "header does not end in an applied switch",
            )
        cond_node, tb, fb = sel.args
        if not (is_constant_graph(tb) and is_constant_graph(fb)):
            raise _LoopMismatch(
                FallbackReason.RECURSION, "switch branches are not graph constants"
            )
        t_loops = tb.value in self.fam
        f_loops = fb.value in self.fam
        if t_loops == f_loops:
            raise _LoopMismatch(
                FallbackReason.RECURSION,
                "both switch branches re-enter the loop"
                if t_loops
                else "no switch branch re-enters the loop",
            )
        loop_g, exit_g = (tb.value, fb.value) if t_loops else (fb.value, tb.value)
        negate = not t_loops
        if loop_g.parameters or exit_g.parameters:
            raise _LoopMismatch(
                FallbackReason.RECURSION, "switch branch takes parameters"
            )
        for p in h.parameters:
            if not _carryable(p.abstract):
                raise _LoopMismatch(
                    FallbackReason.NON_ARRAY,
                    f"loop carry {p.debug_name or p!r} is not an array value "
                    f"({p.abstract!r})",
                )

        cg = self._fresh("loop_cond")
        c = _CloneEnv(cg, self.fam, self._base_env(cg)).clone(cond_node)
        if negate:
            neg = cg.apply(P.bool_not, c)
            neg.abstract = AScalar("bool")
            c = neg
        cg.set_return(c)

        sg = self._fresh("loop_step")
        exprs = self._trace(sg, self._base_env(sg), loop_g)
        mt = sg.apply(P.make_tuple, *exprs)
        mt.abstract = ATuple(
            tuple(
                e.abstract if e.abstract is not None else _widen_abstract(p.abstract)
                for e, p in zip(exprs, self.h.parameters)
            )
        )
        sg.set_return(mt)

        eg = self._fresh("loop_exit")
        eg.set_return(
            _CloneEnv(
                eg, self.fam, self._base_env(eg), scope=self.fam | {exit_g}
            ).clone(exit_g.return_)
        )
        return cg, sg, eg

    def _fresh(self, tag: str) -> Graph:
        g = Graph(f"{self.h.name}:{tag}")
        for p in self.h.parameters:
            np_ = g.add_parameter(p.debug_name)
            np_.abstract = _widen_abstract(p.abstract)
        for j, v in enumerate(self.fvs):
            np_ = g.add_parameter(v.debug_name or f"fv{j}")
            np_.abstract = _widen_abstract(v.abstract)
        return g

    def _base_env(self, g: Graph) -> dict[int, Node]:
        env: dict[int, Node] = {}
        for p, np_ in zip(self.h.parameters, g.parameters[: self.k]):
            env[p._id] = np_
        for v, np_ in zip(self.fvs, g.parameters[self.k:]):
            env[v._id] = np_
        return env

    def _trace(self, target: Graph, env: dict[int, Node], g: Graph) -> list[Node]:
        """Symbolically execute loop block ``g`` down to the back-edge,
        returning the k cloned next-carry expressions.  Handles chains of
        argument-carrying tail calls (the for-loop ``incr`` shim, if/else
        rejoin blocks) and switch diamonds whose branches both loop."""
        self._steps += 1
        if self._steps > _MAX_TRACE:
            raise _LoopMismatch(
                FallbackReason.RECURSION,
                "loop control flow too complex (trace budget exceeded — "
                "nested or irreducible recursion)",
            )
        ret = g.return_
        if not isinstance(ret, Apply):
            raise _LoopMismatch(
                FallbackReason.RECURSION, f"loop block {g.name!r} returns a non-call"
            )
        ce = _CloneEnv(target, self.fam, env)
        fn = ret.inputs[0]
        if is_constant_graph(fn):
            callee = fn.value
            if callee is self.h:
                if len(ret.args) != self.k:
                    raise _LoopMismatch(
                        FallbackReason.RECURSION, "back-edge arity mismatch"
                    )
                return [ce.clone(a) for a in ret.args]
            if callee in self.fam:
                if len(ret.args) != len(callee.parameters):
                    raise _LoopMismatch(
                        FallbackReason.RECURSION, "tail-call arity mismatch"
                    )
                env2 = dict(env)
                for p, a in zip(callee.parameters, [ce.clone(a) for a in ret.args]):
                    env2[p._id] = a
                return self._trace(target, env2, callee)
            raise _LoopMismatch(
                FallbackReason.RECURSION,
                f"loop body exits through {callee.name!r} "
                "(break-style control flow)",
            )
        if (
            isinstance(fn, Apply)
            and is_apply(fn, P.switch)
            and len(fn.args) == 3
            and len(ret.args) == 0
        ):
            c, t, f = fn.args
            if not (is_constant_graph(t) and is_constant_graph(f)):
                raise _LoopMismatch(
                    FallbackReason.RECURSION, "switch branches are not graph constants"
                )
            tg, fg = t.value, f.value
            if tg not in self.fam or fg not in self.fam:
                raise _LoopMismatch(
                    FallbackReason.RECURSION,
                    "conditional exit from the loop body (break-style control flow)",
                )
            if tg.parameters or fg.parameters:
                raise _LoopMismatch(
                    FallbackReason.RECURSION, "switch branch takes parameters"
                )
            cnode = ce.clone(c)
            ta = self._trace(target, dict(env), tg)
            fa = self._trace(target, dict(env), fg)
            out: list[Node] = []
            for i, (x, y) in enumerate(zip(ta, fa)):
                s = target.apply(P.switch, cnode, x, y)
                s.abstract = _widen_abstract(self.h.parameters[i].abstract)
                out.append(s)
            return out
        raise _LoopMismatch(
            FallbackReason.RECURSION,
            f"unrecognized loop-block return in {g.name!r}",
        )


def _static_int(node: Node, site: Apply, cg: Graph, k: int) -> int | None:
    """Resolve a cond/step operand to a static int: a literal constant, or
    a loop parameter whose binding at the entry site is statically known."""
    if isinstance(node, Constant):
        v = node.value
        return v if isinstance(v, int) and not isinstance(v, bool) else None
    if isinstance(node, Parameter) and node.graph is cg:
        j = cg.parameters.index(node)
        init = site.args[j] if j < k else None
        if init is None:
            return None
        if isinstance(init, Constant):
            v = init.value
            return v if isinstance(v, int) and not isinstance(v, bool) else None
        ab = init.abstract
        if isinstance(ab, AScalar) and ab.kind == "int" and ab.known():
            return int(ab.value)
    return None


def _static_trip_count(site: Apply, cg: Graph, sg: Graph, k: int) -> int | None:
    """Trip count when the loop is an affine counting loop with static
    bounds (``for i in range(...)``): cond ``lt/gt(i, stop)``, step
    ``i + const``, static init — the scan-shaped case."""
    ret = cg.return_
    if not isinstance(ret, Apply) or len(ret.args) != 2:
        return None
    if is_apply(ret, P.lt):
        ascending = True
    elif is_apply(ret, P.gt):
        ascending = False
    else:
        return None
    iv, stop_n = ret.args
    if not (isinstance(iv, Parameter) and iv.graph is cg):
        return None
    idx = cg.parameters.index(iv)
    if idx >= k:
        return None  # comparing a loop invariant: not a counting loop
    mt = sg.return_
    if not is_apply(mt, P.make_tuple) or idx >= len(mt.args):
        return None
    if isinstance(stop_n, Parameter) and stop_n.graph is cg:
        # a carried stop bound is only static if the step keeps it
        # LOOP-INVARIANT (identity update) — `while i < n: ...; n = n - 1`
        # has a static init but a moving bound and must stay a while_loop
        j = cg.parameters.index(stop_n)
        if j < k:
            upd_j = mt.args[j] if j < len(mt.args) else None
            if not (
                isinstance(upd_j, Parameter)
                and upd_j.graph is sg
                and sg.parameters.index(upd_j) == j
            ):
                return None
    stop = _static_int(stop_n, site, cg, k)
    start = _static_int(cg.parameters[idx], site, cg, k)
    if stop is None or start is None:
        return None
    upd = mt.args[idx]
    if not (is_apply(upd, P.add) and len(upd.args) == 2):
        return None
    step = None
    for a, b in ((upd.args[0], upd.args[1]), (upd.args[1], upd.args[0])):
        if (
            isinstance(a, Parameter)
            and a.graph is sg
            and sg.parameters.index(a) == idx
            and isinstance(b, Constant)
            and isinstance(b.value, int)
            and not isinstance(b.value, bool)
        ):
            step = b.value
            break
    if step is None or step == 0:
        return None
    if ascending:
        if step < 0:
            return None
        return max(0, math.ceil((stop - start) / step))
    if step > 0:
        return None
    return max(0, math.ceil((start - stop) / (-step)))


def _find_site(root: Graph, failed: set[int]) -> Apply | None:
    """First live entry call of a recursive header (a call from *outside*
    the header's own family — back-edges don't count)."""
    for n in dfs_nodes(root.return_):
        if not (isinstance(n, Apply) and is_constant_graph(n.fn)):
            continue
        h = n.fn.value
        if h._id in failed or h.return_ is None or not _reaches_itself(h):
            continue
        if n.graph in _loop_family(h):
            continue  # interior back-edge, not an entry
        return n
    return None


def lower_loops(root: Graph, stats: Any = None) -> LoopReport:
    """Rewrite every recognizable tail-recursive family below ``root``
    into ``while_loop`` / ``scan_loop`` applies (in place).  One site is
    rewritten per scan so later sites see the updated graph; headers that
    fail to match are recorded once in the report and skipped."""
    from repro.obs import trace as obs_trace

    report = LoopReport()
    failed: set[int] = set()
    sp = obs_trace.span("closure.lower_loops", graph=root.name)
    with sp:
        _lower_loops_body(root, report, failed, stats)
        sp.set(lowered=report.lowered, scans=report.scans, failed=len(failed))
    return report


def _lower_loops_body(
    root: Graph, report: LoopReport, failed: set[int], stats: Any = None
) -> None:
    for _ in range(64):
        site = _find_site(root, failed)
        if site is None:
            break
        h = site.fn.value
        try:
            builder = _LoopBuilder(site)
            cg, sg, eg = builder.build()
        except _LoopMismatch as e:
            failed.add(h._id)
            report.reasons.append(
                FallbackReason(e.kind, f"{h.name}: {e.detail}")
            )
            continue
        caller = site.graph
        fv_nodes = list(builder.fvs)
        n_iters = _static_trip_count(site, cg, sg, builder.k)
        if n_iters is not None:
            new = caller.apply(
                P.scan_loop,
                Constant(sg, sg.name),
                Constant(eg, eg.name),
                n_iters,
                builder.k,
                *site.args,
                *fv_nodes,
                debug_name=f"scan_{h.name}",
            )
            report.scans += 1
            if stats is not None:
                stats.record_rule("lower_loop_scan")
        else:
            new = caller.apply(
                P.while_loop,
                Constant(cg, cg.name),
                Constant(sg, sg.name),
                Constant(eg, eg.name),
                builder.k,
                *site.args,
                *fv_nodes,
                debug_name=f"while_{h.name}",
            )
            if stats is not None:
                stats.record_rule("lower_loop_while")
        new.abstract = _widen_abstract(eg.return_.abstract)
        _replace(root, site, new)
        report.lowered += 1
