"""Ahead-of-time optimization of IR graphs (paper §4.3).

The AD transform produces graphs "substantially larger than the original
source … many computations that are not necessary, such as gradients with
respect to constants, and a lot of tuple packing and unpacking.  These
graphs can be simplified using inlining and local optimizations."  (paper
§4.3 / Figure 1.)  This module implements exactly that:

* **inlining** of non-recursive graphs called through constants,
* **local rules**: tuple getitem/setitem cancellation, gradient-environment
  cancellation (``env_getitem(env_setitem(e,k,v),k,d) → v`` — this is what
  erases the Env machinery from first-order adjoints), switch-of-constant,
  algebraic simplification, constant folding, ``gadd``-with-zero removal,
* **shape-directed rules** using inferred abstracts (``shape(x) → const``,
  ``unbroadcast(d, shp) → d`` when shapes already agree) — these complete
  the Figure-1 collapse of the adjoint of ``x ** 3`` to ``3·x²``.

Dead code needs no explicit pass: execution and node counts only ever
follow edges from the return node, so orphaned computation simply vanishes
(the VM is demand-driven; ``reachable_nodes`` is the metric).
"""

from __future__ import annotations

from typing import Any, Callable

import numpy as np

from . import primitives as P
from .ir import (
    Apply,
    Constant,
    Graph,
    GraphCloner,
    Node,
    dfs_nodes,
    graph_and_descendants,
    is_apply,
    is_constant_graph,
    is_constant_prim,
)
from .infer import AArray, AScalar, ATuple  # noqa: F401 (ATuple used in folding)
from .primitives import Primitive
from .values import EnvInstance, SymbolicKey

__all__ = ["optimize", "reachable_nodes", "count_nodes"]


def reachable_nodes(graph: Graph) -> list[Node]:
    return list(dfs_nodes(graph.return_))


def count_nodes(graph: Graph) -> int:
    return len(reachable_nodes(graph))


# ---------------------------------------------------------------------------
# Rewriting machinery
# ---------------------------------------------------------------------------


class _Rewriter:
    def __init__(self, root: Graph, max_inline_size: int | None) -> None:
        self.root = root
        self.max_inline_size = max_inline_size
        self.changed = False
        self._fam: set[Graph] | None = None
        self._desc_cache: dict[Graph, set[Graph]] = {}
        self._rec_cache: dict[Graph, bool] = {}
        self._safe_cache: dict[Graph, bool] = {}

    # -- helpers -----------------------------------------------------------
    def family(self) -> set[Graph]:
        # cached: membership only changes when inlining clones graphs
        # (invalidate_family below); local rewrites can orphan graphs but
        # scanning an orphan is merely wasted work, never unsound.
        if self._fam is None:
            self._fam = graph_and_descendants(self.root)
        return self._fam

    def invalidate_family(self) -> None:
        self._fam = None
        self._desc_cache.clear()
        self._rec_cache.clear()
        self._safe_cache.clear()

    def replace(self, old: Node, new: Node) -> None:
        for user, idx in list(old.users):
            user.set_input(idx, new)
        for g in self.family():
            if g.return_ is old:
                g.set_return(new)
        self.changed = True

    # -- inlining -----------------------------------------------------------
    def _desc(self, g: Graph) -> set[Graph]:
        if g not in self._desc_cache:
            self._desc_cache[g] = graph_and_descendants(g)
        return self._desc_cache[g]

    def _is_recursive(self, g: Graph) -> bool:
        """Can ``g`` reach a reference to itself?  Uses the SAME
        reachability the cloner uses (dfs entering graph constants AND
        free-variable pointers into other graphs), so classification and
        clone scope can never disagree."""
        hit = self._rec_cache.get(g)
        if hit is None:
            hit = any(
                is_constant_graph(n) and n.value is g for n in dfs_nodes(g.return_)
            )
            self._rec_cache[g] = hit
        return hit

    def _inline_safe(self, callee: Graph) -> bool:
        """A callee may be inlined only if nothing recursive is reachable
        from it: the cloner deep-copies ``graph_and_descendants(callee)``,
        and duplicating a recursive cycle exposes a fresh entry wrapper
        every wave — unbounded peeling of the recursion."""
        hit = self._safe_cache.get(callee)
        if hit is None:
            hit = not any(self._is_recursive(h) for h in self._desc(callee))
            self._safe_cache[callee] = hit
        return hit

    def _family_has_recursion(self) -> bool:
        """Value-based partial evaluation is gated on this: the inferencer's
        value inference is frame-insensitive for closures (AFunction joins
        dedup closure specs by graph), so in RECURSIVE families an interior
        node can be annotated with a base-case frame's value — folding it
        would be unsound.  Non-recursive families keep full constant
        propagation (the Figure-1 collapse)."""
        return not self._inline_safe(self.root)

    def inline_pass(self, max_waves: int = 64) -> bool:
        """Wave-based inlining: one dfs collects every eligible call site,
        all are inlined, repeat until a wave finds none.

        Inlining a non-recursive callee cannot create a cycle among
        pre-existing graphs (clones only *reference* graphs), so the
        recursive set computed at wave start stays valid for the wave; it
        is recomputed next wave so recursive clones are re-classified (or
        recursion would unroll forever)."""
        changed = False
        for _ in range(max_waves):
            fam = self.family()
            targets: list[Apply] = []
            for n in dfs_nodes(self.root.return_):
                if (
                    isinstance(n, Apply)
                    and n.graph in fam
                    and is_constant_graph(n.fn)
                    and n.fn.value is not n.graph
                    and self._inline_safe(n.fn.value)
                ):
                    callee = n.fn.value
                    if callee.return_ is None:
                        continue
                    if (
                        self.max_inline_size is not None
                        and count_nodes(callee) > self.max_inline_size
                    ):
                        continue
                    if len(callee.parameters) != len(n.args):
                        continue  # arity error: leave for runtime
                    targets.append(n)
            if not targets:
                return changed
            for n in targets:
                if not is_constant_graph(n.fn):
                    continue  # rewritten by an earlier inline this wave
                callee = n.fn.value
                param_repl = dict(zip(callee.parameters, n.args))
                cloner = GraphCloner(callee, inline_target=n.graph, param_repl=param_repl)
                cloner.clone()  # (remaps symbolic env keys internally)
                self.replace(n, cloner.inlined_return)
                changed = True
                self.changed = True
            self.invalidate_family()  # clones added graphs
        return changed

    # -- local rules ----------------------------------------------------------
    def rules_pass(self) -> bool:
        changed = False
        work = True
        while work:
            work = False
            # one dfs over the whole family (dfs_nodes enters graph
            # constants); per-graph re-walks were O(F·N)
            for n in list(dfs_nodes(self.root.return_)):
                if not (isinstance(n, Apply) and n.graph is not None):
                    continue
                new = self.try_rules(n)
                if new is not None:
                    self.replace(n, new)
                    work = True
                    changed = True
        return changed

    def try_rules(self, n: Apply) -> Node | None:
        fn = n.fn
        if not (isinstance(fn, Constant) and isinstance(fn.value, Primitive)):
            return None
        p: Primitive = fn.value
        a = n.args

        # partial evaluation: the inferencer proved the value (paper §4.2,
        # "It can infer types as well as values (constant propagation)").
        # Gated off in recursive families — see _family_has_recursion.
        if p not in (P.env_setitem, P.env_getitem) and not self._family_has_recursion():
            known = _known_abstract_value(n.abstract)
            if known is not _NO_VALUE:
                return Constant(known)

        if p is P.tuple_getitem and len(a) == 2 and isinstance(a[1], Constant):
            idx = a[1].value
            src = a[0]
            if is_apply(src, P.make_tuple):
                if not (isinstance(idx, int) and -len(src.args) <= idx < len(src.args)):
                    return None  # stale/dead node from the sweep snapshot
                return src.args[idx]
            if is_apply(src, P.tuple_setitem) and isinstance(src.args[1], Constant):
                if src.args[1].value == idx:
                    return src.args[2]
                return n.graph.apply(P.tuple_getitem, src.args[0], idx)
            if isinstance(src, Constant) and isinstance(src.value, tuple):
                return Constant(src.value[idx])

        if p is P.env_getitem and len(a) == 3:
            env, key, dflt = a
            if isinstance(key, Constant):
                if is_apply(env, P.env_setitem) and isinstance(env.args[1], Constant):
                    if env.args[1].value == key.value:
                        return env.args[2]
                    return n.graph.apply(P.env_getitem, env.args[0], key, dflt)
                if isinstance(env, Constant) and isinstance(env.value, EnvInstance):
                    if len(env.value) == 0:
                        return dflt

        if p is P.switch and len(a) == 3 and isinstance(a[0], Constant):
            if a[0].value is True:
                return a[1]
            if a[0].value is False:
                return a[2]

        if p is P.gadd and len(a) == 2:
            for i, j in ((0, 1), (1, 0)):
                z = a[i]
                if isinstance(z, Constant) and (
                    z.value is None
                    or (isinstance(z.value, (int, float)) and z.value == 0)
                ):
                    return a[j]
                if is_apply(z, P.zeros_like):
                    return a[j]

        # algebraic: x+0, x-0, x*1, x/1, --x  (scalar literal identities only:
        # they cannot change the broadcast shape of the result)
        if p in (P.add, P.sub) and len(a) == 2:
            if _is_scalar_const(a[1], 0):
                return a[0]
            if p is P.add and _is_scalar_const(a[0], 0):
                return a[1]
        if p in (P.mul, P.div) and len(a) == 2:
            if _is_scalar_const(a[1], 1):
                return a[0]
            if p is P.mul and _is_scalar_const(a[0], 1):
                return a[1]
        if p in (P.power, P.integer_pow) and len(a) == 2 and _is_scalar_const(a[1], 1):
            return a[0]
        if p is P.neg and is_apply(a[0], P.neg):
            return a[0].args[0]

        # shape-directed rules (need inferred abstracts)
        if p is P.shape and len(a) == 1:
            ab = a[0].abstract
            if isinstance(ab, AArray):
                return Constant(tuple(ab.shape))
            if isinstance(ab, AScalar) and ab.kind in ("int", "float", "bool"):
                return Constant(())
        if p is P.dtype_of and len(a) == 1:
            ab = a[0].abstract
            if isinstance(ab, AArray):
                return Constant(ab.dtype)
        if p in (P.unbroadcast, P.broadcast_to) and len(a) == 2 and isinstance(a[1], Constant):
            ab = a[0].abstract
            if isinstance(ab, AArray) and tuple(ab.shape) == tuple(a[1].value):
                return a[0]
            if (
                isinstance(ab, AScalar)
                and ab.kind in ("int", "float")
                and tuple(a[1].value) == ()
            ):
                return a[0]
        if p is P.cast and len(a) == 2 and isinstance(a[1], Constant):
            ab = a[0].abstract
            if isinstance(ab, AArray) and ab.dtype == np.dtype(a[1].value):
                return a[0]
        if p is P.reshape and len(a) == 2 and isinstance(a[1], Constant):
            ab = a[0].abstract
            if isinstance(ab, AArray) and tuple(ab.shape) == tuple(a[1].value):
                return a[0]

        # constant folding (pure, cheap prims on python scalars/tuples;
        # results may be tiny arrays, e.g. cast(1.0, f32))
        if p in _FOLDABLE and all(isinstance(x, Constant) for x in a):
            vals = [x.value for x in a]
            if all(_foldable_value(v) for v in vals):
                try:
                    res = p.impl(*vals)
                except Exception:
                    return None
                if _foldable_value(res) or _tiny_array(res):
                    return Constant(res)
        return None


_NO_VALUE = object()


def _known_abstract_value(ab: Any) -> Any:
    """Extract a fully-known python value from an inferred abstract."""
    if isinstance(ab, AScalar) and ab.known() and ab.kind in (
        "int", "float", "bool", "str", "none", "dtype"
    ):
        return ab.value
    if isinstance(ab, ATuple):
        vals = []
        for e in ab.elements:
            v = _known_abstract_value(e)
            if v is _NO_VALUE:
                return _NO_VALUE
            vals.append(v)
        return tuple(vals)
    return _NO_VALUE


def _tiny_array(v: Any) -> bool:
    return hasattr(v, "shape") and hasattr(v, "size") and v.size <= 16


def _is_scalar_const(node: Node, val: float) -> bool:
    """Literal scalar ``val``, possibly behind a cast (``cast(1.0, dt)``) or
    as a 0-d array constant — identities that cannot change broadcasting."""
    if is_apply(node, P.cast) and len(node.args) == 2:
        return _is_scalar_const(node.args[0], val)
    if not isinstance(node, Constant):
        return False
    v = node.value
    if isinstance(v, (int, float)) and not isinstance(v, bool):
        return v == val
    if _tiny_array(v) and getattr(v, "ndim", None) == 0:
        try:
            return float(v) == val
        except Exception:
            return False
    return False


def _foldable_value(v: Any) -> bool:
    if isinstance(v, (int, float, bool, str, np.dtype)) or v is None:
        return True
    if isinstance(v, tuple):
        return all(_foldable_value(x) for x in v)
    return False


_FOLDABLE = {
    P.add, P.sub, P.mul, P.div, P.floordiv, P.mod, P.neg, P.power,
    P.lt, P.gt, P.le, P.ge, P.eq, P.ne, P.bool_and, P.bool_or, P.bool_not,
    P.maximum, P.minimum, P.tuple_getitem, P.tuple_setitem, P.tuple_len,
    P.make_tuple, P.invert_permutation, P.axes_size, P.absolute, P.cast,
    P.dtype_of, P.integer_pow,
}


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def optimize(
    graph: Graph,
    *,
    inline: bool = True,
    max_inline_size: int | None = None,
    max_iterations: int = 50,
) -> Graph:
    """Optimize ``graph`` in place (and return it)."""
    rw = _Rewriter(graph, max_inline_size)
    for _ in range(max_iterations):
        changed = False
        if inline:
            changed |= rw.inline_pass()
        changed |= rw.rules_pass()
        if not changed:
            break
    return graph
