"""Ahead-of-time optimization of IR graphs (paper §4.3).

The AD transform produces graphs "substantially larger than the original
source … many computations that are not necessary, such as gradients with
respect to constants, and a lot of tuple packing and unpacking.  These
graphs can be simplified using inlining and local optimizations."  (paper
§4.3 / Figure 1.)  This module implements exactly that:

* **inlining** of non-recursive graphs called through constants,
* **local rules**: tuple getitem/setitem cancellation, gradient-environment
  cancellation (``env_getitem(env_setitem(e,k,v),k,d) → v`` — this is what
  erases the Env machinery from first-order adjoints), switch-of-constant,
  algebraic simplification, constant folding, ``gadd``-with-zero removal,
* **shape-directed rules** using inferred abstracts (``shape(x) → const``,
  ``unbroadcast(d, shp) → d`` when shapes already agree) — these complete
  the Figure-1 collapse of the adjoint of ``x ** 3`` to ``3·x²``.

Dead code needs no explicit pass: execution and node counts only ever
follow edges from the return node, so orphaned computation simply vanishes
(the VM is demand-driven; ``reachable_nodes`` is the metric).

Rewriting engines
-----------------
Two engines drive the local rules to their fixed point:

* ``engine="worklist"`` (default): users-edge-driven.  Every reachable
  node is seeded once; each ``replace(old, new)`` re-enqueues only ``new``,
  its users (one and two levels — rules inspect at most grandchildren), and
  the users of the replaced node's inputs.  Local rules therefore converge
  in near-linear time instead of O(sweeps × family-size).  When the
  worklist drains, one full verification sweep confirms the fixed point
  (any stragglers — there should be none — are processed and the drain
  repeats), so both engines always reach the same normal form.
* ``engine="sweep"``: the reference fixed-point implementation — repeated
  whole-family DFS sweeps until a sweep finds nothing.  Kept as the
  equivalence oracle for tests and debugging.

``optimize(..., stats=OptStats())`` fills a per-rule hit counter plus
worklist/inline counters, so benchmarks can record *why* a graph shrank.

Compile-time scalability
------------------------
Reverse-over-reverse families are large (thousands of nodes, hundreds of
graphs), so the optimizer's asymptotics — not XLA — used to dominate
cold pipeline latency (`BENCH_higher_order.json` recorded ~9.4 s of a
9.6 s grad²-MLP pipeline inside `optimize`).  The structures that keep
it near-linear now:

* ``ir.FamilyIndex`` memoizes per-graph body facts, Tarjan-SCC
  recursion/inline-safety facts and clone-family scopes, invalidated
  *scoped to the graphs a rewrite actually touched*
  (``invalidate_rewrites(dirty=...)``) instead of wholesale;
* inline waves clone **only the open sub-family** of a callee
  (``share_closed``: closed descendant graphs are shared, not copied)
  and order sites deepest-first so shared callees are simplified once,
  pre-clone (``_simplify_callee``), not re-discovered per copy;
* the family-recursion gate on value-based partial evaluation is
  *sticky* (``_norec``): rewrites only cut graph-reference edges, so an
  acyclic family can never become cyclic again within a run — without
  this, every edge-cutting rewrite forced a fresh facts pass;
* ``replace`` retargets returns through an incrementally-maintained
  return-node index instead of scanning the family per rewrite.

The remaining cold cost is cacheable wholesale: the optimized-graph
cache tier (``jax_backend.ProgramCache.graph_key`` +
``CompileOptions.graph_cache``) keys the *pre-opt* graph via the loose
structural hash (``serialize.structural_hash(g, loose=True)``) and skips
this module entirely on a warm hit.  See ``docs/architecture.md``.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Callable

import numpy as np

from repro.obs import trace as obs_trace

from . import primitives as P
from .ir import (
    Apply,
    Constant,
    FamilyIndex,
    Graph,
    GraphCloner,
    Node,
    dfs_nodes,
    is_apply,
    is_constant_graph,
)
from .infer import AArray, AFunction, AScalar, ATuple  # noqa: F401 (ATuple used in folding)
from .primitives import COLLECTIVE_NAMES, Primitive
from .values import EnvInstance, newenv

#: primitives excluded from value-based partial evaluation (environment
#: plumbing must survive until closure elimination rewires it) —
#: prebuilt: try_rules runs per worklist pop, so even tuple construction
#: in its prologue shows up on grad² profiles
_ENV_PRIMS = frozenset((P.env_setitem, P.env_getitem))

__all__ = ["optimize", "reachable_nodes", "count_nodes", "OptStats"]


def reachable_nodes(graph: Graph) -> list[Node]:
    return list(dfs_nodes(graph.return_))


def count_nodes(graph: Graph) -> int:
    return len(reachable_nodes(graph))


# ---------------------------------------------------------------------------
# Rewriting machinery
# ---------------------------------------------------------------------------


class OptStats:
    """Counters from one ``optimize`` run (pass ``optimize(..., stats=s)``).

    * ``rule_hits`` — rewrites applied, per rule name,
    * ``inlined_calls`` / ``inline_waves`` — inliner activity,
    * ``worklist_pops`` — nodes examined by the worklist engine,
    * ``verify_sweep_hits`` — rewrites found only by the post-drain
      verification sweep (should stay 0: nonzero means the enqueue locality
      missed a rule dependency and the engine fell back to sweeping),
    * ``iterations`` — outer inline+rules iterations until fixpoint,
    * ``fallback_reasons`` — structured reasons the final pipeline graph
      still cannot lower (``FallbackReason.as_dict()`` entries, filled by
      ``api.compile_pipeline``; empty means the graph compiles VM-free).
    """

    __slots__ = (
        "rule_hits",
        "inlined_calls",
        "inline_waves",
        "worklist_pops",
        "verify_sweep_hits",
        "iterations",
        "fallback_reasons",
    )

    def __init__(self) -> None:
        self.rule_hits: dict[str, int] = {}
        self.inlined_calls = 0
        self.inline_waves = 0
        self.worklist_pops = 0
        self.verify_sweep_hits = 0
        self.iterations = 0
        self.fallback_reasons: list[dict] = []

    def record_rule(self, name: str) -> None:
        self.rule_hits[name] = self.rule_hits.get(name, 0) + 1

    @property
    def total_rewrites(self) -> int:
        return sum(self.rule_hits.values())

    def as_dict(self) -> dict:
        return {
            "rule_hits": dict(sorted(self.rule_hits.items())),
            "total_rewrites": self.total_rewrites,
            "inlined_calls": self.inlined_calls,
            "inline_waves": self.inline_waves,
            "worklist_pops": self.worklist_pops,
            "verify_sweep_hits": self.verify_sweep_hits,
            "iterations": self.iterations,
            "fallback_reasons": list(self.fallback_reasons),
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"OptStats({self.as_dict()!r})"


class _Rewriter:
    def __init__(
        self,
        root: Graph,
        max_inline_size: int | None,
        stats: OptStats | None = None,
        patterns: bool = False,
    ) -> None:
        self.root = root
        self.max_inline_size = max_inline_size
        self.changed = False
        self.patterns = patterns
        self.stats = stats if stats is not None else OptStats()
        self.fam = FamilyIndex(root)
        #: enqueue hook, live only while the worklist engine drains
        self._push: Callable[[Node], None] | None = None
        #: ids of the family's return nodes, maintained while the worklist
        #: engine runs (a userless node that is no graph's return is dead)
        self._returns: set[int] | None = None
        #: return-node id -> graphs whose return_ it is.  ``replace`` used
        #: to scan the whole family per rewrite to retarget returns —
        #: O(rewrites × family), one of the superlinear optimizer costs.
        #: Lazily built, incrementally maintained (replace / inline
        #: clones), dropped when the family index is rebuilt wholesale.
        self._ret_index: dict[int, set[Graph]] | None = None
        #: graphs whose bodies changed since the last facts invalidation —
        #: lets FamilyIndex.invalidate_rewrites keep per-graph body facts
        #: for every untouched graph instead of re-walking the world
        self._dirty: set[Graph] = set()
        #: sticky "family proved non-recursive": no local rule can mint a
        #: graph constant (partial evaluation folds scalars/tuples only)
        #: and inline clones of safe callees are themselves safe, so the
        #: graph-reference digraph only ever LOSES edges during a run —
        #: once acyclic, acyclic forever.  Caching that answer keeps
        #: try_rules from re-running the Tarjan facts pass after every
        #: edge-cutting rewrite (measured: ~650 full passes per grad²
        #: pipeline without it).
        self._norec = False

    # -- helpers -----------------------------------------------------------
    def family(self) -> set[Graph]:
        # incrementally maintained: inline clones extend it (note_clone);
        # local rewrites can orphan graphs, but scanning an orphan is merely
        # wasted work, never unsound.
        return self.fam.graphs()

    def _return_index(self) -> dict[int, set[Graph]]:
        idx = self._ret_index
        if idx is None:
            idx = self._ret_index = {}
            for g in self.family():
                if g.return_ is not None:
                    idx.setdefault(g.return_._id, set()).add(g)
        return idx

    def replace(self, old: Node, new: Node) -> None:
        dirty = self._dirty
        if isinstance(new, Apply) and new.graph is not None:
            dirty.add(new.graph)
        elif isinstance(old, Apply) and old.graph is not None:
            dirty.add(old.graph)
        for user, idx in list(old.users):
            user.set_input(idx, new)
            if user.graph is not None:
                dirty.add(user.graph)
        ridx = self._return_index()
        owners = ridx.pop(old._id, None)
        if owners:
            for g in owners:
                if g.return_ is old:
                    g.set_return(new)
                    dirty.add(g)
                    ridx.setdefault(new._id, set()).add(g)
                    if self._returns is not None:
                        self._returns.discard(old._id)
                        self._returns.add(new._id)
        self.changed = True
        if isinstance(old, Apply):
            # the replaced node is gone: sever its input edges so its former
            # inputs' users sets stay live-only (this is what lets the
            # worklist engine recognise — and skip — orphaned subtrees)
            for i, inp in enumerate(old.inputs):
                inp.users.discard((old, i))
        push = self._push
        if push is not None:
            # users-edge-driven requeue: the replacement may itself match a
            # rule; its users (= the replaced node's former users, rewired
            # above) consume the new value; rules look through one level of
            # inputs (make_tuple/setitem/cast chains), so refresh two levels
            # of users; and the replaced node's inputs lost a user.
            push(new)
            if isinstance(new, Apply):
                # distribute-style rules build fresh child applies under the
                # replacement (zeros_like/gadd over tuple elements) — each
                # child may itself match a rule, so it must be seeded
                for inp in new.inputs:
                    push(inp)
            for user, _ in list(new.users):
                push(user)
                for uu, _ in list(user.users):
                    push(uu)
            if isinstance(old, Apply):
                for inp in old.inputs:
                    for user, _ in list(inp.users):
                        push(user)

    def _family_has_recursion(self) -> bool:
        """Value-based partial evaluation is gated on this: the inferencer's
        value inference is frame-insensitive for closures (AFunction joins
        dedup closure specs by graph), so in RECURSIVE families an interior
        node can be annotated with a base-case frame's value — folding it
        would be unsound.  Non-recursive families keep full constant
        propagation (the Figure-1 collapse).  The negative answer is
        sticky (``_norec``): rewrites only cut reference edges, so a
        family that went acyclic can never become cyclic again this run."""
        if self._norec:
            return False
        rec = not self.fam.inline_safe(self.root)
        if not rec:
            self._norec = True
        return rec

    def _simplify_callee(self, callee: Graph, simplified: set[Graph]) -> None:
        """Drain local rules over ``callee``'s family before the inliner
        clones it: a rewrite applied once pre-clone would otherwise be
        re-discovered (and the nodes it deletes re-copied) in every
        call-site copy.  Seeds only family members not yet drained this
        pass (``simplified`` — deepest-first site ordering means shared
        descendants are already in normal form when their callers arrive).
        Uses the same worklist machinery as ``_rules_worklist`` minus the
        verification sweep — the global pass that follows still certifies
        the fixed point."""
        members = sorted(
            (h for h in self.fam.descendants(callee) if h not in simplified),
            key=lambda h: h._id,
        )
        simplified.update(members)
        if not members:
            return
        work: deque[Apply] = deque()
        queued: set[int] = set()

        def push(node: Node) -> None:
            if isinstance(node, Apply) and id(node) not in queued:
                queued.add(id(node))
                work.append(node)

        prev_push, prev_returns = self._push, self._returns
        self._push = push
        self._returns = set(self._return_index().keys())
        dirty0 = set(self._dirty)
        try:
            seen: set[int] = set()
            for h in members:
                if h.return_ is None:
                    continue
                stack: list[Node] = [h.return_]
                while stack:
                    n = stack.pop()
                    if id(n) in seen:
                        continue
                    seen.add(id(n))
                    if isinstance(n, Apply):
                        push(n)
                        stack.extend(n._inputs)
            while work:
                n = work.popleft()
                queued.discard(id(n))
                if n.graph is None:
                    continue
                if not n.users and n._id not in self._returns:
                    for i, inp in enumerate(n.inputs):
                        inp.users.discard((n, i))
                        push(inp)
                    continue
                self.stats.worklist_pops += 1
                hit = self.try_rules(n)
                if hit is not None:
                    new, rule = hit
                    self.stats.record_rule(rule)
                    self.replace(n, new)
        finally:
            self._push = prev_push
            self._returns = prev_returns
        touched = self._dirty - dirty0
        if touched:
            # body facts / clone-family entries derived from the rewritten
            # graphs are stale NOW (the wave is still running), not at the
            # next iteration boundary — scope-invalidate immediately
            self.fam.invalidate_rewrites(dirty=touched)

    # -- inlining -----------------------------------------------------------
    def inline_pass(self, max_waves: int = 64) -> bool:
        """Wave-based inlining: one dfs collects every eligible call site,
        all are inlined, repeat until a wave finds none.

        Inlining a non-recursive callee cannot create a cycle among
        pre-existing graphs (clones only *reference* clones), so the
        recursion facts cached in the family index stay valid across waves;
        only the family set and stale descendant entries are updated, per
        clone (``FamilyIndex.note_clone``)."""
        changed = False
        # pre-clone simplification memo: a callee drained once stays
        # drained for the whole pass (later waves may touch its family,
        # making the skip merely less effective, never unsound — the
        # global rules pass still certifies the normal form)
        simplified: set[Graph] = set()
        for wave in range(max_waves):
            # one span per wave: at trace level the "clone storms" of the
            # superlinear compile-time item become directly visible as
            # wide opt.inline_wave spans with large `inlined` counts
            with obs_trace.span("opt.inline_wave", wave=wave) as sp:
                fam = self.family()
                targets: list[Apply] = []
                for n in dfs_nodes(self.root.return_):
                    if (
                        isinstance(n, Apply)
                        and n.graph in fam
                        and is_constant_graph(n.fn)
                        and n.fn.value is not n.graph
                        and self.fam.inline_safe(n.fn.value)
                    ):
                        callee = n.fn.value
                        if callee.return_ is None:
                            continue
                        if (
                            self.max_inline_size is not None
                            and count_nodes(callee) > self.max_inline_size
                        ):
                            continue
                        if len(callee.parameters) != len(n.args):
                            continue  # arity error: leave for runtime
                        targets.append(n)
                if not targets:
                    sp.set(inlined=0)
                    return changed
                # deepest callees first: a callee's OWN call sites are
                # inlined before any caller clones it, so bodies are
                # cloned flat — without this ordering a call nested k
                # levels deep is re-cloned once per wave level
                targets.sort(key=lambda t: self.fam.topo_pos(t.graph))
                self.stats.inline_waves += 1
                inlined = 0
                for n in targets:
                    if not is_constant_graph(n.fn):
                        continue  # rewritten by an earlier inline this wave
                    if not n.users and n.graph.return_ is not n:
                        continue  # orphaned by a pre-clone simplification
                    callee = n.fn.value
                    if callee not in simplified:
                        self._simplify_callee(callee, simplified)
                    if not is_constant_graph(n.fn):
                        continue
                    callee = n.fn.value
                    param_repl = dict(zip(callee.parameters, n.args))
                    cloner = GraphCloner(
                        callee,
                        inline_target=n.graph,
                        param_repl=param_repl,
                        # closed sub-families are shared, not re-copied per
                        # call site (the "clone storm" fix); the analysis
                        # is memoized per callee on the family index
                        family=self.fam.clone_family(callee),
                    )
                    cloner.clone()  # (remaps symbolic env keys internally)
                    self.replace(n, cloner.inlined_return)
                    self.fam.note_clone(cloner)
                    if self._ret_index is not None:
                        for ng in cloner.graph_map.values():
                            if ng is not n.graph and ng.return_ is not None:
                                self._ret_index.setdefault(
                                    ng.return_._id, set()
                                ).add(ng)
                    self.stats.inlined_calls += 1
                    inlined += 1
                    changed = True
                    self.changed = True
                sp.set(targets=len(targets), inlined=inlined)
        return changed

    # -- local rules ----------------------------------------------------------
    def rules_pass(self, engine: str = "worklist") -> bool:
        if engine not in ("sweep", "worklist"):
            raise ValueError(f"unknown rewrite engine {engine!r}")
        # the per-rule-class breakdown rides on the span as a hit-count
        # delta (rule spans per worklist pop would swamp the buffer AND
        # the hot path; the drain-level delta costs two dict copies,
        # armed-only)
        sp = obs_trace.span("opt.rules", engine=engine)
        before = dict(self.stats.rule_hits) if sp is not obs_trace.NULL_SPAN else None
        pops0 = self.stats.worklist_pops
        with sp:
            changed = (
                self._rules_sweep() if engine == "sweep" else self._rules_worklist()
            )
            if before is not None:
                delta = {
                    k: v - before.get(k, 0)
                    for k, v in self.stats.rule_hits.items()
                    if v != before.get(k, 0)
                }
                sp.set(
                    rewrites=sum(delta.values()),
                    pops=self.stats.worklist_pops - pops0,
                    rule_hits=delta,
                )
        return changed

    def _rules_sweep(self) -> bool:
        """Reference engine: whole-family DFS sweeps to a fixed point."""
        changed = False
        work = True
        while work:
            work = False
            for n in list(dfs_nodes(self.root.return_)):
                if not (isinstance(n, Apply) and n.graph is not None):
                    continue
                hit = self.try_rules(n)
                if hit is not None:
                    new, rule = hit
                    self.stats.record_rule(rule)
                    self.replace(n, new)
                    work = True
                    changed = True
        return changed

    def _rules_worklist(self) -> bool:
        """Worklist engine: seed every reachable node once, then follow
        users edges — each replacement requeues only its local neighborhood
        (see ``replace``), and subtrees orphaned by a rewrite are skipped
        (userless non-return nodes cannot affect the program).  A final
        verification sweep certifies the fixed point — any straggler it
        finds is rewritten on the spot and the drain repeats — so this
        engine and the sweep reference agree on normal forms."""
        changed = False
        work: deque[Apply] = deque()
        queued: set[int] = set()

        def push(node: Node) -> None:
            if isinstance(node, Apply) and id(node) not in queued:
                queued.add(id(node))
                work.append(node)

        self._push = push
        self._returns = {
            g.return_._id for g in self.family() if g.return_ is not None
        }
        try:
            for n in dfs_nodes(self.root.return_):
                push(n)
            while True:
                while work:
                    n = work.popleft()
                    queued.discard(id(n))
                    if n.graph is None:
                        continue
                    if not n.users and n._id not in self._returns:
                        # dead or orphaned: cannot affect the program.  Sever
                        # its input edges and requeue the inputs — orphan
                        # subtrees disconnect (and get skipped) transitively,
                        # mirroring how a sweep's dfs never visits them.
                        for i, inp in enumerate(n.inputs):
                            inp.users.discard((n, i))
                            push(inp)
                        continue
                    self.stats.worklist_pops += 1
                    hit = self.try_rules(n)
                    if hit is not None:
                        new, rule = hit
                        self.stats.record_rule(rule)
                        self.replace(n, new)
                        changed = True
                # verification sweep: certify the fixed point (a hit here
                # means a rule dependency the requeue policy missed — apply
                # it directly and drain the consequences)
                stragglers = 0
                for n in list(dfs_nodes(self.root.return_)):
                    if not (isinstance(n, Apply) and n.graph is not None):
                        continue
                    hit = self.try_rules(n)
                    if hit is not None:
                        new, rule = hit
                        self.stats.record_rule(rule)
                        self.replace(n, new)
                        changed = True
                        stragglers += 1
                if not stragglers:
                    break
                self.stats.verify_sweep_hits += stragglers
        finally:
            self._push = None
            self._returns = None
        return changed

    def try_rules(self, n: Apply) -> tuple[Node, str] | None:
        fn = n.fn
        if not (isinstance(fn, Constant) and isinstance(fn.value, Primitive)):
            return None
        p: Primitive = fn.value
        a = n.args

        # sharding boundary: collectives communicate across shards — no
        # local rule may fold, fold through, or eliminate one (their value
        # is NOT a function of their per-shard inputs alone)
        if p.name in COLLECTIVE_NAMES:
            return None

        # partial evaluation: the inferencer proved the value (paper §4.2,
        # "It can infer types as well as values (constant propagation)").
        # Gated off in recursive families — see _family_has_recursion.
        if p not in _ENV_PRIMS and not self._family_has_recursion():
            known = _known_abstract_value(n.abstract)
            if known is not _NO_VALUE:
                return Constant(known), "partial_eval"

        if p is P.tuple_getitem and len(a) == 2 and isinstance(a[1], Constant):
            idx = a[1].value
            src = a[0]
            if is_apply(src, P.make_tuple):
                if not (isinstance(idx, int) and -len(src.args) <= idx < len(src.args)):
                    return None  # stale/dead node from the pass snapshot
                return src.args[idx], "getitem_of_make_tuple"
            if is_apply(src, P.tuple_setitem) and isinstance(src.args[1], Constant):
                if src.args[1].value == idx:
                    return src.args[2], "getitem_of_setitem_hit"
                return (
                    n.graph.apply(P.tuple_getitem, src.args[0], idx),
                    "getitem_of_setitem_skip",
                )
            if isinstance(src, Constant) and isinstance(src.value, tuple):
                return Constant(src.value[idx]), "getitem_of_const"

        if p is P.env_getitem and len(a) == 3:
            env, key, dflt = a
            if isinstance(key, Constant):
                if is_apply(env, P.env_setitem) and isinstance(env.args[1], Constant):
                    if env.args[1].value == key.value:
                        return env.args[2], "env_getitem_of_setitem_hit"
                    return (
                        n.graph.apply(P.env_getitem, env.args[0], key, dflt),
                        "env_getitem_of_setitem_skip",
                    )
                if isinstance(env, Constant) and isinstance(env.value, EnvInstance):
                    if len(env.value) == 0:
                        return dflt, "env_getitem_empty"

        if p is P.switch and len(a) == 3 and isinstance(a[0], Constant):
            if a[0].value is True:
                return a[1], "switch_const"
            if a[0].value is False:
                return a[2], "switch_const"

        if p is P.gadd and len(a) == 2:
            for i, j in ((0, 1), (1, 0)):
                z = a[i]
                if isinstance(z, Constant) and (
                    z.value is None
                    or (isinstance(z.value, (int, float)) and z.value == 0)
                    or (isinstance(z.value, EnvInstance) and len(z.value) == 0)
                ):
                    return a[j], "gadd_zero"
                if is_apply(z, P.zeros_like) and _gadd_zero_drop_safe(z, a[j]):
                    return a[j], "gadd_zero"
            # distribute over tuples: gadd is elementwise on same-length
            # tuples (values.gadd_values), so pairing the elements lets the
            # per-element zero/closure rules fire where a whole-tuple match
            # could not (the closure-elimination tier's workhorse)
            lhs, rhs = a
            le = _tuple_elements(lhs)
            re_ = _tuple_elements(rhs)
            if le is not None and re_ is not None and len(le) == len(re_):
                g = n.graph
                items = [g.apply(P.gadd, x, y) for x, y in zip(le, re_)]
                return g.apply(P.make_tuple, *items), "gadd_tuple_distribute"

        # closure elimination (paper §3.2 / §4.3): the sensitivity of a
        # function value is an (empty) gradient environment, and zeros of a
        # tuple distribute — these erase the residual ◀-closure plumbing
        # from reverse-over-reverse adjoints so they lower without the VM
        if p is P.zeros_like and len(a) == 1:
            z = a[0]
            if isinstance(z, Constant) and isinstance(z.value, (Graph, Primitive)):
                return Constant(newenv), "zeros_of_function"
            if isinstance(z, Constant) and isinstance(z.value, EnvInstance):
                return Constant(newenv), "zeros_of_function"
            if isinstance(z.abstract, AFunction):
                return Constant(newenv), "zeros_of_function"
            if is_apply(z, P.zeros_like):
                return z, "zeros_idempotent"
            elts = _tuple_elements(z)
            if elts is not None:
                g = n.graph
                items = [g.apply(P.zeros_like, x) for x in elts]
                return g.apply(P.make_tuple, *items), "zeros_tuple_distribute"

        # algebraic: x+0, x-0, x*1, x/1, --x  (scalar literal identities only:
        # they cannot change the broadcast shape of the result)
        if p in (P.add, P.sub) and len(a) == 2:
            if _is_scalar_const(a[1], 0):
                return a[0], "add_zero"
            if p is P.add and _is_scalar_const(a[0], 0):
                return a[1], "add_zero"
        if p in (P.mul, P.div) and len(a) == 2:
            if _is_scalar_const(a[1], 1):
                return a[0], "mul_one"
            if p is P.mul and _is_scalar_const(a[0], 1):
                return a[1], "mul_one"
        if p in (P.power, P.integer_pow) and len(a) == 2 and _is_scalar_const(a[1], 1):
            return a[0], "pow_one"
        if p is P.neg and is_apply(a[0], P.neg):
            return a[0].args[0], "neg_neg"

        # shape-directed rules (need inferred abstracts)
        if p is P.shape and len(a) == 1:
            ab = a[0].abstract
            if isinstance(ab, AArray):
                return Constant(tuple(ab.shape)), "shape_const"
            if isinstance(ab, AScalar) and ab.kind in ("int", "float", "bool"):
                return Constant(()), "shape_const"
        if p is P.dtype_of and len(a) == 1:
            ab = a[0].abstract
            if isinstance(ab, AArray):
                return Constant(ab.dtype), "dtype_const"
        if p in (P.unbroadcast, P.broadcast_to) and len(a) == 2 and isinstance(a[1], Constant):
            ab = a[0].abstract
            if isinstance(ab, AArray) and tuple(ab.shape) == tuple(a[1].value):
                return a[0], "broadcast_noop"
            if (
                isinstance(ab, AScalar)
                and ab.kind in ("int", "float")
                and tuple(a[1].value) == ()
            ):
                return a[0], "broadcast_noop"
        if p is P.cast and len(a) == 2 and isinstance(a[1], Constant):
            ab = a[0].abstract
            if isinstance(ab, AArray) and ab.dtype == np.dtype(a[1].value):
                return a[0], "cast_noop"
        if p is P.reshape and len(a) == 2 and isinstance(a[1], Constant):
            ab = a[0].abstract
            if isinstance(ab, AArray) and tuple(ab.shape) == tuple(a[1].value):
                return a[0], "reshape_noop"

        # constant folding (pure, cheap prims on python scalars/tuples;
        # results may be tiny arrays, e.g. cast(1.0, f32))
        if p in _FOLDABLE and all(isinstance(x, Constant) for x in a):
            vals = [x.value for x in a]
            if all(_foldable_value(v) for v in vals):
                try:
                    res = p.impl(*vals)
                except Exception:
                    return None
                if _foldable_value(res) or _tiny_array(res):
                    return Constant(res), "const_fold"

        # kernel-pattern rules (fusion tier only): rewrite kernel-shaped
        # subgraphs to the hand-written Pallas primitives
        if self.patterns:
            hit = _try_kernel_patterns(n, p)
            if hit is not None:
                return hit
        return None


def _gadd_zero_drop_safe(z: Node, other: Node) -> bool:
    """Dropping the zero operand of a gadd is only shape-preserving when
    the zeros cannot broadcast-extend the other side: ``gadd(scalar,
    zeros_like(arr))`` has the ARRAY's shape, so erasing the zeros would
    change the result.  Array-shaped zeros may go only when the other
    operand provably has (at least) the same shape; with no inferred
    abstracts we keep the legacy permissive behavior (the structural pass
    runs before inference, and pre-seed-fix graphs never mixed shapes)."""
    za = z.abstract
    if za is None or isinstance(za, (AScalar, ATuple)):
        return True
    if isinstance(za, AArray):
        oa = other.abstract
        if isinstance(oa, AArray):
            try:
                return tuple(np.broadcast_shapes(za.shape, oa.shape)) == tuple(oa.shape)
            except ValueError:
                return False
        return False  # other side scalar/unknown: zeros would extend it
    return True  # env/function zeros: structural, never shape-bearing


def _tuple_elements(node: Node) -> list[Node] | None:
    """Element nodes of a syntactic tuple: a ``make_tuple`` apply, a
    tuple-valued constant (elements wrapped as fresh Constants), or a
    constant-index ``tuple_setitem`` over one of those (the shape
    ``_bprop_tuple_getitem`` emits — resolved so gadd/zeros distribution
    reaches the real elements)."""
    if is_apply(node, P.make_tuple):
        return list(node.args)
    if isinstance(node, Constant) and isinstance(node.value, tuple):
        return [Constant(v) for v in node.value]
    if (
        is_apply(node, P.tuple_setitem)
        and len(node.args) == 3
        and isinstance(node.args[1], Constant)
        and isinstance(node.args[1].value, int)
    ):
        base = _tuple_elements(node.args[0])
        idx = node.args[1].value
        if base is not None and 0 <= idx < len(base):
            base[idx] = node.args[2]
            return base
    return None


_NO_VALUE = object()


def _known_abstract_value(ab: Any) -> Any:
    """Extract a fully-known python value from an inferred abstract."""
    if isinstance(ab, AScalar) and ab.known() and ab.kind in (
        "int", "float", "bool", "str", "none", "dtype"
    ):
        return ab.value
    if isinstance(ab, ATuple):
        vals = []
        for e in ab.elements:
            v = _known_abstract_value(e)
            if v is _NO_VALUE:
                return _NO_VALUE
            vals.append(v)
        return tuple(vals)
    return _NO_VALUE


def _tiny_array(v: Any) -> bool:
    return hasattr(v, "shape") and hasattr(v, "size") and v.size <= 16


def _is_scalar_const(node: Node, val: float) -> bool:
    """Literal scalar ``val``, possibly behind a cast (``cast(1.0, dt)``) or
    as a 0-d array constant — identities that cannot change broadcasting."""
    if is_apply(node, P.cast) and len(node.args) == 2:
        return _is_scalar_const(node.args[0], val)
    if not isinstance(node, Constant):
        return False
    v = node.value
    if isinstance(v, (int, float)) and not isinstance(v, bool):
        return v == val
    if _tiny_array(v) and getattr(v, "ndim", None) == 0:
        try:
            return float(v) == val
        except Exception:
            return False
    return False


def _foldable_value(v: Any) -> bool:
    if isinstance(v, (int, float, bool, str, np.dtype)) or v is None:
        return True
    if isinstance(v, tuple):
        return all(_foldable_value(x) for x in v)
    return False


_FOLDABLE = {
    P.add, P.sub, P.mul, P.div, P.floordiv, P.mod, P.neg, P.power,
    P.lt, P.gt, P.le, P.ge, P.eq, P.ne, P.bool_and, P.bool_or, P.bool_not,
    P.maximum, P.minimum, P.tuple_getitem, P.tuple_setitem, P.tuple_len,
    P.make_tuple, P.invert_permutation, P.axes_size, P.absolute, P.cast,
    P.dtype_of, P.integer_pow,
}


# ---------------------------------------------------------------------------
# Kernel-pattern rules (fusion tier, paper §3: "write efficient low-level
# kernels … and expose them to Myia as primitives").  These recognize the
# canonical user-level spellings of rmsnorm and the softmax-attention core
# and rewrite the whole subgraph to ONE call of the corresponding
# hand-written Pallas primitive from ``repro.kernels.ops`` — which carries
# its own backpropagator, so ``grad`` of a rewritten graph runs the
# kernel's backward instead of the unrolled adjoint chain.
# ---------------------------------------------------------------------------


def _ashape(node: Node) -> tuple[int, ...] | None:
    ab = node.abstract
    return ab.shape if isinstance(ab, AArray) else None


def _scalar_const_value(node: Node) -> float | None:
    if isinstance(node, Constant) and isinstance(node.value, (int, float)) \
            and not isinstance(node.value, bool):
        return float(node.value)
    return None


def _is_last_axis_reduce(n: Apply, prim: Primitive) -> Node | None:
    """``prim(x, (last_axis,), True)`` → x, else None."""
    if not is_apply(n, prim) or len(n.args) != 3:
        return None
    x, axes, keep = n.args
    nd_shape = _ashape(x)
    if nd_shape is None or not isinstance(axes, Constant) or not isinstance(keep, Constant):
        return None
    if keep.value is not True:
        return None
    ax = axes.value
    if isinstance(ax, int):
        ax = (ax,)
    if not isinstance(ax, tuple):
        return None
    nd = len(nd_shape)
    if tuple(a % nd for a in ax) != (nd - 1,):
        return None
    return x


def _commuted(n: Node, prim: Primitive):
    """Yield both operand orders of a binary apply of ``prim``."""
    if is_apply(n, prim) and len(n.args) == 2:
        a, b = n.args
        yield a, b
        yield b, a


def _match_rmsnorm(n: Apply):
    """``mul(mul(x, rsqrt(mean(x²) + eps)), w)`` (any commutation; mean
    spelled ``reduce_sum(x*x, (last,), True) / D``) → ``rmsnorm(x, w, eps)``."""
    for u, w in _commuted(n, P.mul):
        w_shape = _ashape(w)
        if w_shape is None or len(w_shape) != 1:
            continue
        for x, r in _commuted(u, P.mul):
            x_shape = _ashape(x)
            if x_shape is None or len(x_shape) < 2 or x_shape[-1] != w_shape[0]:
                continue
            if not (is_apply(r, P.rsqrt) and len(r.args) == 1):
                continue
            for m, eps_n in _commuted(r.args[0], P.add):
                eps = _scalar_const_value(eps_n)
                if eps is None:
                    continue
                if not (is_apply(m, P.div) and len(m.args) == 2):
                    continue
                rs, d = m.args
                dv = _scalar_const_value(d)
                if dv is None or dv != float(x_shape[-1]):
                    continue
                sq = _is_last_axis_reduce(rs, P.reduce_sum)
                if sq is None:
                    continue
                if is_apply(sq, P.square) and sq.args[0] is x:
                    pass
                elif is_apply(sq, P.mul) and sq.args[0] is x and sq.args[1] is x:
                    pass
                else:
                    continue
                from repro.kernels.ops import rmsnorm_prim

                return n.graph.apply(rmsnorm_prim, x, w, eps), "pattern_rmsnorm"
    return None


def _match_attention_core(n: Apply):
    """``softmax(q @ kᵀ · scale) @ v`` with softmax spelled
    ``exp(s − max(s)) / Σ exp(s − max(s))`` (stable, last-axis) →
    ``flash_attention(q, k, v, False, None, scale)``.  Fires only on
    4-D (B, H, S, D) operands — the kernel's layout."""
    if not (is_apply(n, P.matmul) and len(n.args) == 2):
        return None
    prob, v = n.args
    if not (is_apply(prob, P.div) and len(prob.args) == 2):
        return None
    e, z = prob.args
    if _is_last_axis_reduce(z, P.reduce_sum) is not e:
        return None
    if not (is_apply(e, P.exp) and len(e.args) == 1):
        return None
    d = e.args[0]
    if not (is_apply(d, P.sub) and len(d.args) == 2):
        return None
    s, m = d.args
    if _is_last_axis_reduce(m, P.reduce_max) is not s:
        return None
    scale = 1.0
    t = s
    for cand, c in _commuted(s, P.mul):
        cv = _scalar_const_value(c)
        if cv is not None:
            t, scale = cand, cv
            break
    if not (is_apply(t, P.matmul) and len(t.args) == 2):
        return None
    q, kt = t.args
    if not (is_apply(kt, P.mT) and len(kt.args) == 1):
        return None
    k = kt.args[0]
    qs, ks, vs = _ashape(q), _ashape(k), _ashape(v)
    if not (qs and ks and vs) or not (len(qs) == len(ks) == len(vs) == 4):
        return None
    if ks != vs or qs[-1] != ks[-1] or qs[0] != ks[0] or qs[1] % ks[1] != 0:
        return None
    from repro.kernels.ops import flash_attention_prim

    return (
        n.graph.apply(flash_attention_prim, q, k, v, False, None, scale),
        "pattern_flash_attention",
    )


def _try_kernel_patterns(n: Apply, p: Primitive):
    if p is P.mul:
        return _match_rmsnorm(n)
    if p is P.matmul:
        return _match_attention_core(n)
    return None


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def optimize(
    graph: Graph,
    *,
    inline: bool = True,
    max_inline_size: int | None = None,
    max_iterations: int = 50,
    engine: str = "worklist",
    stats: OptStats | None = None,
    patterns: bool = False,
    defunctionalize: bool = True,
) -> Graph:
    """Optimize ``graph`` in place (and return it).

    ``engine`` selects the local-rule driver: ``"worklist"`` (near-linear,
    the default) or ``"sweep"`` (the reference fixed-point sweep — both
    reach the same normal form; see the module docstring).  Pass an
    :class:`OptStats` as ``stats`` to collect per-rule hit counters.
    ``patterns=True`` (the fusion tier) additionally recognizes
    kernel-shaped subgraphs — rmsnorm, the softmax-attention core — and
    rewrites them to the hand-written Pallas primitives registered in
    ``repro.kernels.ops`` (shape-directed: requires inferred abstracts).
    ``defunctionalize=True`` monomorphizes calls of *recursive* graphs on
    graph/primitive-valued constant arguments (``repro.core.closure``):
    the specialized clone's interior calls become first-order, which the
    next inline wave resolves — higher-order recursion reduces to the
    loop shapes ``lower_loops`` compiles.
    """
    rw = _Rewriter(graph, max_inline_size, stats, patterns=patterns)
    spec_memo: dict = {}
    with obs_trace.span(
        "optimize", graph=graph.name, engine=engine, patterns=patterns
    ) as osp:
        for _ in range(max_iterations):
            changed = False
            if inline:
                changed |= rw.inline_pass()
            if inline and defunctionalize:
                from .closure import specialize_recursive_calls

                with obs_trace.span("opt.defunctionalize"):
                    specialized = specialize_recursive_calls(
                        graph, stats=rw.stats, memo=spec_memo
                    )
                if specialized:
                    # whole families were cloned and rewired: rebuild the index
                    rw.fam = FamilyIndex(graph)
                    rw._ret_index = None
                    changed = True
            changed |= rw.rules_pass(engine)
            rw.stats.iterations += 1
            if not changed:
                break
            # rewrites may have cut graph references (e.g. switch-of-constant
            # dropping a branch): refresh recursion facts before re-inlining
            # (scoped to the graphs the rewrites actually touched)
            rw.fam.invalidate_rewrites(dirty=rw._dirty)
            rw._dirty = set()
        osp.set(
            iterations=rw.stats.iterations,
            rewrites=rw.stats.total_rewrites,
            inlined_calls=rw.stats.inlined_calls,
        )
    return graph
