"""Public API of the Myia-style toolchain (paper §4).

* ``@myia`` — compile a pure-Python-subset function through the pipeline:
  parse → (AD transform) → inline → infer (call-site specialization on the
  actual argument types/shapes, §4.2) → optimize (§4.3) → execute, either
  through the reference VM or traced once under ``jax.jit`` so XLA compiles
  the whole (straight-line) program.
* ``grad`` / ``value_and_grad`` / ``vjp`` — the ST AD transforms of §3.2.
  ``grad`` is also a *macro*: used inside ``@myia`` code it expands at parse
  time (paper Figure 1: "After the grad macro is expanded …").
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import numpy as np

from .ad import build_grad_graph, build_value_and_grad_graph, build_vjp_graph
from .infer import InferenceError, abstract_of_value, infer
from .ir import Constant, Graph, clone_graph
from .opt import count_nodes, optimize
from .parser import MyiaSyntaxError, parse_function
from .values import is_array_like
from .vm import VM

__all__ = ["myia", "grad", "value_and_grad", "vjp", "MyiaFunction", "compile_pipeline"]


def compile_pipeline(
    graph: Graph,
    example_args: tuple | None = None,
    *,
    opt: bool = True,
    infer_types: bool = True,
) -> Graph:
    """inline → infer → optimize, on a private clone of ``graph``."""
    g = clone_graph(graph)
    if not opt:
        return g
    optimize(g)  # structural pass (no abstracts needed)
    if infer_types and example_args is not None:
        try:
            infer(g, *example_args)
        except InferenceError:
            pass  # dynamic program: shape-directed rules simply won't fire
        optimize(g)  # shape-directed pass
    return g


class MyiaFunction:
    """A function compiled through the Myia pipeline, specialized and cached
    per call signature (the paper's call-site specialization)."""

    def __init__(
        self,
        fn: Callable | None = None,
        graph: Graph | None = None,
        *,
        backend: str = "jax",
        opt: bool = True,
        name: str | None = None,
    ) -> None:
        if fn is None and graph is None:
            raise ValueError("need fn or graph")
        self._fn = fn
        self._graph = graph
        self.backend = backend
        self.opt = opt
        self._specializations: dict[tuple, Callable] = {}
        self.__name__ = name or (fn.__name__ if fn is not None else graph.name)
        if fn is not None:
            functools.update_wrapper(self, fn, updated=())

    # -- graph access ---------------------------------------------------
    @property
    def graph(self) -> Graph:
        if self._graph is None:
            self._graph = parse_function(self._fn)
        return self._graph

    def __myia_graph_factory__(self) -> Graph:
        return self.graph

    # -- compilation ------------------------------------------------------
    def _sigkey(self, args: tuple) -> tuple:
        out = []
        for a in args:
            if is_array_like(a) or isinstance(a, np.generic):
                out.append(("arr", np.shape(a), np.dtype(a.dtype) if hasattr(a, "dtype") else None))
            elif isinstance(a, tuple):
                out.append(("tup", self._sigkey(a)))
            else:
                out.append(("val", type(a).__name__, a))
        return tuple(out)

    def specialize(self, args: tuple) -> Callable:
        key = (self.backend, self._sigkey(args))
        hit = self._specializations.get(key)
        if hit is not None:
            return hit
        g = compile_pipeline(
            self.graph,
            tuple(abstract_of_value(a) for a in args),
            opt=self.opt,
        )
        runner = self._make_runner(g, args)
        self._specializations[key] = runner
        return runner

    def _make_runner(self, g: Graph, example_args: tuple) -> Callable:
        if self.backend == "vm":
            return lambda *args: VM().call(g, args)
        # jax backend: arrays are dynamic (traced), everything else static.
        dyn_idx = [i for i, a in enumerate(example_args) if is_array_like(a)]
        static = {i: a for i, a in enumerate(example_args) if i not in set(dyn_idx)}

        def run(*arrs):
            full: list[Any] = [None] * (len(arrs) + len(static))
            for i, v in static.items():
                full[i] = v
            for i, v in zip(dyn_idx, arrs):
                full[i] = v
            return VM().call(g, tuple(full))

        jitted = jax.jit(run)

        def runner(*args):
            return jitted(*[args[i] for i in dyn_idx])

        return runner

    def __call__(self, *args: Any) -> Any:
        return self.specialize(args)(*args)

    # -- introspection (benchmarks / tests) --------------------------------
    def optimized_graph(self, *args: Any) -> Graph:
        return compile_pipeline(
            self.graph, tuple(abstract_of_value(a) for a in args), opt=self.opt
        )

    def node_count(self, *args: Any, optimized: bool = True) -> int:
        g = self.optimized_graph(*args) if optimized else self.graph
        return count_nodes(g)


def myia(fn: Callable | None = None, *, backend: str = "jax", opt: bool = True):
    """Decorator: compile ``fn`` (pure Python subset) through the pipeline."""

    def wrap(f: Callable) -> MyiaFunction:
        return MyiaFunction(f, backend=backend, opt=opt)

    return wrap(fn) if fn is not None else wrap


# ---------------------------------------------------------------------------
# AD entry points (callable API + in-language macros)
# ---------------------------------------------------------------------------


def _as_graph(fn: Any) -> Graph:
    if isinstance(fn, Graph):
        return fn
    if isinstance(fn, MyiaFunction):
        return fn.graph
    return parse_function(fn)


def _macro_expand_grad(parser, block, ast_args):
    if len(ast_args) < 1:
        raise MyiaSyntaxError("grad() takes a function argument")
    fn_node = parser.expr(block, ast_args[0])
    if not (isinstance(fn_node, Constant) and isinstance(fn_node.value, Graph)):
        raise MyiaSyntaxError("grad() macro requires a statically-known function")
    wrt: int | tuple = 0
    if len(ast_args) > 1:
        import ast as _ast

        a1 = ast_args[1]
        if isinstance(a1, _ast.Constant):
            wrt = a1.value
        elif isinstance(a1, _ast.Tuple):
            wrt = tuple(e.value for e in a1.elts)
        else:
            raise MyiaSyntaxError("grad() wrt must be a literal")
    return Constant(build_grad_graph(fn_node.value, wrt))


def _macro_expand_vag(parser, block, ast_args):
    fn_node = parser.expr(block, ast_args[0])
    if not (isinstance(fn_node, Constant) and isinstance(fn_node.value, Graph)):
        raise MyiaSyntaxError("value_and_grad() macro requires a statically-known function")
    return Constant(build_value_and_grad_graph(fn_node.value))


def grad(fn: Any, wrt: int | tuple[int, ...] = 0, *, backend: str = "jax", opt: bool = True):
    """Reverse-mode gradient of a scalar-output function (paper §3.2)."""
    g = build_grad_graph(_as_graph(fn), wrt)
    return MyiaFunction(graph=g, backend=backend, opt=opt, name=g.name)


def value_and_grad(
    fn: Any, wrt: int | tuple[int, ...] = 0, *, backend: str = "jax", opt: bool = True
):
    g = build_value_and_grad_graph(_as_graph(fn), wrt)
    return MyiaFunction(graph=g, backend=backend, opt=opt, name=g.name)


def vjp(fn: Any, *, backend: str = "jax", opt: bool = True):
    g = build_vjp_graph(_as_graph(fn))
    return MyiaFunction(graph=g, backend=backend, opt=opt, name=g.name)


grad.__is_myia_macro__ = True
grad.__myia_macro_expand__ = _macro_expand_grad
value_and_grad.__is_myia_macro__ = True
value_and_grad.__myia_macro_expand__ = _macro_expand_vag
