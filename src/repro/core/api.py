"""Public API of the Myia-style toolchain (paper §4).

* ``@myia`` — compile a pure-Python-subset function through the pipeline:
  parse → (AD transform) → inline → infer (call-site specialization on the
  actual argument types/shapes, §4.2) → worklist-optimize (§4.3) → execute.
  First-order graphs are *lowered directly* to a straight-line callable
  (``repro.core.lowering``); the first call answers from a cheap tier-0
  XLA compile of it, and subsequent calls use the fully optimized
  ``jax.jit`` executable.  Graphs with residual recursion / higher-order
  calls fall back to the reference VM, traced once under ``jax.jit``.
  See ``docs/pipeline.md``.
* ``grad`` / ``value_and_grad`` / ``vjp`` — the ST AD transforms of §3.2.
  ``grad`` is also a *macro*: used inside ``@myia`` code it expands at parse
  time (paper Figure 1: "After the grad macro is expanded …").

Compile configuration — migration note
--------------------------------------

All four entry points (and ``compile_pipeline``) take a single frozen
:class:`CompileOptions` carrying the full tier set::

    opts = CompileOptions(fuse=True, program_cache=cache,
                          checkpoint_policy="auto")
    f  = myia(fn, options=opts)
    df = grad(fn, options=opts)          # same tiers — full parity

The historical per-kwarg spelling (``myia(fn, fuse=True, ...)``) still
works through a shim that assembles the same ``CompileOptions`` and emits
a ``DeprecationWarning``; both spellings produce identical compiled
artifacts (same structural hash — pinned by tests).  ``checkpoint_policy``
(loop-adjoint recording: ``"auto"`` / ``"save_all"`` / ``"recompute"`` /
int slot count, see ``repro.core.ad``) is only reachable through
``CompileOptions``.  ``MyiaFunction.options`` holds the resolved object;
the legacy attributes (``.fuse``, ``.program_cache``, ...) remain as
delegating properties.

``grad``/``value_and_grad``/``vjp`` of a program containing loops or
recursion defer the AD transform to specialization time: the primal runs
the full pipeline (so parsed loops become ``while_loop``/``scan_loop``
primitives) *before* the adjoint is built, which is what lets grad-of-loop
programs compile VM-free instead of leaving residual ▶-closures.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import warnings
from typing import Any, Callable

import jax
import numpy as np

from repro.obs import trace as obs_trace

from .ad import (
    _needs_loop_pipeline,
    build_grad_graph,
    build_value_and_grad_graph,
    build_vjp_graph,
)
from .infer import InferenceError, abstract_of_value, infer
from .ir import Constant, Graph, clone_graph
from .lowering import try_lower
from .opt import OptStats, count_nodes, optimize
from .parser import MyiaSyntaxError, parse_function
from .values import is_array_like
from .vm import VM

__all__ = [
    "myia",
    "grad",
    "value_and_grad",
    "vjp",
    "MyiaFunction",
    "CompileOptions",
    "compile_pipeline",
]


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    """One immutable object carrying every compile tier configuration.

    Replaces the seven loose kwargs that accreted onto the entry points;
    every entry point accepts ``options=CompileOptions(...)`` and threads
    it whole, so each tier (fusion, patterns, SPMD, AOT cache, tracing,
    loop-adjoint checkpointing) is reachable from *all* of
    ``myia``/``grad``/``value_and_grad``/``vjp``.

    ===================  ==========  =============================================
    field                default     tier it arms
    ===================  ==========  =============================================
    ``backend``          ``"jax"``   lowered/jit execution (``"vm"``: reference)
    ``opt``              ``True``    the worklist optimizer (§4.3)
    ``fuse``             ``False``   fusion clusters → generated Pallas kernels
    ``patterns``         ``False``   kernel-pattern rewrites (rmsnorm/attention)
    ``in_specs``         ``None``    SPMD partitioning (under a mesh context)
    ``program_cache``    ``None``    AOT executable tier (``ProgramCache``)
    ``graph_cache``      ``None``    optimized-graph tier (skips optimize warm)
    ``trace``            ``None``    observability (``Tracer`` spans)
    ``checkpoint_policy``  ``"auto"``  loop-adjoint memory/recompute point
    ``profile``          ``False``   runtime profiler (eager instrumented launch)
    ===================  ==========  =============================================

    ``graph_cache`` and ``program_cache`` usually point at the *same*
    :class:`~repro.core.jax_backend.ProgramCache` object — the two tiers
    key and store independently (``<key>.graph.json`` vs ``<key>.pkl``),
    see ``docs/architecture.md`` ("Cache-tier anatomy").
    """

    #: execution backend: "jax" (lowered/jit tiers) or "vm" (reference)
    backend: str = "jax"
    #: run the optimizer (False: parse-and-execute, debugging only)
    opt: bool = True
    #: fusion tier — clustered regions run as generated Pallas kernels
    fuse: bool = False
    #: kernel-pattern rewrites (rmsnorm / attention → Pallas prims)
    patterns: bool = False
    #: SPMD tier — per-argument sharding specs (active under a mesh)
    in_specs: tuple | None = None
    #: AOT tier — a ProgramCache making compiled specializations durable
    program_cache: Any = None
    #: optimized-graph tier — a ProgramCache (usually the same object as
    #: ``program_cache``) consulted *before* the optimizer runs: a hit
    #: deserializes the stored post-optimize graph and skips the
    #: optimize + closure-elim pipeline phases entirely
    graph_cache: Any = None
    #: observability tier — a Tracer armed for every specialization
    trace: Any = None
    #: loop-adjoint carry recording: "auto" / "save_all" / "recompute"
    #: or an int slot count (see ``repro.core.ad._CHECKPOINT_SLOTS``)
    checkpoint_policy: str | int = "auto"
    #: runtime-profiler tier — when True AND a ``repro.obs.profile``
    #: Profiler is armed, calls with concrete args execute an instrumented
    #: eager lowering that records per-launch wall time + bytes moved;
    #: disarmed (or False) the ordinary jit tiers run untouched
    profile: bool = False


_UNSET: Any = object()

#: the legacy kwargs the shim still accepts (checkpoint_policy and
#: graph_cache are newer than the shim and reachable only through
#: CompileOptions — no legacy spelling to support)
_LEGACY_FIELDS = (
    "backend", "opt", "fuse", "patterns", "in_specs", "program_cache", "trace",
)


def _resolve_options(
    options: CompileOptions | None, caller: str, legacy: dict[str, Any]
) -> CompileOptions:
    """The legacy-kwarg shim: fold explicitly-passed per-tier kwargs into
    a ``CompileOptions`` (with a ``DeprecationWarning``), or pass the
    given options object through.  Mixing both spellings is an error —
    silently preferring one would mask a config bug."""
    passed = {k: v for k, v in legacy.items() if v is not _UNSET}
    if options is not None:
        if passed:
            raise TypeError(
                f"{caller}() got both options= and legacy compile kwargs "
                f"{sorted(passed)}; pass everything in CompileOptions"
            )
        return options
    if passed:
        warnings.warn(
            f"{caller}({', '.join(sorted(passed))}=...) is deprecated; pass "
            f"options=CompileOptions(...) instead",
            DeprecationWarning,
            stacklevel=3,
        )
        return CompileOptions(**passed)
    return CompileOptions()

#: XLA options for the throwaway first-call executable (tiered compilation):
#: skip backend optimizations and expensive LLVM passes — on CPU this
#: roughly halves time-to-first-result for straight-line lowered graphs,
#: and the executable is discarded once the full-opt jit takes over.
_TIER0_COMPILER_OPTIONS = {
    "xla_backend_optimization_level": 0,
    "xla_llvm_disable_expensive_passes": True,
}


def _content_key(a: Any) -> tuple:
    """Hashable content-capturing key for an unhashable static argument.

    The whole value is baked into the specialized runner, so two statics
    may share a cache slot only if their *contents* agree — ``repr`` is not
    enough (numpy elides arrays > 1000 elements with ``...``)."""
    if isinstance(a, (list, tuple)):
        return (type(a).__name__, tuple(_content_key(e) for e in a))
    if isinstance(a, dict):
        return (
            "dict",
            tuple(
                (_content_key(k), _content_key(v))
                for k, v in sorted(a.items(), key=lambda kv: repr(kv[0]))
            ),
        )
    if is_array_like(a) or isinstance(a, np.generic):
        arr = np.asarray(a)
        return ("arrval", arr.shape, str(arr.dtype), hashlib.sha1(arr.tobytes()).hexdigest())
    try:
        hash(a)
        return ("val", type(a).__name__, a)
    except TypeError:
        return ("repr", type(a).__name__, repr(a))


def compile_pipeline(
    graph: Graph,
    example_args: tuple | None = None,
    *,
    opt: bool = True,
    infer_types: bool = True,
    engine: str = "worklist",
    stats: OptStats | None = None,
    patterns: bool = False,
    loops: bool = True,
    options: CompileOptions | None = None,
    snapshot: Callable[[str, Graph], None] | None = None,
) -> Graph:
    """inline → infer → optimize → loop-lower, on a private clone of
    ``graph``.

    ``options`` (a :class:`CompileOptions`) supplies ``opt``/``patterns``
    when given — the same object the entry points thread — while the
    pipeline-internal knobs (``engine``, ``stats``, ``infer_types``,
    ``loops``) stay explicit kwargs.
    ``engine`` / ``stats`` are forwarded to :func:`repro.core.opt.optimize`
    (all optimize calls share the one stats object).  ``patterns=True``
    additionally enables the kernel-pattern rules of the fusion tier
    (rmsnorm / softmax-attention subgraphs rewritten to the hand-written
    Pallas primitives registered in ``repro.kernels.ops``) in the
    shape-directed pass.  ``loops=True`` (the closure-elimination tier)
    rewrites residual tail-recursive families into ``while_loop`` /
    ``scan_loop`` primitive applies (``repro.core.closure``) so parsed
    loops lower instead of falling back to the VM; when ``stats`` is
    given, any remaining fallback reasons land in
    ``stats.fallback_reasons`` (structured, see ``FallbackReason``).

    ``snapshot`` (the explain layer's IR-dump hook) is called as
    ``snapshot(stage, graph)`` after each pipeline stage — ``cloned`` /
    ``optimized`` / ``shape_opt`` / ``loop_lowered`` / ``final``, or
    ``graph_cache_hit`` when the optimized-graph tier answers.  None (the
    default) costs nothing.
    """
    if options is not None:
        opt = options.opt
        patterns = options.patterns
    gcache = options.graph_cache if options is not None else None
    # every phase below opens a span (see docs/observability.md for the
    # taxonomy); disarmed, span() is a single global None-check
    with obs_trace.span("compile_pipeline", graph=graph.name):
        gkey = None
        if gcache is not None and opt and infer_types and example_args is not None:
            # optimized-graph tier: key the PRE-optimization graph × abstract
            # signature × optimizer config; a hit deserializes the stored
            # post-optimize post-closure-elim graph and skips both expensive
            # phases, falling through to infer → lower → XLA below
            from .serialize import SerializeError

            hit = None
            with obs_trace.span("cache.graph_lookup", graph=graph.name) as sp:
                try:
                    gkey = gcache.graph_key(
                        graph, example_args,
                        opt=opt, patterns=patterns, loops=loops, engine=engine,
                    )
                except SerializeError:
                    sp.set(verdict="unkeyable")  # exotic constants: full pipeline
                else:
                    hit = gcache.load_graph(gkey)
                    sp.set(verdict="hit" if hit is not None else "miss")
            if hit is not None:
                try:
                    infer(hit, *example_args)  # re-derive abstracts (cheap)
                except InferenceError:
                    pass
                if stats is not None:
                    from .closure import analyze_blockers

                    with obs_trace.span("closure.analyze_blockers"):
                        stats.fallback_reasons = [
                            r.as_dict() for r in analyze_blockers(hit)
                        ]
                if snapshot is not None:
                    snapshot("graph_cache_hit", hit)
                    snapshot("final", hit)
                return hit
        with obs_trace.span("clone"):
            g = clone_graph(graph)
        if snapshot is not None:
            snapshot("cloned", g)
        if not opt:
            if snapshot is not None:
                snapshot("final", g)
            return g
        optimize(g, engine=engine, stats=stats)  # structural pass (no abstracts)
        if snapshot is not None:
            snapshot("optimized", g)
        if infer_types and example_args is not None:
            try:
                infer(g, *example_args)
            except InferenceError:
                pass  # dynamic program: shape-directed rules simply won't fire
            # shape-directed pass (kernel patterns need inferred shapes)
            optimize(g, engine=engine, stats=stats, patterns=patterns)
            if snapshot is not None:
                snapshot("shape_opt", g)
            if loops:
                from .closure import lower_loops

                report = lower_loops(g, stats=stats)
                if report.lowered:
                    # the rewrite leaves dead families and foldable glue; the
                    # cleanup pass also optimizes *inside* the loop subgraphs
                    optimize(g, engine=engine, stats=stats, patterns=patterns)
                if snapshot is not None:
                    snapshot("loop_lowered", g)
        if gkey is not None:
            with obs_trace.span("cache.graph_write", graph=graph.name):
                gcache.store_graph(gkey, g)
        if stats is not None:
            from .closure import analyze_blockers

            with obs_trace.span("closure.analyze_blockers"):
                stats.fallback_reasons = [r.as_dict() for r in analyze_blockers(g)]
        if snapshot is not None:
            snapshot("final", g)
        return g


def _wrap_profiled(inner: Callable, g: Graph, fuse: bool) -> Callable:
    """The ``CompileOptions.profile`` tier: while a
    :class:`repro.obs.profile.Profiler` is armed and the args are
    concrete, route calls to a lazily-built *instrumented eager* lowering
    (``lower_graph(profile=True)``) so every launch records wall time and
    bytes moved.  Disarmed — or under an outer jit trace, or when the
    graph doesn't lower — the wrapped runner is a single module-global
    None-check away from the ordinary tiers."""
    from repro.obs import profile as obs_profile

    from .lowering import LoweringError, lower_graph

    state: dict[str, Any] = {}

    def runner(*args):
        if obs_profile._ACTIVE is None or any(
            isinstance(a, jax.core.Tracer) for a in args
        ):
            return inner(*args)
        pfn = state.get("fn", _UNSET)
        if pfn is _UNSET:
            try:
                pfn = lower_graph(g, fuse=fuse, profile=True)
            except LoweringError:
                pfn = None  # VM-fallback graph: nothing to instrument
            state["fn"] = pfn
        if pfn is None:
            return inner(*args)
        return pfn(*args)

    runner.profiled = True
    for attr in ("lowered", "jitted", "aot", "cache_key", "degraded"):
        if hasattr(inner, attr):
            setattr(runner, attr, getattr(inner, attr))
    return runner


def _apply_transform(
    g: Graph, t: tuple, example: tuple | None, policy: str | int
) -> Graph:
    """Apply one pending AD stage.  ``example`` lets the builders run the
    primal through the full pipeline first (loops lower before J), which
    is what makes grad-of-loop adjoints closed first-order graphs."""
    kind = t[0]
    if kind == "grad":
        return build_grad_graph(
            g, t[1], example_args=example, checkpoint_policy=policy
        )
    if kind == "vag":
        return build_value_and_grad_graph(
            g, t[1], example_args=example, checkpoint_policy=policy
        )
    if kind == "vjp":
        return build_vjp_graph(g, example_args=example, checkpoint_policy=policy)
    raise ValueError(f"unknown transform {t!r}")


class MyiaFunction:
    """A function compiled through the Myia pipeline, specialized and cached
    per call signature (the paper's call-site specialization)."""

    def __init__(
        self,
        fn: Callable | None = None,
        graph: Graph | None = None,
        *,
        options: CompileOptions | None = None,
        name: str | None = None,
        transforms: tuple = (),
        backend: Any = _UNSET,
        opt: Any = _UNSET,
        fuse: Any = _UNSET,
        patterns: Any = _UNSET,
        in_specs: Any = _UNSET,
        program_cache: Any = _UNSET,
        trace: Any = _UNSET,
    ) -> None:
        if fn is None and graph is None:
            raise ValueError("need fn or graph")
        self._fn = fn
        self._graph = graph
        #: the resolved :class:`CompileOptions` — the single source of
        #: truth for every tier (the legacy per-tier attributes below are
        #: delegating properties over this object):
        #:
        #: * ``program_cache`` — AOT tier: a ProgramCache makes compiled
        #:   specializations durable (``jit(...).lower().compile()`` +
        #:   serialized executable), so a warm process skips XLA entirely.
        #: * ``fuse`` / ``patterns`` — fusion tier: clustered regions run
        #:   as generated Pallas kernels (docs/fusion.md).
        #: * ``in_specs`` — SPMD tier: per-argument sharding specs; active
        #:   only under a concrete mesh context, inert otherwise.
        #: * ``trace`` — observability tier: a Tracer armed for the
        #:   dynamic extent of every specialization.
        #: * ``checkpoint_policy`` — loop-adjoint carry recording (used
        #:   when pending AD ``transforms`` resolve at specialization).
        self.options = _resolve_options(
            options, "MyiaFunction", {
                "backend": backend, "opt": opt, "fuse": fuse,
                "patterns": patterns, "in_specs": in_specs,
                "program_cache": program_cache, "trace": trace,
            },
        )
        #: pending AD transforms, applied at specialization time *after*
        #: the primal has run the loop-lowering pipeline: a tuple of
        #: ``("grad", wrt)`` / ``("vag", wrt)`` / ``("vjp",)`` stages.
        #: Empty for plain ``@myia`` functions and for AD of straight-line
        #: programs (those build their adjoint graph eagerly).
        self.transforms = tuple(transforms)
        self._resolved: dict[tuple, Graph] = {}
        self._specializations: dict[tuple, Callable] = {}
        self.__name__ = name or (fn.__name__ if fn is not None else graph.name)
        if fn is not None:
            functools.update_wrapper(self, fn, updated=())

    # -- legacy attribute surface (delegates to .options) -----------------
    def _opt_property(field):  # noqa: N805 — descriptor factory, not a method
        def get(self):
            return getattr(self.options, field)

        def set_(self, value):
            self.options = dataclasses.replace(self.options, **{field: value})

        return property(get, set_, doc=f"delegates to CompileOptions.{field}")

    backend = _opt_property("backend")
    opt = _opt_property("opt")
    fuse = _opt_property("fuse")
    patterns = _opt_property("patterns")
    in_specs = _opt_property("in_specs")
    program_cache = _opt_property("program_cache")
    trace = _opt_property("trace")
    del _opt_property

    # -- graph access ---------------------------------------------------
    @property
    def graph(self) -> Graph:
        if self._graph is None:
            self._graph = parse_function(self._fn)
        return self._graph

    def __myia_graph_factory__(self) -> Graph:
        return self.graph

    # -- pending AD transforms -------------------------------------------
    def _resolved_graph(self, example: tuple | None) -> Graph:
        """The graph to compile: the primal with any pending AD transforms
        applied.  ``example`` is the full abstract signature of *this*
        function; each trailing ``vjp`` stage consumes one argument (the
        output cotangent), so the primal's own signature is the prefix."""
        if not self.transforms:
            return self.graph
        n_vjp = sum(1 for t in self.transforms if t[0] == "vjp")
        base_ex = example[: len(example) - n_vjp] if example is not None else None
        key = ("resolved", base_ex)
        hit = self._resolved.get(key)
        if hit is not None:
            return hit
        g = self.graph
        ex = base_ex
        for t in self.transforms:
            g = _apply_transform(g, t, ex, self.options.checkpoint_policy)
            # downstream stages differentiate the adjoint graph itself;
            # its signature matches the primal's (grad) so ex carries over
        self._resolved[key] = g
        return g

    # -- compilation ------------------------------------------------------
    def _sigkey(self, args: tuple) -> tuple:
        out = []
        for a in args:
            if is_array_like(a) or isinstance(a, np.generic):
                out.append(("arr", np.shape(a), np.dtype(a.dtype) if hasattr(a, "dtype") else None))
            elif isinstance(a, tuple):
                out.append(("tup", self._sigkey(a)))
            else:
                try:
                    hash(a)
                except TypeError:
                    # unhashable static (list, dict, …): its *content* is
                    # baked into the specialization, so the key must capture
                    # content — repr() truncates large arrays and collides
                    out.append(("val", type(a).__name__, _content_key(a)))
                else:
                    out.append(("val", type(a).__name__, a))
        return tuple(out)

    def _active_mesh(self):
        """The concrete mesh the SPMD tier should target, or None.

        None when no ``in_specs`` were configured, no mesh context is
        active, or the context's mesh is abstract (spec-resolution tests).
        A trivial 1×1 mesh still takes the spmd path — that identity with
        the single-device tier is pinned by tests."""
        if self.in_specs is None or self.backend != "jax":
            return None
        from repro.parallel import current_mesh_context

        ctx = current_mesh_context()
        if ctx is None or not isinstance(ctx.mesh, jax.sharding.Mesh):
            return None
        return ctx.mesh

    def specialize(self, args: tuple) -> Callable:
        if self.fuse:
            # fused runners bake the kernel mode in at trace time (the
            # FusedKernel dispatch runs under jit), so a mode switch must
            # select a different specialization, not reuse a stale trace
            from repro.kernels.ops import get_kernel_mode

            mode = get_kernel_mode()
        else:
            mode = None
        mesh = self._active_mesh()
        # key by shape AND device identity: a same-shape mesh over different
        # devices must not reuse a runner closed over the old mesh (same
        # identity rule the AOT cache key uses)
        from .jax_backend import mesh_descriptor

        meshkey = mesh_descriptor(mesh)
        key = (self.backend, self.fuse, self.patterns, mode, meshkey, self._sigkey(args))
        hit = self._specializations.get(key)
        if hit is not None:
            return hit
        with obs_trace.tracing(self.trace), obs_trace.span(
            "specialize", graph=self.__name__, fuse=self.fuse
        ):
            try:
                example = tuple(abstract_of_value(a) for a in args)
            except InferenceError:
                example = None  # e.g. a list static: skip inference, VM handles it
            base = self._resolved_graph(example) if self.transforms else self.graph
            g = compile_pipeline(base, example, options=self.options)
            runner = None
            if mesh is not None:
                runner = self._make_spmd_runner(g, args, mesh)
                # (spmd runners are never profile-wrapped: collectives
                # only execute under shard_map, not eagerly)
            if runner is None:
                runner = self._make_runner(g, args)
                if self.options.profile and self.backend == "jax":
                    runner = _wrap_profiled(runner, g, self.fuse)
            self._specializations[key] = runner
            return runner

    def _make_spmd_runner(self, g: Graph, example_args: tuple, mesh) -> Callable | None:
        """Sharded runner, or None → automatic single-device fallback (graph
        not first-order / non-array arguments / propagation failure)."""
        from .jax_backend import compile_graph_spmd
        from .spmd import SpmdError

        if not all(is_array_like(a) for a in example_args):
            return None
        try:
            return compile_graph_spmd(g, mesh, self.in_specs, fuse=self.fuse)
        except SpmdError:
            return None

    def _make_runner(self, g: Graph, example_args: tuple) -> Callable:
        if self.backend == "vm":
            def runner(*args):
                return VM().call(g, args)

            runner.lowered = False
            return runner
        # jax backend: arrays are dynamic (traced), everything else static.
        dyn_idx = [i for i, a in enumerate(example_args) if is_array_like(a)]
        static = {i: a for i, a in enumerate(example_args) if i not in set(dyn_idx)}
        lowered = try_lower(g, fuse=self.fuse)

        if (
            self.program_cache is not None
            and lowered is not None
            and len(dyn_idx) == len(example_args)
            and not any(isinstance(a, jax.core.Tracer) for a in example_args)
        ):
            # (tracer args mean we're specializing under an outer jit trace
            # — an AOT executable cannot be invoked there; use the jit tier)
            # AOT tier: durable compiled artifact, answered from the
            # persistent cache when this program was compiled before (by
            # this process or any earlier one)
            from .jax_backend import CompileFailed
            from .serialize import SerializeError

            try:
                aot = self.program_cache.load_or_compile(
                    g, example_args, fuse=self.fuse, lowered_fn=lowered
                )
            except SerializeError:
                pass  # not durable (exotic constants): ordinary tiers
            except CompileFailed:
                # bottom rung of the degraded-mode ladder: XLA would not
                # compile this specialization even after bounded retries
                # (docs/serving.md).  The reference VM evaluates the same
                # optimized graph eagerly — no XLA on the critical path,
                # slow but correct — and the downgrade is counted so a
                # serving fleet can alarm on it.
                self.program_cache.stats.vm_fallbacks += 1

                def runner(*args):
                    return VM().call(g, args)

                runner.lowered = False
                runner.degraded = "vm_oracle"
                return runner
            else:
                # the specialization key cannot tell a concrete array from
                # a same-shaped tracer, so this runner may later be handed
                # tracer args (the MyiaFunction called under an outer
                # jit/grad) — an AOT executable rejects those; route them
                # to a lazily-built ordinary jit of the same lowered fn
                state: dict[str, Any] = {}

                def runner(*args):
                    if any(isinstance(a, jax.core.Tracer) for a in args):
                        jitted = state.get("jit")
                        if jitted is None:
                            jitted = state["jit"] = jax.jit(lowered)
                        return jitted(*args)
                    return aot(*args)

                runner.lowered = True
                runner.aot = True
                runner.cache_key = aot.cache_key
                return runner

        def assemble(arrs) -> tuple:
            full: list[Any] = [None] * (len(arrs) + len(static))
            for i, v in static.items():
                full[i] = v
            for i, v in zip(dyn_idx, arrs):
                full[i] = v
            return tuple(full)

        if lowered is not None:
            def run(*arrs):
                return lowered(*assemble(arrs))
        else:
            # residual graph values (recursion, higher-order calls): the VM
            # evaluates, and jit traces *through* the interpreter.
            def run(*arrs):
                return VM().call(g, assemble(arrs))

        jitted = jax.jit(run)

        if lowered is not None:
            # Tiered compilation (only possible because the program is a
            # straight-line lowered function, not an interpreter trace):
            # the first call compiles at a low XLA optimization level —
            # a fraction of the full-opt compile time on CPU — and answers
            # from that; the second call onwards uses the fully optimized
            # ``jax.jit`` executable.  If the backend rejects the tier-0
            # options, the first call simply takes the normal jit path.
            state = {"calls": 0}

            def runner(*args):
                arrs = [args[i] for i in dyn_idx]
                state["calls"] += 1
                if state["calls"] == 1:
                    fast = None
                    try:
                        with obs_trace.span("xla.tier0_compile"):
                            fast = jitted.lower(*arrs).compile(
                                compiler_options=_TIER0_COMPILER_OPTIONS
                            )
                    except Exception:
                        pass  # unknown option/backend: use the full jit
                    if fast is not None:
                        # outside the try: a genuine runtime error must
                        # surface, not silently re-run under the full jit
                        return fast(*arrs)
                return jitted(*arrs)
        else:
            def runner(*args):
                return jitted(*[args[i] for i in dyn_idx])

        runner.lowered = lowered is not None
        runner.jitted = jitted
        return runner

    def __call__(self, *args: Any) -> Any:
        return self.specialize(args)(*args)

    # -- introspection (benchmarks / tests) --------------------------------
    def explain(self, *example_args: Any, dump_ir: str | None = None):
        """A structured compile report for this function at
        ``example_args``'s signature: per-cluster fusion verdicts, per-node
        decisions with reasons, sharding specs, cache-tier verdicts,
        checkpoint policies and residual VM-fallback reasons — see
        :class:`repro.obs.explain.ExplainReport`.  ``dump_ir="dir/"``
        additionally writes diffable per-stage IR text dumps."""
        from repro.obs.explain import explain_function

        return explain_function(self, example_args, dump_ir=dump_ir)

    def optimized_graph(self, *args: Any) -> Graph:
        example = tuple(abstract_of_value(a) for a in args)
        base = self._resolved_graph(example) if self.transforms else self.graph
        return compile_pipeline(base, example, options=self.options)

    def node_count(self, *args: Any, optimized: bool = True) -> int:
        g = self.optimized_graph(*args) if optimized else self.graph
        return count_nodes(g)


def myia(
    fn: Callable | None = None,
    *,
    options: CompileOptions | None = None,
    backend: Any = _UNSET,
    opt: Any = _UNSET,
    fuse: Any = _UNSET,
    patterns: Any = _UNSET,
    in_specs: Any = _UNSET,
    program_cache: Any = _UNSET,
    trace: Any = _UNSET,
):
    """Decorator: compile ``fn`` (pure Python subset) through the pipeline.

    Tier configuration arrives as one ``options=CompileOptions(...)``
    (the per-kwarg spelling still works but is deprecated — see the
    module docstring's migration note):

    * ``fuse=True`` turns on the fusion tier (clustered regions run as
      generated Pallas kernels); ``patterns=True`` additionally rewrites
      kernel-shaped subgraphs (rmsnorm, softmax-attention core) to the
      hand-written Pallas primitives.  Both default off: the unfused
      straight-line lowering remains the bit-exact reference.
    * ``in_specs`` (one sharding spec per argument) arms the SPMD tier:
      under an active concrete mesh context the optimized+fused graph is
      partitioned per-shard and executed under ``shard_map``; with no
      mesh active the single-device tiers run unchanged.
    * ``program_cache`` (a :class:`repro.core.jax_backend.ProgramCache`)
      arms the AOT tier: all-array specializations of lowerable graphs
      are compiled ahead of time and persisted, so a warm process reloads
      the XLA executable instead of recompiling (see docs/serving.md).
    * ``trace`` (a :class:`repro.obs.Tracer`) arms the observability
      tier: every specialization compiles with the tracer armed, so
      pipeline phases, inline waves and XLA compiles land in its buffer.
    """
    opts = _resolve_options(options, "myia", {
        "backend": backend, "opt": opt, "fuse": fuse, "patterns": patterns,
        "in_specs": in_specs, "program_cache": program_cache, "trace": trace,
    })

    def wrap(f: Callable) -> MyiaFunction:
        return MyiaFunction(f, options=opts)

    return wrap(fn) if fn is not None else wrap


# ---------------------------------------------------------------------------
# AD entry points (callable API + in-language macros)
# ---------------------------------------------------------------------------


def _as_graph(fn: Any) -> Graph:
    if isinstance(fn, Graph):
        return fn
    if isinstance(fn, MyiaFunction):
        return fn.graph
    return parse_function(fn)


def _macro_expand_grad(parser, block, ast_args):
    if len(ast_args) < 1:
        raise MyiaSyntaxError("grad() takes a function argument")
    fn_node = parser.expr(block, ast_args[0])
    if not (isinstance(fn_node, Constant) and isinstance(fn_node.value, Graph)):
        raise MyiaSyntaxError("grad() macro requires a statically-known function")
    wrt: int | tuple = 0
    if len(ast_args) > 1:
        import ast as _ast

        a1 = ast_args[1]
        if isinstance(a1, _ast.Constant):
            wrt = a1.value
        elif isinstance(a1, _ast.Tuple):
            wrt = tuple(e.value for e in a1.elts)
        else:
            raise MyiaSyntaxError("grad() wrt must be a literal")
    return Constant(build_grad_graph(fn_node.value, wrt))


def _macro_expand_vag(parser, block, ast_args):
    fn_node = parser.expr(block, ast_args[0])
    if not (isinstance(fn_node, Constant) and isinstance(fn_node.value, Graph)):
        raise MyiaSyntaxError("value_and_grad() macro requires a statically-known function")
    return Constant(build_value_and_grad_graph(fn_node.value))


def _transform_entry(
    fn: Any, transform: tuple, opts: CompileOptions, caller: str
) -> MyiaFunction:
    """Shared construction path of the AD entry points.

    Straight-line primals build their adjoint graph eagerly (back-compat:
    ``grad(f).graph`` is the adjoint, and the grad *macro* path stays
    parse-time).  Primals containing loops or recursion defer the
    transform to specialization (``MyiaFunction.transforms``), so the
    primal runs the loop-lowering pipeline — with the concrete signature
    — before J sees it; that ordering is what keeps grad-of-loop programs
    off the VM.  Chaining (``grad(grad(f))``) extends the pending tuple."""
    if isinstance(fn, MyiaFunction) and fn.transforms:
        return MyiaFunction(
            fn=fn._fn, graph=fn._graph, options=opts,
            transforms=fn.transforms + (transform,),
            name=f"{transform[0]}_{fn.__name__}",
        )
    primal = _as_graph(fn)
    if _needs_loop_pipeline(primal):
        return MyiaFunction(
            graph=primal, options=opts, transforms=(transform,),
            name=f"{transform[0]}_{primal.name}",
        )
    g = _apply_transform(primal, transform, None, opts.checkpoint_policy)
    return MyiaFunction(graph=g, options=opts, name=g.name)


def grad(
    fn: Any,
    wrt: int | tuple[int, ...] = 0,
    *,
    options: CompileOptions | None = None,
    backend: Any = _UNSET,
    opt: Any = _UNSET,
    fuse: Any = _UNSET,
    patterns: Any = _UNSET,
    in_specs: Any = _UNSET,
    program_cache: Any = _UNSET,
    trace: Any = _UNSET,
):
    """Reverse-mode gradient of a scalar-output function (paper §3.2).

    The adjoint takes the same arguments as ``fn``, so every tier in
    ``options`` (SPMD ``in_specs``, the AOT ``program_cache``, ``trace``)
    carries over unchanged — full parity with ``myia``."""
    opts = _resolve_options(options, "grad", {
        "backend": backend, "opt": opt, "fuse": fuse, "patterns": patterns,
        "in_specs": in_specs, "program_cache": program_cache, "trace": trace,
    })
    return _transform_entry(fn, ("grad", wrt), opts, "grad")


def value_and_grad(
    fn: Any,
    wrt: int | tuple[int, ...] = 0,
    *,
    options: CompileOptions | None = None,
    backend: Any = _UNSET,
    opt: Any = _UNSET,
    fuse: Any = _UNSET,
    patterns: Any = _UNSET,
    in_specs: Any = _UNSET,
    program_cache: Any = _UNSET,
    trace: Any = _UNSET,
):
    opts = _resolve_options(options, "value_and_grad", {
        "backend": backend, "opt": opt, "fuse": fuse, "patterns": patterns,
        "in_specs": in_specs, "program_cache": program_cache, "trace": trace,
    })
    return _transform_entry(fn, ("vag", wrt), opts, "value_and_grad")


def vjp(
    fn: Any,
    *,
    options: CompileOptions | None = None,
    backend: Any = _UNSET,
    opt: Any = _UNSET,
    fuse: Any = _UNSET,
    patterns: Any = _UNSET,
    in_specs: Any = _UNSET,
    program_cache: Any = _UNSET,
    trace: Any = _UNSET,
):
    opts = _resolve_options(options, "vjp", {
        "backend": backend, "opt": opt, "fuse": fuse, "patterns": patterns,
        "in_specs": in_specs, "program_cache": program_cache, "trace": trace,
    })
    return _transform_entry(fn, ("vjp",), opts, "vjp")


grad.__is_myia_macro__ = True
grad.__myia_macro_expand__ = _macro_expand_grad
value_and_grad.__is_myia_macro__ = True
value_and_grad.__myia_macro_expand__ = _macro_expand_vag
