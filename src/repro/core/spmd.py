"""SPMD tier: thread sharding through the optimize→fuse→lower pipeline.

The paper's closing argument (§4) is that once the ST adjoint has been
inlined and simplified, the remaining straight-line graph is "amenable to
ahead-of-time optimization".  Sharding is such an optimization: like
Dex/JAX-style staged compilation, the partitioning of every tensor is a
*property of the IR*, propagated ahead of time — not a bolt-on at the
execution layer.  This module takes an optimized, shape-inferred,
first-order graph plus per-parameter sharding specs (the same
PartitionSpec vocabulary as ``repro.distributed.sharding``) and produces
the **per-shard program** that ``shard_map`` executes on every device:

1. **Propagation** (:func:`propagate`): a forward pass over the inferred
   abstracts assigns each node a spec — which mesh axes shard which dims.
   Elementwise ops merge operand specs; matmul contracts; reductions drop
   reduced dims; broadcasts get a *backward refinement* pass (an expanded
   dim can adopt its consumers' sharding for free — each shard simply
   materializes a smaller broadcast).
2. **Resharding points**: where the propagated specs disagree with what an
   op needs, the transform inserts explicit collectives —
   ``psum_axes``/``pmax_axes`` after cross-shard reductions and
   contractions, ``all_gather_axes`` to replicate a sharded value,
   ``shard_slice`` (index math only, no communication) to re-partition a
   replicated one.  Collectives classify as *opaque* in the fusion
   partitioner, so no cluster ever spans a resharding point, and the
   optimizer refuses to fold them (``opt.try_rules``).
3. **Localization** (:func:`shard_graph`): shape-carrying constants
   (``broadcast_to``/``unreduce``/``unbroadcast`` targets) are rewritten
   to per-shard shapes and the transformed graph is re-inferred at the
   *local* parameter shapes, so downstream fusion codegen blocks Pallas
   kernels for the shard a device actually owns.

The result lowers through the ordinary ``lower_graph(fuse=...)`` path and
runs under ``jax.shard_map`` (see ``jax_backend.compile_graph_spmd``).
When no mesh is active the tier simply never engages — the single-device
lowering of PRs 1–2 is the fallback, and the per-shard program on a 1×1
mesh is that same program (the identity the tests pin down).

Specs are internally tuples of per-dim axis-name tuples (``()`` =
replicated); :func:`normalize_spec` accepts ``jax.sharding.PartitionSpec``,
plain tuples, axis-name strings and ``None``, with the same divisibility
fallback as ``distributed.sharding`` (a dim that does not divide by its
mesh axes replicates).
"""

from __future__ import annotations

from typing import Any, Sequence

import numpy as np

from . import primitives as P
from .fusion import BROADCAST, ELEMENTWISE
from .infer import AArray, ATuple, AbstractValue, infer
from .ir import Apply, Constant, Graph, Node, toposort
from .lowering import lowering_blockers

__all__ = [
    "SpmdError",
    "SpmdPlan",
    "ShardedGraph",
    "normalize_spec",
    "propagate",
    "shard_graph",
    "spec_to_partition",
]


class SpmdError(Exception):
    """The graph cannot be sharded; callers fall back to single-device."""


#: per-dim spec entry: a tuple of mesh axis names, () = replicated
Entry = tuple
#: array spec: one Entry per dim
Spec = tuple

_SCALAR = ("<scalar>",)  # sentinel spec for non-array values


class _TSpec:
    """Spec of a tuple value (mirrors ATuple)."""

    __slots__ = ("elements",)

    def __init__(self, elements: tuple) -> None:
        self.elements = tuple(elements)

    def __eq__(self, o: object) -> bool:
        return isinstance(o, _TSpec) and o.elements == self.elements

    def __hash__(self) -> int:
        return hash(self.elements)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"T{self.elements!r}"


def _is_replicated(spec: Any) -> bool:
    if spec is _SCALAR:
        return True
    if isinstance(spec, _TSpec):
        return all(_is_replicated(e) for e in spec.elements)
    return all(e == () for e in spec)


def normalize_spec(
    spec: Any, abstract: AbstractValue, mesh_axes: dict[str, int]
) -> Any:
    """Normalize a user-facing spec against an abstract value.

    Accepts ``PartitionSpec``, tuple/list of entries (``None`` | axis name |
    tuple of names), or ``None`` (fully replicated).  Unknown mesh axes are
    dropped; a dim that does not divide by the product of its axis sizes
    replicates (the ``distributed.sharding`` divisibility rule); no mesh
    axis may shard two dims.
    """
    if isinstance(abstract, ATuple):
        parts = list(spec) if isinstance(spec, (tuple, list)) else [spec] * len(
            abstract.elements
        )
        if len(parts) != len(abstract.elements):
            raise SpmdError(f"tuple spec arity mismatch: {spec!r} vs {abstract!r}")
        return _TSpec(
            tuple(normalize_spec(s, a, mesh_axes) for s, a in zip(parts, abstract.elements))
        )
    if not isinstance(abstract, AArray):
        if spec not in (None, ()) and not _is_partition_like_empty(spec):
            raise SpmdError(f"cannot shard non-array {abstract!r} with {spec!r}")
        return _SCALAR
    entries = list(spec) if spec is not None else []
    entries = entries[: len(abstract.shape)]
    entries += [None] * (len(abstract.shape) - len(entries))
    used: set[str] = set()
    out: list[Entry] = []
    for dim, e in zip(abstract.shape, entries):
        axes = () if e is None else ((e,) if isinstance(e, str) else tuple(e))
        axes = tuple(a for a in axes if a in mesh_axes and a not in used)
        total = int(np.prod([mesh_axes[a] for a in axes])) if axes else 1
        if axes and dim % total == 0:
            out.append(axes)
            used.update(axes)
        else:
            out.append(())
    return tuple(out)


def _is_partition_like_empty(spec: Any) -> bool:
    try:
        return len(tuple(spec)) == 0
    except TypeError:
        return False


def spec_to_partition(spec: Any):
    """Internal spec → ``jax.sharding.PartitionSpec`` (tuples for tuples)."""
    from jax.sharding import PartitionSpec as PS

    if spec is _SCALAR:
        return PS()
    if isinstance(spec, _TSpec):
        return tuple(spec_to_partition(e) for e in spec.elements)
    return PS(*[None if e == () else (e[0] if len(e) == 1 else e) for e in spec])


def _shape_of(ab: AbstractValue) -> tuple[int, ...] | None:
    return ab.shape if isinstance(ab, AArray) else None


def local_shape(shape: Sequence[int], spec: Spec, mesh_axes: dict[str, int]) -> tuple:
    out = []
    for dim, axes in zip(shape, spec):
        total = int(np.prod([mesh_axes[a] for a in axes])) if axes else 1
        out.append(dim // total)
    return tuple(out)


# ---------------------------------------------------------------------------
# Per-primitive propagation rules
# ---------------------------------------------------------------------------


class _Res:
    """One rule decision: the node's output spec, the spec each argument
    must be *provided at* (None: leave untouched — statics, scalars), the
    collectives to append after the local computation, and static-constant
    rewrites (arg index → new value) that localize baked-in shapes."""

    __slots__ = ("out", "reqs", "post", "rewrites")

    def __init__(self, out, reqs, post=(), rewrites=None) -> None:
        self.out = out
        self.reqs = reqs
        self.post = tuple(post)  # sequence of ("psum" | "pmax", axes-tuple)
        self.rewrites = rewrites or {}


def _merge_elementwise(arg_specs, arg_shapes, out_shape):
    """NumPy-broadcast-aware merge: per output dim pick the first usable
    sharding among the size-matching operands; each mesh axis at most
    once.  Returns (out_spec, per-arg required spec)."""
    rank = len(out_shape)
    used: set[str] = set()
    out: list[Entry] = []
    for d in range(rank):
        chosen: Entry = ()
        for spec, shp in zip(arg_specs, arg_shapes):
            if spec is _SCALAR or shp is None:
                continue
            ad = len(shp) - (rank - d)
            if ad < 0 or shp[ad] != out_shape[d] or out_shape[d] == 1:
                continue
            e = spec[ad]
            if e and not (set(e) & used):
                chosen = e
                break
        out.append(chosen)
        used.update(chosen)
    reqs = []
    for spec, shp in zip(arg_specs, arg_shapes):
        if spec is _SCALAR or shp is None:
            reqs.append(None)
            continue
        req = []
        for ad in range(len(shp)):
            d = rank - len(shp) + ad
            req.append(out[d] if shp[ad] == out_shape[d] and shp[ad] != 1 else ())
        reqs.append(tuple(req))
    return tuple(out), reqs


def _const_value(node: Node) -> Any:
    if isinstance(node, Constant):
        return node.value
    raise SpmdError(f"expected a static constant, got {node!r}")


def _norm_axes(axes: Any, rank: int) -> tuple[int, ...]:
    if axes is None:
        return tuple(range(rank))
    if isinstance(axes, int):
        axes = (axes,)
    return tuple(a % rank for a in axes)


class _Rules:
    """Forward propagation rules.  ``self.spec_of`` resolves a node's
    current spec; each rule returns a :class:`_Res`."""

    def __init__(self, mesh_axes: dict[str, int], bspec: dict[int, Spec]) -> None:
        self.mesh_axes = mesh_axes
        self.bspec = bspec  # broadcast-node spec overrides (refinement)

    def apply(self, node: Apply, prim: P.Primitive, arg_specs, arg_abs, out_ab) -> _Res:
        name = prim.name
        if name in ELEMENTWISE or name in ("zeros_like", "stop_gradient", "sign"):
            return self._elementwise(node, arg_specs, arg_abs, out_ab)
        handler = getattr(self, f"_r_{name}", None)
        if handler is not None:
            return handler(node, arg_specs, arg_abs, out_ab)
        return self._default(node, arg_specs, arg_abs, out_ab)

    # -- generic ----------------------------------------------------------
    def _elementwise(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        out_shape = _shape_of(out_ab)
        if out_shape is None:  # scalar compute: replicated by construction
            return _Res(_SCALAR, [None] * len(arg_specs))
        shapes = [_shape_of(a) for a in arg_abs]
        out, reqs = _merge_elementwise(arg_specs, shapes, out_shape)
        return _Res(out, reqs)

    def _default(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        """Unknown primitive: compute fully replicated (gather every
        sharded operand) — always sound, never fast."""
        reqs = []
        for spec, ab in zip(arg_specs, arg_abs):
            if isinstance(spec, _TSpec) and not _is_replicated(spec):
                raise SpmdError(
                    f"cannot replicate sharded tuple operand of {node!r}"
                )
            reqs.append(
                tuple(() for _ in spec) if isinstance(spec, tuple) and spec is not _SCALAR
                else None
            )
        shape = _shape_of(out_ab)
        if shape is None and isinstance(out_ab, ATuple):
            out = _TSpec(tuple(
                _SCALAR if not isinstance(e, AArray) else tuple(() for _ in e.shape)
                for e in out_ab.elements
            ))
        elif shape is None:
            out = _SCALAR
        else:
            out = tuple(() for _ in shape)
        return _Res(out, reqs)

    # -- structure --------------------------------------------------------
    def _r_make_tuple(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        return _Res(_TSpec(tuple(arg_specs)), [None] * len(arg_specs))

    def _r_tuple_getitem(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        t = arg_specs[0]
        i = _const_value(node.args[1])
        if not isinstance(t, _TSpec):
            raise SpmdError(f"tuple_getitem on non-tuple spec {t!r}")
        return _Res(t.elements[i], [None, None])

    def _r_gadd(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        # gradient addition is elementwise on tuples (values.gadd_values);
        # identically-sharded operands add shard-locally
        a, b = arg_specs
        if isinstance(a, _TSpec) or isinstance(b, _TSpec):
            if a == b:
                return _Res(a, [None, None])
            raise SpmdError(f"gadd of differently-sharded tuples: {a!r} vs {b!r}")
        return self._elementwise(node, arg_specs, arg_abs, out_ab)

    def _r_tuple_setitem(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        # second-order adjoints update gradient tuples in place: the
        # result keeps every element's spec, with slot i taking the new
        # value's spec (no resharding required on any operand)
        t, _i, v = arg_specs
        i = _const_value(node.args[1])
        if not isinstance(t, _TSpec):
            raise SpmdError(f"tuple_setitem on non-tuple spec {t!r}")
        elts = list(t.elements)
        elts[i] = v
        return _Res(_TSpec(tuple(elts)), [None, None, None])

    # -- structured loops --------------------------------------------------
    def _loop(self, n_graphs: int, node, arg_specs, arg_abs, out_ab) -> _Res:
        """``while_loop`` / ``scan_loop``: the body is an opaque sub-graph
        to this per-node propagation, so the sound contraction is to run
        the whole loop replicated — sharded carries and extras are gathered
        at entry and the exit tuple (including any saved-carry stacks the
        adjoint threads) comes out replicated.  Per-shard loop bodies would
        need a carry-spec fixpoint through the step graph; until then this
        keeps loop-adjoint programs *eligible* for the SPMD tier (the rest
        of the graph still shards) instead of failing propagation."""
        reqs: list[Any] = []
        for i, spec in enumerate(arg_specs):
            if i < n_graphs or spec is _SCALAR:
                reqs.append(None)  # sub-graphs / static ints / scalar operands
            elif isinstance(spec, _TSpec):
                if not _is_replicated(spec):
                    raise SpmdError(
                        f"cannot gather sharded tuple carry of {node!r}"
                    )
                reqs.append(None)
            else:
                reqs.append(tuple(() for _ in spec))
        if isinstance(out_ab, ATuple):
            out: Any = _TSpec(tuple(
                _SCALAR if not isinstance(e, AArray) else tuple(() for _ in e.shape)
                for e in out_ab.elements
            ))
        else:
            shape = _shape_of(out_ab)
            out = _SCALAR if shape is None else tuple(() for _ in shape)
        return _Res(out, reqs)

    def _r_while_loop(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        return self._loop(3, node, arg_specs, arg_abs, out_ab)

    def _r_scan_loop(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        return self._loop(2, node, arg_specs, arg_abs, out_ab)

    # -- linear algebra ---------------------------------------------------
    def _r_matmul(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        la, ra = arg_abs
        ls, rs = _shape_of(la), _shape_of(ra)
        out_shape = _shape_of(out_ab)
        if ls is None or rs is None or len(ls) < 2 or len(rs) < 2 or out_shape is None:
            return self._default(node, arg_specs, arg_abs, out_ab)
        lspec = list(arg_specs[0]) if arg_specs[0] is not _SCALAR else [()] * len(ls)
        rspec = list(arg_specs[1]) if arg_specs[1] is not _SCALAR else [()] * len(rs)
        lreq, rreq = list(lspec), list(rspec)
        cl, cr = lspec[-1], rspec[-2]
        post = []
        if cl and cl == cr:
            post.append(("psum", cl))  # tensor-parallel contraction
        else:
            if cl:
                lreq[-1] = ()  # gather lhs on k
            if cr:
                rreq[-2] = ()  # gather rhs on k
        # batch dims: both operands execute the SAME local batch block, so
        # a broadcastable batch dim merges like elementwise (size-1 dims
        # broadcast locally; matching dims must be co-sharded)
        rank = len(out_shape)
        used: set[str] = set(post[0][1]) if post else set()
        out: list[Entry] = []
        for d in range(rank - 2):
            chosen: Entry = ()
            for spec, shp in ((lspec, ls), (rspec, rs)):
                ad = len(shp) - 2 - (rank - 2 - d)
                if ad < 0 or shp[ad] != out_shape[d] or out_shape[d] == 1:
                    continue
                e = tuple(spec[ad])
                if e and not (set(e) & used):
                    chosen = e
                    break
            out.append(chosen)
            used.update(chosen)
            for spec, req, shp in ((lspec, lreq, ls), (rspec, rreq, rs)):
                ad = len(shp) - 2 - (rank - 2 - d)
                if ad >= 0:
                    req[ad] = (
                        chosen if (shp[ad] == out_shape[d] and shp[ad] != 1) else ()
                    )
        # m from lhs, n from rhs
        for spec, req, idx in (
            (lspec, lreq, len(ls) - 2),
            (rspec, rreq, len(rs) - 1),
        ):
            e = spec[idx]
            if e and not (set(e) & used):
                out.append(e)
                used.update(e)
            else:
                if e:
                    req[idx] = ()
                out.append(())
        return _Res(tuple(out), [tuple(lreq), tuple(rreq)], post)

    def _r_mT(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        s = arg_specs[0]
        if s is _SCALAR or len(s) < 2:
            return self._default(node, arg_specs, arg_abs, out_ab)
        out = tuple(s[:-2]) + (s[-1], s[-2])
        return _Res(out, [tuple(s)])

    def _r_transpose(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        s = arg_specs[0]
        perm = _const_value(node.args[1])
        if s is _SCALAR:
            return self._default(node, arg_specs, arg_abs, out_ab)
        return _Res(tuple(s[p] for p in perm), [tuple(s), None])

    def _r_reshape(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        # conservative: reshape runs on the replicated (global) value
        s = arg_specs[0]
        req = tuple(() for _ in s) if s is not _SCALAR else None
        shape = _shape_of(out_ab)
        out = tuple(() for _ in shape) if shape is not None else _SCALAR
        return _Res(out, [req, None])

    # -- reductions -------------------------------------------------------
    def _reduce(self, kind, node, arg_specs, arg_abs, out_ab) -> _Res:
        x_ab = arg_abs[0]
        xs = _shape_of(x_ab)
        spec = arg_specs[0]
        if xs is None or spec is _SCALAR:
            return self._default(node, arg_specs, arg_abs, out_ab)
        axes = _norm_axes(_const_value(node.args[1]), len(xs))
        keepdims = bool(_const_value(node.args[2]))
        comm: list[str] = []
        out: list[Entry] = []
        for d, e in enumerate(spec):
            if d in axes:
                comm.extend(e)
                if keepdims:
                    out.append(())
            else:
                out.append(e)
        out_spec: Any = tuple(out) if _shape_of(out_ab) is not None else _SCALAR
        post = [(kind, tuple(comm))] if comm else []
        return _Res(out_spec, [tuple(spec), None, None], post)

    def _r_reduce_sum(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        return self._reduce("psum", node, arg_specs, arg_abs, out_ab)

    def _r_reduce_max(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        return self._reduce("pmax", node, arg_specs, arg_abs, out_ab)

    def _r_unbroadcast(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        xs = _shape_of(arg_abs[0])
        spec = arg_specs[0]
        out_shape = _shape_of(out_ab)
        if xs is None or spec is _SCALAR or out_shape is None:
            return self._default(node, arg_specs, arg_abs, out_ab)
        ndiff = len(xs) - len(out_shape)
        comm: list[str] = []
        out: list[Entry] = []
        for d, e in enumerate(spec):
            if d < ndiff:
                comm.extend(e)  # summed-away leading dim
            elif out_shape[d - ndiff] == 1 and xs[d] != 1:
                comm.extend(e)  # keepdims-style sum
                out.append(())
            else:
                out.append(e)
        post = [("psum", tuple(comm))] if comm else []
        rewrites = {1: local_shape(out_shape, tuple(out), self.mesh_axes)}
        return _Res(tuple(out), [tuple(spec), None], post, rewrites)

    # -- broadcasts (refinable) -------------------------------------------
    def _r_broadcast_to(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        xs = _shape_of(arg_abs[0])
        out_shape = _shape_of(out_ab)
        if out_shape is None:
            return self._default(node, arg_specs, arg_abs, out_ab)
        spec = arg_specs[0]
        if spec is _SCALAR:
            xs, spec = (), ()
        # right-aligned dim map: out dim -> x dim (retained) or expanded
        mapping: dict[int, int] = {}
        expanded: set[int] = set()
        for d in range(len(out_shape)):
            ad = len(xs) - (len(out_shape) - d)
            if ad >= 0 and xs[ad] == out_shape[d] and out_shape[d] != 1:
                mapping[d] = ad
            else:
                expanded.add(d)
        out = self._broadcast_refined(node, spec, mapping, expanded, out_shape)
        x_req = None
        if xs:
            # x dims not in the mapping are size-1: those broadcast locally
            inv = {ad: d for d, ad in mapping.items()}
            x_req = tuple(out[inv[ad]] if ad in inv else () for ad in range(len(xs)))
        rewrites = {1: local_shape(out_shape, out, self.mesh_axes)}
        return _Res(out, [x_req, None], (), rewrites)

    def _r_unreduce(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        xs = _shape_of(arg_abs[0])
        out_shape = _shape_of(out_ab)
        if out_shape is None:
            return self._default(node, arg_specs, arg_abs, out_ab)
        spec = arg_specs[0]
        axes = _norm_axes(_const_value(node.args[2]), len(out_shape))
        keepdims = bool(_const_value(node.args[3]))
        mapping: dict[int, int] = {}
        expanded: set[int] = set(axes)
        if keepdims:
            for d in range(len(out_shape)):
                if d not in expanded:
                    mapping[d] = d
                elif xs is not None and xs[d] == out_shape[d]:
                    # size already matched: no expansion happened
                    mapping[d] = d
                    expanded.discard(d)
        else:
            ad = 0
            for d in range(len(out_shape)):
                if d not in expanded:
                    mapping[d] = ad
                    ad += 1
        if spec is _SCALAR:
            xs, spec = (), ()
            mapping = {}
            expanded = set(range(len(out_shape)))
        out = self._broadcast_refined(node, spec, mapping, expanded, out_shape)
        x_req = None
        if xs:
            inv = {ad: d for d, ad in mapping.items()}
            x_req = tuple(
                out[inv[ad]] if ad in inv else () for ad in range(len(xs))
            )
        rewrites = {1: local_shape(out_shape, out, self.mesh_axes)}
        return _Res(out, [x_req, None, None, None], (), rewrites)

    def _broadcast_refined(self, node, x_spec, mapping, expanded, out_shape) -> Spec:
        override = self.bspec.get(node._id)
        out: list[Entry] = []
        used: set[str] = set()
        for d in range(len(out_shape)):
            if d in mapping:
                e = x_spec[mapping[d]] if x_spec else ()
            else:
                e = override[d] if override is not None and d < len(override) else ()
            total = int(np.prod([self.mesh_axes[a] for a in e])) if e else 1
            if e and out_shape[d] != 1 and out_shape[d] % total == 0 and not (set(e) & used):
                out.append(tuple(e))
                used.update(e)
            else:
                out.append(())
        return tuple(out)

    # -- gather / scatter --------------------------------------------------
    def _r_take(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        xs, is_ = _shape_of(arg_abs[0]), _shape_of(arg_abs[1])
        out_shape = _shape_of(out_ab)
        if xs is None or out_shape is None:
            return self._default(node, arg_specs, arg_abs, out_ab)
        x_spec = list(arg_specs[0]) if arg_specs[0] is not _SCALAR else [()] * len(xs)
        i_spec = (
            list(arg_specs[1])
            if arg_specs[1] is not _SCALAR and is_ is not None
            else []
        )
        x_req = list(x_spec)
        x_req[0] = ()  # the table's indexed dim must be whole on each shard
        out: list[Entry] = []
        used: set[str] = set()
        for e in i_spec:
            out.append(e if not (set(e) & used) else ())
            used.update(e)
        for ad in range(1, len(xs)):
            e = x_spec[ad]
            if e and not (set(e) & used):
                out.append(e)
                used.update(e)
            else:
                if e:
                    x_req[ad] = ()
                out.append(())
        i_req = tuple(i_spec) if i_spec else None
        return _Res(tuple(out), [tuple(x_req), i_req])

    def _r_one_hot(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        is_ = _shape_of(arg_abs[0])
        out_shape = _shape_of(out_ab)
        if is_ is None or out_shape is None:
            return self._default(node, arg_specs, arg_abs, out_ab)
        i_spec = arg_specs[0] if arg_specs[0] is not _SCALAR else tuple(() for _ in is_)
        out = tuple(i_spec) + ((),)
        return _Res(out, [tuple(i_spec), None, None])

    def _r_index_add(self, node, arg_specs, arg_abs, out_ab) -> _Res:
        bs, is_, vs = (_shape_of(a) for a in arg_abs)
        if bs is None or vs is None:
            return self._default(node, arg_specs, arg_abs, out_ab)
        i_spec = (
            tuple(arg_specs[1])
            if arg_specs[1] is not _SCALAR and is_ is not None
            else ()
        )
        i_rank = len(is_) if is_ is not None else 0
        base_req = tuple(() for _ in bs)  # scatter target replicated
        # updates: indexed dims follow idx's sharding, payload dims replicated
        v_req = tuple(i_spec) + tuple(() for _ in range(len(vs) - i_rank))
        comm = tuple(a for e in i_spec for a in e)
        post = [("psum", comm)] if comm else []
        return _Res(base_req, [base_req, i_spec or None, v_req], post)


# ---------------------------------------------------------------------------
# The propagation pass
# ---------------------------------------------------------------------------


class SpmdPlan:
    """Result of :func:`propagate`: node spec table + accounting."""

    __slots__ = ("graph", "mesh_axes", "in_specs", "spec", "post", "out_spec", "stats")

    def __init__(self, graph, mesh_axes, in_specs, spec, post, out_spec, stats) -> None:
        self.graph = graph
        self.mesh_axes = dict(mesh_axes)
        self.in_specs = in_specs
        self.spec = spec  # node id -> Spec | _TSpec | _SCALAR
        self.post = post  # node id -> tuple of ("psum"|"pmax", axes)
        self.out_spec = out_spec
        self.stats = stats

    def spec_of(self, node: Node) -> Any:
        got = self.spec.get(node._id)
        if got is not None:
            return got
        return _spec_of_leaf(node)


def _spec_of_leaf(node: Node) -> Any:
    """Spec of a node outside the spec table: constants are replicated."""
    if isinstance(node, Constant):
        ab = node.abstract
        shp = _shape_of(ab) if ab is not None else None
        if shp is None:
            try:
                shp = tuple(int(d) for d in np.shape(node.value))
            except Exception:
                return _SCALAR
            if shp == () and not hasattr(node.value, "shape"):
                return _SCALAR
        return tuple(() for _ in shp)
    raise SpmdError(f"no spec for {node!r}")


def _check_shardable(graph: Graph) -> list[Apply]:
    blockers = lowering_blockers(graph)
    if blockers:
        raise SpmdError("graph is not first-order straight-line: " + "; ".join(blockers))
    topo = [n for n in toposort(graph) if isinstance(n, Apply)]
    for n in topo:
        if n.abstract is None:
            raise SpmdError(f"node {n!r} has no inferred abstract (run infer first)")
    return topo


def propagate(
    graph: Graph,
    in_specs: Sequence[Any],
    mesh_axes: dict[str, int],
    *,
    max_refine: int = 4,
) -> SpmdPlan:
    """Assign a sharding spec to every node of ``graph``.

    Forward abstract-interpretation over the inferred abstracts with a
    bounded backward-refinement loop for broadcast-family nodes: an
    expanded dim adopts the merged sharding of its consumers (each shard
    then materializes only its slice of the broadcast — no communication).
    """
    topo = _check_shardable(graph)
    if len(in_specs) != len(graph.parameters):
        raise SpmdError(
            f"{graph.name} has {len(graph.parameters)} parameters, "
            f"got {len(in_specs)} in_specs"
        )
    params_norm = [
        normalize_spec(s, p.abstract, mesh_axes)
        for s, p in zip(in_specs, graph.parameters)
    ]
    live = {n._id for n in topo}

    bspec: dict[int, Spec] = {}
    spec: dict[int, Any] = {}
    post: dict[int, tuple] = {}
    for _ in range(max_refine):
        rules = _Rules(mesh_axes, bspec)
        spec = {}
        post = {}
        for p, s in zip(graph.parameters, params_norm):
            spec[p._id] = s

        def spec_of(node: Node) -> Any:
            got = spec.get(node._id)
            return got if got is not None else _spec_of_leaf(node)

        results: dict[int, _Res] = {}
        for n in topo:
            prim = n.fn.value
            arg_specs = [spec_of(a) for a in n.args]
            arg_abs = [a.abstract for a in n.args]
            res = rules.apply(n, prim, arg_specs, arg_abs, n.abstract)
            results[n._id] = res
            spec[n._id] = res.out
            if res.post:
                post[n._id] = res.post
        # backward refinement: broadcast expanded dims adopt consumer specs
        new_bspec: dict[int, Spec] = {}
        for n in reversed(topo):
            prim = n.fn.value
            if prim.name not in BROADCAST:
                continue
            out_shape = _shape_of(n.abstract)
            if out_shape is None:
                continue
            users = [u for (u, _i) in n.users if u._id in live]
            desired: list[Entry] = [()] * len(out_shape)
            for u in users:
                req = _user_demand(results.get(u._id), u, n, len(out_shape))
                if req is None:
                    continue
                for d, e in enumerate(req):
                    if e and not desired[d]:
                        desired[d] = tuple(e)
            if any(desired):
                new_bspec[n._id] = tuple(desired)
        if new_bspec == bspec:
            break
        bspec = new_bspec

    out_spec = (
        spec.get(graph.return_._id)
        if graph.return_._id in spec
        else _spec_of_leaf(graph.return_)
    )
    stats = _plan_stats(graph, topo, spec, post, params_norm)
    return SpmdPlan(graph, mesh_axes, params_norm, spec, post, out_spec, stats)


def _user_demand(res: _Res | None, user: Apply, node: Node, rank: int):
    """What spec does ``user`` require ``node`` at (from the recorded rule
    decision)?  None if unknown / not an array requirement."""
    if res is None:
        return None
    for a, req in zip(user.args, res.reqs):
        if a is node and isinstance(req, tuple) and len(req) == rank:
            return req
    return None


def _plan_stats(graph, topo, spec, post, params_norm) -> dict:
    n_sharded = sum(
        1
        for n in topo
        if isinstance(spec.get(n._id), tuple)
        and spec[n._id] is not _SCALAR
        and not _is_replicated(spec[n._id])
    )
    n_psum = sum(1 for ps in post.values() for k, _ in ps if k == "psum")
    n_pmax = sum(1 for ps in post.values() for k, _ in ps if k == "pmax")
    return {
        "params_sharded": sum(1 for s in params_norm if not _is_replicated(s)),
        "nodes": len(topo),
        "nodes_sharded": n_sharded,
        "n_psum": n_psum,
        "n_pmax": n_pmax,
    }


# ---------------------------------------------------------------------------
# The transform: global graph -> per-shard program
# ---------------------------------------------------------------------------


class ShardedGraph:
    """Everything ``compile_graph_spmd`` needs: the per-shard graph (with
    collectives inserted and shape constants localized, re-inferred at
    local shapes), PartitionSpecs for shard_map, and the plan."""

    __slots__ = ("graph", "in_partition", "out_partition", "local_abstracts", "plan", "stats")

    def __init__(self, graph, in_partition, out_partition, local_abstracts, plan, stats):
        self.graph = graph
        self.in_partition = in_partition
        self.out_partition = out_partition
        self.local_abstracts = local_abstracts
        self.plan = plan
        self.stats = stats


def shard_graph(
    graph: Graph, in_specs: Sequence[Any], mesh_axes: dict[str, int]
) -> ShardedGraph:
    """Build the per-shard program for ``graph`` under ``in_specs``.

    The transform is a straight-line rebuild: every apply re-emitted with
    its operands *provided at* the spec the rule demands (``all_gather``
    to replicate, ``shard_slice`` to re-partition — memoized per
    (node, spec)), collectives appended at cross-shard reduction points,
    and shape-carrying constants rewritten to local shapes.  The clone is
    re-inferred at the local parameter shapes so fusion/codegen block for
    per-shard arrays.
    """
    plan = propagate(graph, in_specs, mesh_axes)
    topo = [n for n in toposort(graph) if isinstance(n, Apply)]
    rules = _Rules(mesh_axes, _bspec_from_plan(plan, topo))

    g2 = Graph(graph.name + "_spmd")
    mapped: dict[int, Node] = {}
    provided: dict[tuple, Node] = {}
    counts = {"all_gather": 0, "shard_slice": 0, "psum": 0, "pmax": 0}

    local_abstracts = []
    for p, s in zip(graph.parameters, plan.in_specs):
        np_ = g2.add_parameter(p.debug_name)
        mapped[p._id] = np_
        ab = p.abstract
        if not isinstance(ab, AArray):
            raise SpmdError(f"spmd tier requires array parameters, got {ab!r}")
        local_abstracts.append(AArray(ab.dtype, local_shape(ab.shape, s, mesh_axes)))

    def mapc(node: Node) -> Node:
        got = mapped.get(node._id)
        if got is not None:
            return got
        if isinstance(node, Constant):
            new = Constant(node.value, node.debug_name)
            mapped[node._id] = new
            return new
        raise SpmdError(f"unmapped node {node!r}")

    def provide(node: Node, req: Spec | None) -> Node:
        cur = plan.spec_of(node)
        new = mapc(node)
        if req is None or cur is _SCALAR or isinstance(cur, _TSpec) or tuple(cur) == tuple(req):
            return new
        key = (node._id, tuple(req))
        hit = provided.get(key)
        if hit is not None:
            return hit
        ab = node.abstract
        shape = _shape_of(ab)
        if shape is None:
            raise SpmdError(f"cannot reshard non-array {node!r}")
        out = new
        # ALL gathers before ANY slice: shard_slice reads axis_index, and
        # slicing dim i by an axis that still shards dim j of the SAME
        # value would pick this device's i-block of a j-shard — gather and
        # slice do not commute across dims sharing a mesh axis
        for d in range(len(shape)):
            have, want = tuple(cur[d]), tuple(req[d])
            if have and have != want:
                sizes = tuple(mesh_axes[a] for a in have)
                out = g2.apply(P.all_gather_axes, out, have, d, sizes)
                counts["all_gather"] += 1
        for d in range(len(shape)):
            have, want = tuple(cur[d]), tuple(req[d])
            if want and have != want:
                sizes = tuple(mesh_axes[a] for a in want)
                out = g2.apply(P.shard_slice, out, want, d, sizes)
                counts["shard_slice"] += 1
        provided[key] = out
        return out

    for n in topo:
        prim = n.fn.value
        arg_specs = [plan.spec_of(a) for a in n.args]
        arg_abs = [a.abstract for a in n.args]
        res = rules.apply(n, prim, arg_specs, arg_abs, n.abstract)
        new_args: list[Node] = []
        for i, a in enumerate(n.args):
            if i in res.rewrites:
                new_args.append(Constant(tuple(res.rewrites[i])))
                continue
            req = res.reqs[i] if i < len(res.reqs) else None
            new_args.append(provide(a, req if isinstance(req, tuple) else None))
        if prim.name == "index_add" and res.post:
            # base + psum(scatter-of-local-contributions): scatter into
            # zeros, sum partials across shards, then add the base once
            zeros = g2.apply(P.zeros_like, new_args[0])
            scat = g2.apply(P.index_add, zeros, new_args[1], new_args[2])
            for kind, axes in res.post:
                scat = g2.apply(P.psum_axes, scat, tuple(axes))
                counts["psum"] += 1
            out = g2.apply(P.add, new_args[0], scat)
        else:
            out = g2.apply(n.fn.value, *new_args, debug_name=n.debug_name)
            for kind, axes in res.post:
                prim_c = P.psum_axes if kind == "psum" else P.pmax_axes
                out = g2.apply(prim_c, out, tuple(axes))
                counts[kind] += 1
        mapped[n._id] = out

    ret = graph.return_
    g2.set_return(mapc(ret) if not isinstance(ret, Apply) else mapped[ret._id])

    try:
        infer(g2, *local_abstracts)
    except Exception as e:  # pragma: no cover - transform bug guard
        raise SpmdError(f"local re-inference failed: {e}") from e

    stats = dict(plan.stats)
    stats.update(counts)
    return ShardedGraph(
        g2,
        tuple(spec_to_partition(s) for s in plan.in_specs),
        _out_partition(plan.out_spec),
        tuple(local_abstracts),
        plan,
        stats,
    )


def _bspec_from_plan(plan: SpmdPlan, topo: list[Apply]) -> dict[int, Spec]:
    """Recover the broadcast overrides the plan settled on, so the build
    pass reproduces exactly the propagation's decisions."""
    out: dict[int, Spec] = {}
    for n in topo:
        if n.fn.value.name in BROADCAST and n._id in plan.spec:
            s = plan.spec[n._id]
            if isinstance(s, tuple) and s is not _SCALAR:
                out[n._id] = s
    return out


def _out_partition(out_spec: Any):
    if isinstance(out_spec, _TSpec):
        return tuple(_out_partition(e) for e in out_spec.elements)
    return spec_to_partition(out_spec)


