"""Type / shape / value inference (paper §4.2).

"When a Myia function is called, we use the types of the user-provided
arguments as a starting point for type inference … No type annotations are
required, even when using higher order functions … The inferrer operates on
an untyped version of the IR.  It can infer types as well as values
(constant propagation) and shapes."

Implementation: abstract interpretation over the IR.

* Abstract domain: scalars (with optional known value — value inference
  doubles as constant propagation), arrays (dtype × shape), tuples,
  functions (sets of abstract closures), gradient environments.
* Calls are memoized per ``(graph, argument signature, free-variable
  signature)`` — the call-site specialization of the paper.  Recursion hits
  an in-flight signature and iterates to a least fixpoint from ⊥.
* Loops (tail-recursive headers) converge because scalar values are
  *widened* to unknown when a signature re-enters with different values.
* Array primitives default to ``jax.eval_shape`` over their jnp
  implementations — the registry needs no per-primitive shape rules.

The paper used coroutines for the same semantics; a fixpoint evaluator is
easier to verify (see DESIGN.md §7).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import primitives as P
from .ir import Apply, Constant, Graph, Node, Parameter, free_variables
from .primitives import Primitive
from .values import Closure, EnvInstance, SymbolicKey

__all__ = [
    "AScalar",
    "AArray",
    "ATuple",
    "AFunction",
    "AEnv",
    "BOTTOM",
    "ANY",
    "InferenceError",
    "abstract_of_value",
    "infer",
    "Inferencer",
]


class InferenceError(Exception):
    pass


class _Any:
    def __repr__(self) -> str:
        return "ANY"


ANY = _Any()


class AbstractValue:
    pass


class _Bottom(AbstractValue):
    def __repr__(self) -> str:
        return "⊥"


BOTTOM = _Bottom()


class AScalar(AbstractValue):
    """Python-level scalar: int/float/bool/str/none/dtype/key/opaque."""

    __slots__ = ("kind", "value")

    def __init__(self, kind: str, value: Any = ANY) -> None:
        self.kind = kind
        self.value = value

    def known(self) -> bool:
        return self.value is not ANY

    def __eq__(self, o: object) -> bool:
        return isinstance(o, AScalar) and o.kind == self.kind and _veq(o.value, self.value)

    def __hash__(self) -> int:
        try:
            return hash((self.kind, self.value))
        except TypeError:
            return hash(self.kind)

    def __repr__(self) -> str:
        v = "" if self.value is ANY else f"={self.value!r}"
        return f"{self.kind}{v}"


def _veq(a: Any, b: Any) -> bool:
    if a is ANY or b is ANY:
        return a is b
    try:
        return bool(a == b)
    except Exception:
        return a is b


class AArray(AbstractValue):
    __slots__ = ("dtype", "shape")

    def __init__(self, dtype: Any, shape: tuple[int, ...]) -> None:
        self.dtype = np.dtype(dtype)
        self.shape = tuple(int(s) for s in shape)

    def __eq__(self, o: object) -> bool:
        return isinstance(o, AArray) and o.dtype == self.dtype and o.shape == self.shape

    def __hash__(self) -> int:
        return hash((self.dtype, self.shape))

    def __repr__(self) -> str:
        return f"{self.dtype.name}{list(self.shape)}"


class ATuple(AbstractValue):
    __slots__ = ("elements",)

    def __init__(self, elements: tuple[AbstractValue, ...]) -> None:
        self.elements = tuple(elements)

    def __eq__(self, o: object) -> bool:
        return isinstance(o, ATuple) and o.elements == self.elements

    def __hash__(self) -> int:
        return hash(self.elements)

    def __repr__(self) -> str:
        return f"({', '.join(map(repr, self.elements))})"


class AClosureSpec:
    """A graph + the frame that resolves its free variables (abstractly)."""

    __slots__ = ("graph", "frame")

    def __init__(self, graph: Graph, frame: "_AFrame | None") -> None:
        self.graph = graph
        self.frame = frame

    def __repr__(self) -> str:
        return f"<aclosure {self.graph.name}>"


class AFunction(AbstractValue):
    __slots__ = ("options",)

    def __init__(self, options: tuple) -> None:  # Primitive | AClosureSpec
        self.options = tuple(options)

    def __eq__(self, o: object) -> bool:
        return isinstance(o, AFunction) and _fn_ids(o.options) == _fn_ids(self.options)

    def __hash__(self) -> int:
        return hash(_fn_ids(self.options))

    def __repr__(self) -> str:
        return f"fn{{{', '.join(map(repr, self.options))}}}"


def _fn_ids(opts: tuple) -> frozenset:
    out = set()
    for o in opts:
        if isinstance(o, AClosureSpec):
            out.add(("g", id(o.graph)))
        else:
            out.add(("p", id(o)))
    return frozenset(out)


class AEnv(AbstractValue):
    def __eq__(self, o: object) -> bool:
        return isinstance(o, AEnv)

    def __hash__(self) -> int:
        return hash("AEnv")

    def __repr__(self) -> str:
        return "env"


_AENV = AEnv()


def abstract_of_value(v: Any) -> AbstractValue:
    if isinstance(v, bool):
        return AScalar("bool", v)
    if isinstance(v, int):
        return AScalar("int", v)
    if isinstance(v, float):
        return AScalar("float", v)
    if isinstance(v, str):
        return AScalar("str", v)
    if v is None:
        return AScalar("none", None)
    if isinstance(v, np.dtype):
        return AScalar("dtype", v)
    if isinstance(v, type):
        return AScalar("dtype", np.dtype(v)) if _is_dtype_like(v) else AScalar("opaque", ANY)
    if isinstance(v, SymbolicKey):
        return AScalar("key", v)
    if isinstance(v, EnvInstance):
        return _AENV
    if isinstance(v, tuple):
        return ATuple(tuple(abstract_of_value(x) for x in v))
    if isinstance(v, jax.ShapeDtypeStruct):
        return AArray(v.dtype, v.shape)
    if isinstance(v, (jnp.ndarray, np.ndarray, np.generic)):
        return AArray(v.dtype, np.shape(v))
    if isinstance(v, jax.core.Tracer):
        return AArray(v.dtype, v.shape)
    if isinstance(v, Graph):
        return AFunction((AClosureSpec(v, None),))
    if isinstance(v, Primitive):
        return AFunction((v,))
    if isinstance(v, Closure):
        return AFunction((AClosureSpec(v.graph, None),))
    raise InferenceError(f"no abstract value for {type(v)}")


def _is_dtype_like(v: type) -> bool:
    try:
        np.dtype(v)
        return True
    except TypeError:
        return False


# ---------------------------------------------------------------------------
# Join (least upper bound)
# ---------------------------------------------------------------------------


def join(a: AbstractValue, b: AbstractValue) -> AbstractValue:
    if a is BOTTOM:
        return b
    if b is BOTTOM:
        return a
    if a == b:
        return a
    if isinstance(a, AScalar) and isinstance(b, AScalar) and a.kind == b.kind:
        return AScalar(a.kind)
    if isinstance(a, AScalar) and isinstance(b, AScalar):
        # int/float widening (python semantics would promote at runtime)
        if {a.kind, b.kind} <= {"int", "float", "bool"}:
            return AScalar("float" if "float" in (a.kind, b.kind) else "int")
    if isinstance(a, ATuple) and isinstance(b, ATuple) and len(a.elements) == len(b.elements):
        return ATuple(tuple(join(x, y) for x, y in zip(a.elements, b.elements)))
    if isinstance(a, AFunction) and isinstance(b, AFunction):
        seen = dict()
        for o in (*a.options, *b.options):
            seen[_fn_ids((o,))] = o
        return AFunction(tuple(seen.values()))
    if isinstance(a, AEnv) and isinstance(b, AEnv):
        return _AENV
    if (
        isinstance(a, AArray) and isinstance(b, AArray)
        and a.dtype == b.dtype and a.shape == b.shape
    ):
        return a
    # scalar/0-d array mixing (jnp promotes python scalars to weak arrays)
    if isinstance(a, AArray) and isinstance(b, AScalar) and b.kind in ("int", "float", "bool"):
        return a
    if isinstance(b, AArray) and isinstance(a, AScalar) and a.kind in ("int", "float", "bool"):
        return b
    raise InferenceError(f"cannot join {a!r} and {b!r}")


# ---------------------------------------------------------------------------
# The inferencer
# ---------------------------------------------------------------------------


class _AFrame:
    __slots__ = ("graph", "values")

    def __init__(self, graph: Graph) -> None:
        self.graph = graph
        self.values: dict[int, AbstractValue] = {}


def _sig(abs_list: tuple) -> tuple:
    return tuple(abs_list)


class Inferencer:
    def __init__(self, max_fixpoint_iters: int = 25, max_depth: int = 300) -> None:
        self.memo: dict[tuple, AbstractValue] = {}
        self.inflight: dict[tuple, AbstractValue] = {}
        self.inflight_graphs: dict[int, int] = {}  # graph id -> inflight count
        self.max_fixpoint_iters = max_fixpoint_iters
        self.max_depth = max_depth
        self.depth = 0
        self._fv_cache: dict[int, list[Node]] = {}
        #: per-active-call sets of inflight keys whose *approximations* were
        #: read — results depending on one may not be memoized (unsound until
        #: the enclosing fixpoint settles).
        self._dep_stack: list[set] = []

    def _read_inflight(self, key: tuple) -> AbstractValue:
        for deps in self._dep_stack:
            deps.add(key)
        return self.inflight[key]

    # -- public ------------------------------------------------------------
    def infer_graph(self, g: Graph, args: tuple[AbstractValue, ...]) -> AbstractValue:
        return self._call_closure(AClosureSpec(g, None), tuple(args))

    # -- helpers -----------------------------------------------------------
    def _fvs(self, g: Graph) -> list[Node]:
        if g._id not in self._fv_cache:
            self._fv_cache[g._id] = free_variables(g)
        return self._fv_cache[g._id]

    def _call_closure(self, clos: AClosureSpec, args: tuple) -> AbstractValue:
        g = clos.graph
        if len(args) != len(g.parameters):
            raise InferenceError(
                f"{g.name} expects {len(g.parameters)} args, got {len(args)}"
            )
        fv_nodes = self._fvs(g)
        fv_abs = []
        for v in fv_nodes:
            if clos.frame is None:
                raise InferenceError(
                    f"closure {g.name} needs free variable {v!r} but has no frame"
                )
            fv_abs.append(self._eval(v, clos.frame))
        key = (id(g), _sig(args), _sig(tuple(fv_abs)))
        if key in self.memo:
            return self.memo[key]
        if key in self.inflight:
            return self._read_inflight(key)

        # Widening: re-entering an already-inflight graph with a *different*
        # signature (a loop header counting 0,1,2,… or recursion on a known
        # scalar) would specialize forever.  Drop known scalar values from
        # the recursive signature so it reaches a stable key.
        if self.inflight_graphs.get(id(g), 0) > 0:
            wargs = tuple(_widen(a) for a in args)
            if wargs != args:
                args = wargs
                key = (id(g), _sig(args), _sig(tuple(fv_abs)))
                if key in self.memo:
                    return self.memo[key]
                if key in self.inflight:
                    return self._read_inflight(key)

        self.inflight[key] = BOTTOM
        self.inflight_graphs[id(g)] = self.inflight_graphs.get(id(g), 0) + 1
        self.depth += 1
        if self.depth > self.max_depth:
            raise InferenceError("inference recursion too deep (widening failed?)")
        deps: set = set()
        self._dep_stack.append(deps)
        try:
            for _ in range(self.max_fixpoint_iters):
                frame = _AFrame(g)
                for p, a in zip(g.parameters, args):
                    frame.values[p._id] = a
                    p.abstract = _merge_annot(p.abstract, a)
                for v, a in zip(fv_nodes, fv_abs):
                    frame.values[v._id] = a
                res = self._eval(g.return_, frame)
                prev = self.inflight[key]
                merged = join(prev, res)
                if merged == prev:
                    # Stable — including stable-at-⊥, which means the result
                    # hinges on an *enclosing* inflight call; return ⊥ and
                    # let that outer fixpoint iterate.
                    break
                self.inflight[key] = merged
            else:
                raise InferenceError(f"fixpoint did not converge for {g.name}")
            result = self.inflight[key]
        finally:
            self._dep_stack.pop()
            self.depth -= 1
            self.inflight.pop(key, None)
            self.inflight_graphs[id(g)] -= 1
        # Memoize only if the result did not consult an approximation that is
        # *still* being refined by an enclosing fixpoint.
        deps.discard(key)
        if result is not BOTTOM and not any(d in self.inflight for d in deps):
            self.memo[key] = result
        return result

    def _eval(self, node: Node, frame: _AFrame) -> AbstractValue:
        if node._id in frame.values:
            return frame.values[node._id]
        if isinstance(node, Constant):
            v = node.value
            if isinstance(v, Graph):
                ab: AbstractValue = AFunction((AClosureSpec(v, frame),))
            elif isinstance(v, Primitive):
                ab = AFunction((v,))
            else:
                ab = abstract_of_value(v)
            node.abstract = _merge_annot(node.abstract, ab)
            return ab
        if isinstance(node, Parameter):
            raise InferenceError(f"unbound parameter {node!r} during inference")
        assert isinstance(node, Apply)
        fnab = self._eval(node.fn, frame)
        argabs = tuple(self._eval(a, frame) for a in node.args)
        ab = self._apply(fnab, argabs, frame)
        frame.values[node._id] = ab
        node.abstract = _merge_annot(node.abstract, ab)
        return ab

    def _apply(self, fnab: AbstractValue, args: tuple, frame: _AFrame) -> AbstractValue:
        if fnab is BOTTOM or any(a is BOTTOM for a in args):
            return BOTTOM
        if not isinstance(fnab, AFunction):
            raise InferenceError(f"calling a non-function: {fnab!r}")
        result: AbstractValue = BOTTOM
        for opt in fnab.options:
            if isinstance(opt, Primitive):
                r = self._apply_prim(opt, args, frame)
            else:
                r = self._call_closure(opt, args)
            result = join(result, r)
        return result

    # -- primitives ---------------------------------------------------------
    def _apply_prim(self, p: Primitive, args: tuple, frame: _AFrame) -> AbstractValue:
        rule = _STRUCTURAL_RULES.get(p.name)
        if rule is not None:
            return rule(self, args, frame)

        # full constant propagation when every argument value is known
        if all(_is_concrete(a) for a in args):
            try:
                return abstract_of_value(p.impl(*[_concrete(a) for a in args]))
            except InferenceError:
                raise
            except Exception as e:
                raise InferenceError(f"{p.name} failed during value inference: {e}")

        # The eval_shape result is a pure function of (prim, arg abstracts):
        # memoize process-wide — adjoint graphs apply the same prim at the
        # same signature hundreds of times, and each eval_shape is a full
        # jax trace (milliseconds, the bulk of specialization latency).
        try:
            cache_key = (id(p), args)
            hash(cache_key)
        except TypeError:
            cache_key = None
        if cache_key is not None:
            hit = _EVAL_SHAPE_MEMO.get(cache_key)
            if hit is not None:
                return hit

        # default: shape inference through jax.eval_shape on the jnp impl.
        # Known scalars/tuples are baked in as *statics* (axes, dtypes and
        # flags must not become tracers); only unknowns are traced.
        static: dict[int, Any] = {}
        spec: list[Any] = []
        for i, a in enumerate(args):
            if _is_concrete(a):
                static[i] = _concrete(a)
            else:
                spec.append(_materialize(a))

        def _call(*xs: Any) -> Any:
            it = iter(xs)
            merged = [static[i] if i in static else next(it) for i in range(len(args))]
            return p.impl(*merged)

        try:
            out = jax.eval_shape(_call, *spec)
        except InferenceError:
            raise
        except Exception as e:
            raise InferenceError(f"shape inference failed for {p.name}{args!r}: {e}")
        ab = _abstract_of_spec(out)
        # Python-scalar in ⇒ Python-scalar out: if no argument carried an
        # array, a 0-d result is a scalar of the promoted kind, not an array.
        if not any(_contains_array(a) for a in args):
            ab = _demote_scalars(ab)
        if cache_key is not None:
            if len(_EVAL_SHAPE_MEMO) > 8192:
                # Evict the oldest half (dict preserves insertion order)
                # instead of wiping: later specializations of the same
                # family re-ask the same (prim, signature) questions, and a
                # full clear turns every one back into a jax trace.
                for k in list(_EVAL_SHAPE_MEMO)[:4096]:
                    del _EVAL_SHAPE_MEMO[k]
            _EVAL_SHAPE_MEMO[cache_key] = ab
        return ab


#: (id(prim), arg abstracts) -> result abstract; see _apply_prim
_EVAL_SHAPE_MEMO: dict[tuple, AbstractValue] = {}

_KIND_OF_DTYPE = {"f": "float", "i": "int", "u": "int", "b": "bool"}


def _contains_array(a: AbstractValue) -> bool:
    if isinstance(a, AArray):
        return True
    if isinstance(a, ATuple):
        return any(_contains_array(e) for e in a.elements)
    return False


def _demote_scalars(ab: AbstractValue) -> AbstractValue:
    if isinstance(ab, ATuple):
        return ATuple(tuple(_demote_scalars(e) for e in ab.elements))
    if isinstance(ab, AArray) and ab.shape == ():
        kind = _KIND_OF_DTYPE.get(ab.dtype.kind)
        if kind is not None:
            return AScalar(kind)
    return ab


def _widen(a: AbstractValue) -> AbstractValue:
    """Forget known int/float/bool values (keep structure-relevant kinds:
    str/none/dtype/key stay concrete — they select code paths)."""
    if isinstance(a, AScalar) and a.known() and a.kind in ("int", "float", "bool"):
        return AScalar(a.kind)
    if isinstance(a, ATuple):
        return ATuple(tuple(_widen(e) for e in a.elements))
    return a


def _merge_annot(old: AbstractValue | None, new: AbstractValue) -> AbstractValue | None:
    if old is None or old is BOTTOM:
        return new
    try:
        return join(old, new)
    except InferenceError:
        return None  # polymorphic reuse: drop annotation (sound)


def _is_concrete(a: AbstractValue) -> bool:
    if isinstance(a, AScalar):
        return a.known()
    if isinstance(a, ATuple):
        return all(_is_concrete(e) for e in a.elements)
    return False


def _concrete(a: AbstractValue) -> Any:
    if isinstance(a, AScalar):
        return a.value
    if isinstance(a, ATuple):
        return tuple(_concrete(e) for e in a.elements)
    raise InferenceError("not concrete")


def _materialize(a: AbstractValue) -> Any:
    """Stand-in runtime value for jax.eval_shape."""
    if isinstance(a, AArray):
        return jax.ShapeDtypeStruct(a.shape, a.dtype)
    if isinstance(a, AScalar):
        if a.known():
            return a.value
        if a.kind == "float":
            return 0.0  # value cannot affect shapes/dtypes
        if a.kind == "bool":
            return False
        if a.kind == "int":
            # ints may be shape-relevant; unknown int in an array prim is
            # almost always a runtime index (take etc.) where 0 is safe
            return 0
        if a.kind == "none":
            return None
        if a.kind == "dtype":
            raise InferenceError("unknown dtype at inference time")
        raise InferenceError(f"cannot materialize scalar kind {a.kind}")
    if isinstance(a, ATuple):
        return tuple(_materialize(e) for e in a.elements)
    raise InferenceError(f"cannot materialize {a!r}")


def _abstract_of_spec(out: Any) -> AbstractValue:
    if isinstance(out, tuple):
        return ATuple(tuple(_abstract_of_spec(o) for o in out))
    if isinstance(out, jax.ShapeDtypeStruct):
        return AArray(out.dtype, out.shape)
    return abstract_of_value(out)


# ---------------------------------------------------------------------------
# Structural rules
# ---------------------------------------------------------------------------


def _r_make_tuple(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    return ATuple(args)


def _r_tuple_getitem(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    t, i = args
    if isinstance(t, ATuple):
        if isinstance(i, AScalar) and i.known():
            return t.elements[i.value]
        out: AbstractValue = BOTTOM
        for e in t.elements:
            out = join(out, e)
        return out
    raise InferenceError(f"tuple_getitem on {t!r}")


def _r_tuple_setitem(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    t, i, v = args
    if isinstance(t, ATuple) and isinstance(i, AScalar) and i.known():
        elts = list(t.elements)
        elts[i.value] = v
        return ATuple(tuple(elts))
    raise InferenceError("tuple_setitem needs a tuple and a known index")


def _r_tuple_len(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    (t,) = args
    if isinstance(t, ATuple):
        return AScalar("int", len(t.elements))
    raise InferenceError(f"len of {t!r}")


def _r_shape(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    (x,) = args
    if isinstance(x, AArray):
        return ATuple(tuple(AScalar("int", int(d)) for d in x.shape))
    if isinstance(x, AScalar) and x.kind in ("int", "float", "bool"):
        return ATuple(())
    raise InferenceError(f"shape of {x!r}")


def _r_dtype_of(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    (x,) = args
    if isinstance(x, AArray):
        return AScalar("dtype", x.dtype)
    if isinstance(x, AScalar) and x.known():
        return AScalar("dtype", P.dtype_of.impl(x.value))
    if isinstance(x, AScalar) and x.kind == "int":
        return AScalar("dtype", np.dtype("int32"))
    if isinstance(x, AScalar) and x.kind == "float":
        return AScalar("dtype", np.dtype("float32"))
    raise InferenceError(f"dtype_of {x!r}")


def _contains_fn_or_env(a: AbstractValue) -> bool:
    if isinstance(a, (AFunction, AEnv)):
        return True
    if isinstance(a, ATuple):
        return any(_contains_fn_or_env(e) for e in a.elements)
    return False


def _r_switch(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    c, t, f = args
    if isinstance(c, AScalar) and c.known():
        return t if c.value else f
    if isinstance(c, AScalar):
        return join(t, f)
    if isinstance(c, AArray):
        if _contains_fn_or_env(t) or _contains_fn_or_env(f):
            # selecting between closures on a traced (0-d array) condition
            # — e.g. a loop header whose bound is an array: the branches
            # cannot be materialized for jnp.where, but the result is just
            # their join (both control paths stay live for inference)
            return join(t, f)
        out = jax.eval_shape(  # elementwise select
            lambda cc, tt, ff: jnp.where(cc, tt, ff),
            _materialize(c),
            _materialize(t),
            _materialize(f),
        )
        return _abstract_of_spec(out)
    raise InferenceError(f"switch on {c!r}")


def _r_zeros_like(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    (x,) = args
    if isinstance(x, AFunction):
        return _AENV
    if isinstance(x, AEnv):
        return _AENV
    if isinstance(x, ATuple):
        return ATuple(tuple(_r_zeros_like(inf, (e,), frame) for e in x.elements))
    if isinstance(x, AScalar):
        if x.kind in ("int", "float", "bool"):
            return AScalar(x.kind, {"int": 0, "float": 0.0, "bool": False}[x.kind])
        return AScalar("none", None)
    if isinstance(x, AArray):
        return x
    raise InferenceError(f"zeros_like {x!r}")


def _r_gadd(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    a, b = args
    if isinstance(a, AEnv) or isinstance(b, AEnv):
        return _AENV
    if isinstance(a, AScalar) and a.kind == "none":
        return b
    if isinstance(b, AScalar) and b.kind == "none":
        return a
    if isinstance(a, ATuple) and isinstance(b, ATuple):
        return ATuple(tuple(_r_gadd(inf, (x, y), frame) for x, y in zip(a.elements, b.elements)))
    out = jax.eval_shape(lambda x, y: x + y, _materialize(a), _materialize(b))
    return _abstract_of_spec(out)


def _r_env_setitem(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    return _AENV


def _r_env_getitem(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    return args[2]  # the default has the right abstract (zeros_like of target)


def _r_stop_gradient(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    return args[0]


def _widen_value(a: AbstractValue) -> AbstractValue:
    """Collectives preserve shape/dtype but NOT the value (psum of a known
    scalar is value × devices) — drop known scalar values so constant
    propagation can never fold across a resharding point."""
    if isinstance(a, AArray):
        return a
    if isinstance(a, AScalar):
        return AScalar(a.kind)
    raise InferenceError(f"collective on non-numeric {a!r}")


def _r_psum_axes(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    return _widen_value(args[0])


def _r_all_gather_axes(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    x, _axes, dim, sizes = args
    if not (isinstance(x, AArray) and _is_concrete(dim) and _is_concrete(sizes)):
        raise InferenceError(f"all_gather_axes needs an array and static config: {args!r}")
    d = _concrete(dim)
    factor = int(np.prod(_concrete(sizes)))
    shp = list(x.shape)
    shp[d] = shp[d] * factor
    return AArray(x.dtype, tuple(shp))


def _r_shard_slice(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    x, _axes, dim, sizes = args
    if not (isinstance(x, AArray) and _is_concrete(dim) and _is_concrete(sizes)):
        raise InferenceError(f"shard_slice needs an array and static config: {args!r}")
    d = _concrete(dim)
    factor = int(np.prod(_concrete(sizes)))
    shp = list(x.shape)
    if shp[d] % factor != 0:
        raise InferenceError(f"shard_slice: dim {d} of {x!r} not divisible by {factor}")
    shp[d] = shp[d] // factor
    return AArray(x.dtype, tuple(shp))


def _loop_exit_closure(exit_ab: AbstractValue) -> AClosureSpec:
    if (
        isinstance(exit_ab, AFunction)
        and len(exit_ab.options) == 1
        and isinstance(exit_ab.options[0], AClosureSpec)
    ):
        return exit_ab.options[0]
    raise InferenceError(f"loop exit must be a single closed graph, got {exit_ab!r}")


def _annotate_loop_bodies(inf: Inferencer, subs: tuple, rest: tuple) -> None:
    """Infer through a loop's cond/step closures for the annotation side
    effect: their interior nodes (including *nested* loop applies emitted
    by the while-adjoint's replay recomputation) need abstracts so a later
    J pass can differentiate them (reverse-over-reverse).  Best-effort —
    the loop's own result type comes from the exit graph alone."""
    for s in subs:
        try:
            inf._call_closure(_loop_exit_closure(s), rest)
        except InferenceError:
            pass


def _r_while_loop(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    # (cond, step, exit, n_carry, *carry_and_extras).  The carry is
    # type-stable but its VALUES iterate — widen before applying the exit
    # graph so constant propagation can never fold across the back-edge.
    exit_spec = _loop_exit_closure(args[2])
    rest = tuple(_widen(a) for a in args[4:])
    _annotate_loop_bodies(inf, args[:2], rest)
    return inf._call_closure(exit_spec, rest)


def _r_scan_loop(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    # (step, exit, length, n_carry, *carry_and_extras)
    exit_spec = _loop_exit_closure(args[1])
    rest = tuple(_widen(a) for a in args[4:])
    _annotate_loop_bodies(inf, args[:1], rest)
    return inf._call_closure(exit_spec, rest)


def _r_cast(inf: Inferencer, args: tuple, frame) -> AbstractValue:
    x, dt = args
    if isinstance(dt, AScalar) and dt.known():
        dtype = np.dtype(dt.value)
        if isinstance(x, AArray):
            return AArray(dtype, x.shape)
        if isinstance(x, AScalar):
            return AArray(dtype, ())
    raise InferenceError("cast needs a known dtype")


_STRUCTURAL_RULES = {
    "make_tuple": _r_make_tuple,
    "tuple_getitem": _r_tuple_getitem,
    "tuple_setitem": _r_tuple_setitem,
    "tuple_len": _r_tuple_len,
    "shape": _r_shape,
    "dtype_of": _r_dtype_of,
    "switch": _r_switch,
    "zeros_like": _r_zeros_like,
    "gadd": _r_gadd,
    "env_setitem": _r_env_setitem,
    "env_getitem": _r_env_getitem,
    "stop_gradient": _r_stop_gradient,
    "cast": _r_cast,
    # SPMD collectives: axis names are unbound outside shard_map, so the
    # eval_shape default would fail — shapes are derived structurally
    "psum_axes": _r_psum_axes,
    "pmax_axes": _r_psum_axes,
    "all_gather_axes": _r_all_gather_axes,
    "shard_slice": _r_shard_slice,
    # structured loops (repro.core.closure): carry-widened exit application
    "while_loop": _r_while_loop,
    "scan_loop": _r_scan_loop,
}


def infer(graph: Graph, *args: Any) -> AbstractValue:
    """Infer output abstract of ``graph`` for ``args`` (abstract values, or
    runtime values / ShapeDtypeStructs which are converted).  Annotates the
    graph family's nodes with inferred abstracts as a side effect."""
    from repro.obs import trace as obs_trace

    abs_args = tuple(
        a if isinstance(a, AbstractValue) else abstract_of_value(a) for a in args
    )
    with obs_trace.span("infer", graph=graph.name):
        return Inferencer().infer_graph(graph, abs_args)
