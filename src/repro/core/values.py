"""Runtime values for the IR: closures, gradient environments, symbolic keys.

The AD transform (paper §3.2) makes backpropagators return the partial
derivatives w.r.t. a function's *free variables* in addition to its inputs.
Because a function value may be any of several closures (e.g. the two
branches of a ``switch``) with different free-variable sets, these
sensitivities are carried in an :class:`EnvInstance` — a persistent map from
:class:`SymbolicKey` (a stand-in for an IR node) to gradient values — rather
than the paper's "ordered set".  This matches Myia's actual implementation.
"""

from __future__ import annotations

from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SymbolicKey",
    "EnvInstance",
    "newenv",
    "Closure",
    "gadd_values",
    "zeros_like_value",
    "is_array_like",
]


class SymbolicKey:
    """Identifies a free variable inside gradient environments.

    Holds a reference to the IR node so that ``zeros_like`` semantics are
    recoverable; compares by identity of the node.
    """

    __slots__ = ("node",)

    def __init__(self, node: Any) -> None:
        self.node = node

    def __eq__(self, other: object) -> bool:
        return isinstance(other, SymbolicKey) and other.node is self.node

    def __hash__(self) -> int:
        return id(self.node)

    def __repr__(self) -> str:  # pragma: no cover
        return f"<Key {getattr(self.node, 'debug_name', '') or id(self.node)}>"


class EnvInstance:
    """Persistent (functional) map from SymbolicKey to gradient values."""

    __slots__ = ("_d",)

    def __init__(self, d: dict[SymbolicKey, Any] | None = None) -> None:
        self._d = d or {}

    def set(self, key: SymbolicKey, value: Any) -> "EnvInstance":
        d = dict(self._d)
        d[key] = value
        return EnvInstance(d)

    def get(self, key: SymbolicKey, default: Any) -> Any:
        return self._d.get(key, default)

    def add(self, other: "EnvInstance") -> "EnvInstance":
        d = dict(self._d)
        for k, v in other._d.items():
            d[k] = gadd_values(d[k], v) if k in d else v
        return EnvInstance(d)

    def keys(self) -> Iterable[SymbolicKey]:
        return self._d.keys()

    def __len__(self) -> int:
        return len(self._d)

    def __repr__(self) -> str:  # pragma: no cover
        return f"<Env {len(self._d)} keys>"


newenv = EnvInstance()


def _env_flatten(env: EnvInstance):
    keys = sorted(env._d.keys(), key=lambda k: id(k.node))
    return [env._d[k] for k in keys], tuple(keys)


def _env_unflatten(keys, values):
    return EnvInstance(dict(zip(keys, values)))


jax.tree_util.register_pytree_node(EnvInstance, _env_flatten, _env_unflatten)


class Closure:
    """A graph paired with the frame chain that resolves its free variables
    (VM-level runtime representation of a first-class function)."""

    __slots__ = ("graph", "frame")

    def __init__(self, graph: Any, frame: Any) -> None:
        self.graph = graph
        self.frame = frame

    def __repr__(self) -> str:  # pragma: no cover
        return f"<Closure {self.graph.name}>"


def is_array_like(x: Any) -> bool:
    return isinstance(x, (jnp.ndarray, np.ndarray, jax.core.Tracer))


def gadd_values(x: Any, y: Any) -> Any:
    """Generic gradient addition: numbers/arrays add, tuples add
    elementwise, environments merge (the runtime of the ``gadd`` prim)."""
    if isinstance(x, EnvInstance):
        if isinstance(y, EnvInstance):
            return x.add(y)
        raise TypeError(f"gadd(Env, {type(y)})")
    if isinstance(y, EnvInstance):
        raise TypeError(f"gadd({type(x)}, Env)")
    if isinstance(x, tuple) and isinstance(y, tuple):
        if len(x) != len(y):
            raise TypeError("gadd of tuples with different lengths")
        return tuple(gadd_values(a, b) for a, b in zip(x, y))
    if x is None:
        return y
    if y is None:
        return x
    return x + y


def zeros_like_value(x: Any) -> Any:
    """Generic zeros: the additive identity matching ``x``'s structure.
    Function-typed values get an *empty environment* (their sensitivity is
    the map of free-variable gradients)."""
    from .ir import Graph  # local import to avoid cycle
    from .primitives import Primitive

    if isinstance(x, tuple):
        return tuple(zeros_like_value(v) for v in x)
    if isinstance(x, (EnvInstance, Closure, Graph, Primitive)):
        return newenv
    if isinstance(x, bool):
        return False
    if isinstance(x, int):
        return 0
    if isinstance(x, float):
        return 0.0
    if is_array_like(x) or isinstance(x, np.generic):
        return jnp.zeros_like(x)
    if x is None or isinstance(x, (np.dtype, str, type, SymbolicKey)):
        # opaque, non-differentiable tokens: None is the additive unit
        return None
    if callable(x):
        return newenv
    raise TypeError(f"zeros_like for {type(x)}")
