"""Graph-based direct intermediate representation (the paper's §3).

A *function* is a :class:`Graph` with a list of parameter nodes and a single
return node (multiple return values via tuples).  A :class:`Node` is either

* an **apply** node: an ordered list of incoming edges; the first edge points
  to the function being applied, the rest to its arguments,
* a **parameter** node: belongs to exactly one graph,
* a **constant** node: no incoming edges, carries a ``value`` (a Python
  scalar, array, :class:`Primitive <repro.core.primitives.Primitive>`, or a
  :class:`Graph` — graphs are first-class values).

Links are bidirectional (``node.users``) so graphs can be traversed either
way.  Free variables are represented *directly*: an apply node belonging to
graph ``G`` may point at a node owned by a different graph ``P``, which makes
``G`` implicitly nested inside ``P`` (the Thorin-style closure representation
of the paper §3 "Closure representation").
"""

from __future__ import annotations

import itertools
from typing import Any, Iterable, Iterator

__all__ = [
    "Node",
    "Apply",
    "Parameter",
    "Constant",
    "Graph",
    "GraphCloner",
    "FamilyIndex",
    "is_constant",
    "is_constant_graph",
    "is_constant_prim",
    "is_apply",
    "is_parameter",
    "toposort",
    "dfs_nodes",
    "succ_incoming",
    "free_variables",
    "graphs_used",
    "graph_and_descendants",
]

_counter = itertools.count()


class Node:
    """Base class for IR nodes."""

    __slots__ = ("graph", "abstract", "debug_name", "_id", "users")

    def __init__(self, graph: "Graph | None", debug_name: str = "") -> None:
        self.graph = graph
        #: inferred abstract value (types/shapes/values), set by ``infer``
        self.abstract = None
        self.debug_name = debug_name
        self._id = next(_counter)
        #: set of ``(user_node, input_index)`` pairs, maintained by Graph ops
        self.users: set[tuple["Node", int]] = set()

    # -- classification helpers ------------------------------------------
    @property
    def is_apply(self) -> bool:
        return isinstance(self, Apply)

    @property
    def is_parameter(self) -> bool:
        return isinstance(self, Parameter)

    @property
    def is_constant(self) -> bool:
        return isinstance(self, Constant)

    @property
    def inputs(self) -> list["Node"]:
        return []

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        name = self.debug_name or f"%{self._id}"
        return f"<{type(self).__name__} {name}>"


class Apply(Node):
    """Function application: ``inputs[0]`` is the callee, the rest args."""

    __slots__ = ("_inputs",)

    def __init__(self, inputs: list[Node], graph: "Graph", debug_name: str = "") -> None:
        super().__init__(graph, debug_name)
        self._inputs: list[Node] = []
        for i, inp in enumerate(inputs):
            self._inputs.append(inp)
            inp.users.add((self, i))

    @property
    def inputs(self) -> list[Node]:
        return self._inputs

    @property
    def fn(self) -> Node:
        return self._inputs[0]

    @property
    def args(self) -> list[Node]:
        return self._inputs[1:]

    def set_input(self, index: int, new: Node) -> None:
        old = self._inputs[index]
        old.users.discard((self, index))
        self._inputs[index] = new
        new.users.add((self, index))


class Parameter(Node):
    __slots__ = ()

    def __init__(self, graph: "Graph", debug_name: str = "") -> None:
        super().__init__(graph, debug_name)


class Constant(Node):
    """A constant value.  ``value`` may be a Graph (first-class functions)."""

    __slots__ = ("value",)

    def __init__(self, value: Any, debug_name: str = "") -> None:
        super().__init__(None, debug_name)
        self.value = value

    def __repr__(self) -> str:  # pragma: no cover
        from .primitives import Primitive

        if isinstance(self.value, Graph):
            return f"<Const graph:{self.value.name}>"
        if isinstance(self.value, Primitive):
            return f"<Const prim:{self.value.name}>"
        return f"<Const {self.value!r}>"


def is_constant(node: Node) -> bool:
    return isinstance(node, Constant)


def is_constant_graph(node: Node) -> bool:
    return isinstance(node, Constant) and isinstance(node.value, Graph)


def is_constant_prim(node: Node, prim: Any = None) -> bool:
    from .primitives import Primitive

    if not (isinstance(node, Constant) and isinstance(node.value, Primitive)):
        return False
    return prim is None or node.value is prim


def is_apply(node: Node, prim: Any = None) -> bool:
    if not isinstance(node, Apply):
        return False
    return prim is None or is_constant_prim(node.fn, prim)


def is_parameter(node: Node) -> bool:
    return isinstance(node, Parameter)


class Graph:
    """A function: parameter nodes + a return node.

    Graphs are first-class: wrap one in a :class:`Constant` to pass it as a
    value.  ``flags`` carries parse/transform metadata (e.g. source info).
    """

    __slots__ = (
        "name",
        "parameters",
        "return_",
        "flags",
        "parent_hint",
        "_id",
        "primal",
        "transforms",
    )

    def __init__(self, name: str = "") -> None:
        self._id = next(_counter)
        self.name = name or f"g{self._id}"
        self.parameters: list[Parameter] = []
        self.return_: Node | None = None
        self.flags: dict[str, Any] = {}
        #: graph this one was created inside of (scoping hint from the parser)
        self.parent_hint: "Graph | None" = None
        #: if this graph was produced by a transform, its source graph
        self.primal: "Graph | None" = None
        #: cache of graph transforms, e.g. {"grad": <Graph>}
        self.transforms: dict[str, Any] = {}

    # -- construction helpers --------------------------------------------
    def add_parameter(self, debug_name: str = "") -> Parameter:
        p = Parameter(self, debug_name)
        self.parameters.append(p)
        return p

    def apply(self, *inputs: Any, debug_name: str = "") -> Apply:
        """Create an apply node in this graph.  Non-Node inputs are wrapped
        in Constants (Graph/Primitive/array/scalar values alike)."""
        nodes = [i if isinstance(i, Node) else Constant(i) for i in inputs]
        return Apply(nodes, self, debug_name)

    def constant(self, value: Any) -> Constant:
        return Constant(value)

    def set_return(self, node: Node) -> None:
        self.return_ = node

    # -- queries -----------------------------------------------------------
    def nodes(self) -> list[Node]:
        """All nodes reachable from the return node (incl. nested-graph uses)."""
        return list(dfs_nodes(self.return_))

    def local_nodes(self) -> list[Node]:
        return [n for n in self.nodes() if n.graph is self]

    def free_variables(self) -> list[Node]:
        return free_variables(self)

    def child_graphs(self) -> set["Graph"]:
        """Graphs referenced as constants anywhere below this graph."""
        return graphs_used(self)

    def __repr__(self) -> str:  # pragma: no cover
        return f"<Graph {self.name}>"


# ---------------------------------------------------------------------------
# Traversals
# ---------------------------------------------------------------------------


def succ_incoming(node: Node) -> Iterable[Node]:
    """Successors following incoming edges, *entering* nested graphs."""
    if isinstance(node, Apply):
        yield from node.inputs
    elif isinstance(node, Constant) and isinstance(node.value, Graph):
        g = node.value
        if g.return_ is not None:
            yield g.return_
        # parameters are roots; reachable via uses inside the body anyway


def dfs_nodes(root: Node | None) -> Iterator[Node]:
    """Depth-first over nodes reachable from ``root``, entering graph
    constants (so the whole *graph family* below a node is visited)."""
    if root is None:
        return
    seen: set[int] = set()
    stack = [root]
    while stack:
        node = stack.pop()
        if id(node) in seen:
            continue
        seen.add(id(node))
        yield node
        stack.extend(succ_incoming(node))


def toposort(graph: Graph) -> list[Node]:
    """Topological order of the nodes *owned by* ``graph`` (dependencies
    first).  Nested graphs and free variables count as leaves."""
    order: list[Node] = []
    seen: set[int] = set()
    # iterative post-order
    stack: list[tuple[Node, bool]] = [(graph.return_, False)]
    while stack:
        node, processed = stack.pop()
        if processed:
            if node.graph is graph:
                order.append(node)
            continue
        if id(node) in seen:
            continue
        seen.add(id(node))
        stack.append((node, True))
        if isinstance(node, Apply) and node.graph is graph:
            for inp in node.inputs:
                if id(inp) not in seen:
                    stack.append((inp, False))
    return order


def graphs_used(graph: Graph) -> set[Graph]:
    """All graphs appearing as constants in ``graph``'s reachable family."""
    out: set[Graph] = set()
    for node in dfs_nodes(graph.return_):
        if is_constant_graph(node):
            out.add(node.value)
    return out


def graph_and_descendants(graph: Graph) -> set[Graph]:
    # dfs_nodes already enters graph constants transitively, so ONE dfs
    # covers the whole family (the per-graph re-walk was O(F·N)).
    out: set[Graph] = {graph}
    for node in dfs_nodes(graph.return_):
        if is_constant_graph(node):
            out.add(node.value)
    return out


def direct_free_variables(graph: Graph) -> list[Node]:
    """Nodes referenced by ``graph``'s own applies — or as its return node —
    but owned by some other graph (one level; no nested propagation)."""
    fvs: dict[int, Node] = {}
    ret = graph.return_
    if ret is not None and ret.graph is not None and ret.graph is not graph:
        fvs[ret._id] = ret
    for node in graph.nodes():
        if isinstance(node, Apply) and node.graph is graph:
            for inp in node.inputs:
                if inp.graph is not None and inp.graph is not graph:
                    fvs[inp._id] = inp
    return [fvs[k] for k in sorted(fvs)]


def free_variables(graph: Graph) -> list[Node]:
    """Transitive free variables of ``graph``: every node owned by an
    *enclosing* scope that ``graph`` — or any graph it references, directly
    or transitively — may capture.  Computed as a least fixpoint over the
    graph-reference relation (recursion through an enclosing graph must not
    make that graph's locals look bound — see tests/core/test_ir.py)."""
    # collect the reference closure
    graphs = graph_and_descendants(graph)
    direct: dict[Graph, set[Node]] = {}
    refs: dict[Graph, set[Graph]] = {}
    for g in graphs:
        direct[g] = {n for n in direct_free_variables(g)}
        refs[g] = set()
        for node in dfs_nodes(g.return_):
            if isinstance(node, Apply) and node.graph is g:
                for inp in node.inputs:
                    if is_constant_graph(inp):
                        refs[g].add(inp.value)
    fv: dict[Graph, set[Node]] = {g: set(direct[g]) for g in graphs}
    changed = True
    while changed:
        changed = False
        for g in graphs:
            acc = set(direct[g])
            for h in refs[g]:
                acc |= fv.get(h, set())
            acc = {n for n in acc if n.graph is not g}
            if acc != fv[g]:
                fv[g] = acc
                changed = True
    out = {n._id: n for n in fv[graph]}
    return [out[k] for k in sorted(out)]


# ---------------------------------------------------------------------------
# Incremental family bookkeeping
# ---------------------------------------------------------------------------


def _graph_body_facts(g: Graph) -> tuple[frozenset, frozenset]:
    """One body walk from ``g.return_`` (apply-input edges only, NOT
    entering graph constants, including free-variable chains) collecting:

    * ``crefs`` — graph constants referenced.  The transitive closure of
      this relation equals the entering-constants reachability of
      ``dfs_nodes``; it is the edge set of the graph-reference digraph
      :class:`FamilyIndex` runs SCC over.
    * ``ext`` — owners of foreign nodes the walk touches (free variables),
      including the owners of nodes referenced by
      :class:`SymbolicKey <repro.core.values.SymbolicKey>` constants: a
      key is an edge for sharing purposes (writer and reader must agree
      on node identity).  Used by the shared-region clone analysis.

    Both are functions of ``g``'s body alone, so :class:`FamilyIndex`
    memoizes them per graph until the body is rewritten."""
    from .values import SymbolicKey

    crefs: set[Graph] = set()
    ext: set[Graph] = set()
    if g.return_ is None:
        return frozenset(), frozenset()
    seen: set[int] = set()
    stack: list[Node] = [g.return_]
    while stack:
        n = stack.pop()
        if id(n) in seen:
            continue
        seen.add(id(n))
        if isinstance(n, Constant):
            if isinstance(n.value, Graph):
                crefs.add(n.value)
            elif isinstance(n.value, SymbolicKey):
                owner = n.value.node.graph
                if owner is not None and owner is not g:
                    ext.add(owner)
            continue
        owner = n.graph
        if owner is not None and owner is not g:
            ext.add(owner)
        if isinstance(n, Apply):
            stack.extend(n._inputs)
    return frozenset(crefs), frozenset(ext)


def _clone_needed(root: Graph, fam: set[Graph], body_facts) -> set[Graph]:
    """The subset of ``root``'s family an inline clone must actually copy.

    Cloning exists to rebind ``root``'s parameters (``param_repl``); any
    sub-family that is *closed* — its graphs reference, capture, and key
    only nodes inside itself — evaluates identically in the original and
    the clone, so the cloner can keep one shared copy instead of
    deep-copying it per call site (the inline "clone storm" fix).  A graph
    must be copied when its region touches anything outside itself:
    another family graph's nodes (free variables that will be remapped,
    transitively including ``root``'s parameters) or symbolic keys into
    one.  Falls back to the whole family when the reference digraph is
    cyclic (recursive families are never inlined, but stay safe anyway).
    """
    if len(fam) == 1:
        return set(fam)
    info = {g: body_facts(g) for g in fam}
    # region(g) = {g} ∪ transitive graph-constant closure, via post-order
    # over the (acyclic for inline-safe callees) reference digraph
    region: dict[Graph, frozenset] = {}
    state: dict[int, int] = {}  # id(g) -> 1 in-progress, 2 done
    for start in fam:
        if state.get(id(start)) == 2:
            continue
        stack: list[tuple[Graph, bool]] = [(start, False)]
        while stack:
            g, ready = stack.pop()
            if ready:
                acc = {g}
                for c in info[g][0]:
                    if c not in fam:
                        continue
                    if c in region:
                        acc |= region[c]
                    else:  # cycle (recursive family): share nothing
                        return set(fam)
                region[g] = frozenset(acc)
                state[id(g)] = 2
                continue
            st = state.get(id(g))
            if st == 2:
                continue
            if st == 1:  # back-edge: cyclic reference digraph
                return set(fam)
            state[id(g)] = 1
            stack.append((g, True))
            for c in info[g][0]:
                if c not in fam:
                    continue
                if state.get(id(c)) != 2:
                    if state.get(id(c)) == 1:
                        return set(fam)
                    stack.append((c, False))
    bad = {g for g in fam if any(e in fam and e not in region[g] for e in info[g][1])}
    # taint propagates up the reference digraph: a clean graph whose region
    # contains a bad one cannot be shared either (its copy must reference
    # the bad graph's copy)
    return {root} | {g for g in fam if region[g] & bad}


class FamilyIndex:
    """Incrementally-maintained family / recursion / inline-safety facts for
    a root graph under rewriting.

    The optimizer asks three questions over and over: which graphs make up
    the family below ``root``, is a graph recursive (can it reach a constant
    reference to itself), and is a callee safe to inline (nothing recursive
    reachable from it).  Recomputing these from scratch after every inline
    wave is O(family × nodes); this index instead answers from a facts
    table built in ONE pass per invalidation epoch:

    * ``_ensure_facts`` runs a single linear walk collecting per-graph
      direct graph-constant references (the edge set of the reference
      digraph — its transitive closure equals ``dfs_nodes`` reachability),
      then one iterative Tarjan SCC pass over it: ``is_recursive`` is
      membership in a cyclic SCC, ``inline_safe`` is "no cyclic SCC
      reachable", folded in reverse topological order as SCCs pop.  Every
      subsequent query is a dict hit.
    * ``note_clone`` adds the freshly-cloned graphs to the family set,
      pre-seeds their facts (an inline-safe callee's clones reference only
      other clones and shared inline-safe originals, so each clone is
      non-recursive and safe), and drops only the descendant /
      clone-family entries that contain the inline target.
    * Local rewrites may *orphan* graphs (the family set becomes a
      superset) — scanning an orphan is wasted work, never unsound.  A
      rewrite can also cut a graph's self-reference; call
      ``invalidate_rewrites`` between rewrite passes to pick that up
      (the facts table is rebuilt lazily, one linear pass per epoch).
    * ``clone_family`` memoizes the inliner's shared-region analysis
      (:func:`_clone_needed`) per callee, so inlining the same callee at
      many call sites in a wave analyses it once.
    """

    __slots__ = (
        "root",
        "_graphs",
        "_desc",
        "_rec",
        "_safe",
        "_facts",
        "_clonefam",
        "_bodyfacts",
        "_topo",
    )

    def __init__(self, root: Graph) -> None:
        self.root = root
        self._graphs: set[Graph] | None = None
        self._desc: dict[Graph, set[Graph]] = {}
        self._rec: dict[Graph, bool] = {}
        self._safe: dict[Graph, bool] = {}
        self._facts = False
        #: callee -> (its full family, the subset an inline clone must copy)
        self._clonefam: dict[Graph, tuple[frozenset, frozenset]] = {}
        #: per-graph (crefs, ext) body facts — the single-walk currency
        #: everything above is derived from; dropped per graph when its
        #: body is rewritten (see invalidate_rewrites / note_clone)
        self._bodyfacts: dict[Graph, tuple[frozenset, frozenset]] = {}
        #: Tarjan pop position per graph: lower = deeper in the reference
        #: DAG (popped before its ancestors).  The inliner sorts call
        #: sites by their owner's position so callee bodies are flattened
        #: BEFORE being cloned into callers — without the ordering, a call
        #: nested k levels deep is re-cloned k times across waves
        self._topo: dict[Graph, int] = {}

    # -- queries -----------------------------------------------------------
    def graphs(self) -> set[Graph]:
        if self._graphs is None:
            self._graphs = graph_and_descendants(self.root)
        return self._graphs

    def descendants(self, g: Graph) -> set[Graph]:
        """``{g}`` plus every graph transitively referenced from it —
        computed as the closure of the memoized per-graph crefs instead of
        a full node walk (the two are equivalent: crefs is exactly the
        one-step graph-reference relation of ``dfs_nodes``)."""
        hit = self._desc.get(g)
        if hit is None:
            out = {g}
            stack = [g]
            while stack:
                for c in self.body_facts(stack.pop())[0]:
                    if c not in out:
                        out.add(c)
                        stack.append(c)
            hit = self._desc[g] = out
        return hit

    def is_recursive(self, g: Graph) -> bool:
        """Can ``g`` reach a constant reference to itself?  Equivalent to
        membership in a cyclic SCC of the graph-reference digraph — the
        SAME reachability the cloner uses (dfs entering graph constants),
        so classification and clone scope can never disagree."""
        hit = self._rec.get(g)
        if hit is None:
            self._ensure_facts()
            hit = self._rec.get(g)
            if hit is None:  # graph surfaced after the facts pass
                hit = any(
                    is_constant_graph(n) and n.value is g
                    for n in dfs_nodes(g.return_)
                )
                self._rec[g] = hit
        return hit

    def inline_safe(self, g: Graph) -> bool:
        """True iff nothing recursive is reachable from ``g`` — the cloner
        copies ``g``'s family, and duplicating a recursive cycle exposes a
        fresh entry wrapper every wave (unbounded peeling)."""
        hit = self._safe.get(g)
        if hit is None:
            self._ensure_facts()
            hit = self._safe.get(g)
            if hit is None:  # graph surfaced after the facts pass
                hit = not any(self.is_recursive(h) for h in self.descendants(g))
                self._safe[g] = hit
        return hit

    def clone_family(self, g: Graph) -> set[Graph]:
        """The subset of ``g``'s family an inline clone must deep-copy
        (everything else is closed and shared — see :func:`_clone_needed`),
        memoized per callee until a rewrite epoch or a clone into one of
        its members invalidates it."""
        hit = self._clonefam.get(g)
        if hit is None:
            fam = frozenset(self.descendants(g))
            hit = (fam, frozenset(_clone_needed(g, fam, self.body_facts)))
            self._clonefam[g] = hit
        return set(hit[1])

    def topo_pos(self, g: Graph) -> int:
        """Reverse-topological position of ``g`` (deepest-first ordering
        for the inliner); graphs unknown to the facts pass sort last."""
        self._ensure_facts()
        return self._topo.get(g, 1 << 30)

    def body_facts(self, g: Graph) -> tuple[frozenset, frozenset]:
        """Memoized :func:`_graph_body_facts` — one walk per graph per
        body version."""
        hit = self._bodyfacts.get(g)
        if hit is None:
            hit = self._bodyfacts[g] = _graph_body_facts(g)
        return hit

    def _ensure_facts(self) -> None:
        """One linear pass: per-graph direct reference edges, then Tarjan
        SCC.  Cyclic SCC => every member recursive and unsafe; acyclic
        singleton => non-recursive, safe iff all referenced graphs are
        (folded as SCCs pop, which is reverse topological order)."""
        if self._facts:
            return
        self._facts = True
        self._topo = {}
        topo = self._topo
        # ordering discipline: graphs are visited in creation (_id) order so
        # the Tarjan pop order — and with it the inliner's deepest-first
        # site ordering — is identical run to run (sets of graphs iterate
        # in address order, which Python does not stabilize across runs)
        refs: dict[Graph, list[Graph]] = {}
        work = sorted(self.graphs(), key=lambda g: g._id, reverse=True)
        while work:
            g = work.pop()
            if g in refs:
                continue
            rs = sorted(self.body_facts(g)[0], key=lambda h: h._id)
            refs[g] = rs
            work.extend(h for h in rs if h not in refs)
        rec, safe = self._rec, self._safe
        index: dict[int, int] = {}
        low: dict[int, int] = {}
        on: set[int] = set()
        scc_stack: list[Graph] = []
        counter = 0
        for start in refs:
            if id(start) in index:
                continue
            frames: list[tuple[Graph, int]] = [(start, 0)]
            while frames:
                g, pi = frames[-1]
                gid = id(g)
                if pi == 0:
                    index[gid] = low[gid] = counter
                    counter += 1
                    scc_stack.append(g)
                    on.add(gid)
                children = refs[g]
                descended = False
                while pi < len(children):
                    h = children[pi]
                    pi += 1
                    hid = id(h)
                    if hid not in index:
                        frames[-1] = (g, pi)
                        frames.append((h, 0))
                        descended = True
                        break
                    if hid in on and index[hid] < low[gid]:
                        low[gid] = index[hid]
                if descended:
                    continue
                frames.pop()
                if frames:
                    pgid = id(frames[-1][0])
                    if low[gid] < low[pgid]:
                        low[pgid] = low[gid]
                if low[gid] == index[gid]:
                    comp: list[Graph] = []
                    while True:
                        h = scc_stack.pop()
                        on.discard(id(h))
                        comp.append(h)
                        if h is g:
                            break
                    for h in comp:
                        topo[h] = len(topo)
                    if len(comp) > 1 or any(c is g for c in refs[g]):
                        for h in comp:
                            rec[h] = True
                            safe[h] = False
                    else:
                        rec[g] = False
                        safe[g] = all(safe[c] for c in refs[g])

    # -- maintenance -------------------------------------------------------
    def note_clone(self, cloner: "GraphCloner") -> None:
        """Incremental update after an inline clone: extend the family with
        the new graphs; drop descendant entries that contained the inline
        target (they just gained the clones).  Recursion/safety caches stay
        valid — see the class docstring."""
        target = cloner.inline_target
        new_graphs = set(cloner.graph_map.values())
        if target is not None:
            new_graphs.discard(target)
        if self._graphs is not None:
            self._graphs |= new_graphs
        if self._safe.get(cloner.root) is True:
            # clones of an inline-safe family reference only other clones
            # and shared inline-safe originals: non-recursive and safe
            for ng in new_graphs:
                self._rec.setdefault(ng, False)
                self._safe.setdefault(ng, True)
        # a clone's body facts are its original's, mapped through the
        # cloner (shared references stay as-is) — seeding them here saves
        # one full body walk per cloned graph per facts epoch
        gmap = cloner.graph_map
        for og, ng in gmap.items():
            if ng is target or ng not in new_graphs:
                continue
            base = self._bodyfacts.get(og)
            if base is not None:
                self._bodyfacts[ng] = (
                    frozenset(gmap.get(c, c) for c in base[0]),
                    frozenset(gmap.get(e, e) for e in base[1]),
                )
            pos = self._topo.get(og)
            if pos is not None:
                self._topo.setdefault(ng, pos)
        if target is not None:
            self._bodyfacts.pop(target, None)
            stale = [g for g, d in self._desc.items() if target in d]
            for g in stale:
                del self._desc[g]
            stale_cf = [g for g, (fam, _) in self._clonefam.items() if target in fam]
            for g in stale_cf:
                del self._clonefam[g]

    def invalidate_rewrites(self, dirty: set[Graph] | None = None) -> None:
        """Local rewrites changed graph bodies: recursion facts may be
        stale (a rewrite can cut — or add — a graph reference), so drop
        everything derived from them; the family set only ever grows into
        a sound superset and survives.  When the rewriter can name the
        graphs whose bodies actually changed (``dirty``), per-graph body
        facts survive for every clean graph — the next facts pass is then
        a dict-lookup sweep instead of a full node walk."""
        if dirty is None:
            self._rec.clear()
            self._safe.clear()
            self._facts = False
            self._desc.clear()
            self._clonefam.clear()
            self._bodyfacts.clear()
            return
        # refresh the touched graphs' body facts eagerly: when none of
        # their graph-reference sets changed (the common case for local
        # rules), the reference digraph — and with it every recursion /
        # safety / topo fact — is untouched and survives the epoch
        refs_changed = False
        for g in dirty:
            old = self._bodyfacts.pop(g, None)
            new = self._bodyfacts[g] = _graph_body_facts(g)
            if old is None or old[0] != new[0]:
                refs_changed = True
        if refs_changed:
            self._rec.clear()
            self._safe.clear()
            self._facts = False
        stale = [g for g, d in self._desc.items() if d & dirty]
        for g in stale:
            del self._desc[g]
        stale_cf = [g for g, (fam, _) in self._clonefam.items() if fam & dirty]
        for g in stale_cf:
            del self._clonefam[g]


# ---------------------------------------------------------------------------
# Cloning
# ---------------------------------------------------------------------------


class GraphCloner:
    """Clone a graph family, remapping internal references.

    ``inline_target``: if given, nodes of the root graph are created inside
    that graph instead of a fresh one (used by the inliner), and parameters
    are replaced by ``param_map`` values.

    ``family``: if given, only these graphs are deep-copied; references to
    the rest of the root's family are kept pointing at the shared
    originals.  Callers must pass a set that is sound to share — the
    inliner uses :func:`_clone_needed` (closed sub-families evaluate
    identically in original and clone, so one shared copy suffices).
    Defaults to the whole family (full deep copy).
    """

    def __init__(
        self,
        root: Graph,
        *,
        inline_target: Graph | None = None,
        param_repl: dict[Node, Node] | None = None,
        relabel: str = "",
        family: set[Graph] | None = None,
    ) -> None:
        self.root = root
        self.inline_target = inline_target
        self.param_repl = param_repl or {}
        self.relabel = relabel
        self.node_map: dict[int, Node] = {}
        self.graph_map: dict[Graph, Graph] = {}
        self.family = set(family) if family is not None else graph_and_descendants(root)

    def clone(self) -> Graph:
        new_root = self._clone_graph_shell(self.root, inline=self.inline_target)
        for g in self.family:
            if g is self.root and self.inline_target is not None:
                continue
            self._clone_graph_shell(g)
        # clone bodies
        for g in self.family:
            tgt = self.graph_map[g]
            new_ret = self._clone_node(g.return_, g)
            if not (g is self.root and self.inline_target is not None):
                tgt.set_return(new_ret)
            else:
                # inline: stash the return value for the caller to fetch
                self.inlined_return = new_ret
        self._remap_symbolic_keys()
        return new_root

    def _remap_symbolic_keys(self) -> None:
        """Symbolic keys referencing cloned nodes must point at the clones,
        or gradient environments written by a cloned adjoint would not match
        the keys used by its (also cloned) unpackers."""
        from .values import SymbolicKey

        for new in self.node_map.values():
            if isinstance(new, Constant) and isinstance(new.value, SymbolicKey):
                target = self.node_map.get(new.value.node._id)
                if target is not None:
                    new.value = SymbolicKey(target)

    def _clone_graph_shell(self, g: Graph, inline: Graph | None = None) -> Graph:
        if g in self.graph_map:
            return self.graph_map[g]
        if inline is not None:
            self.graph_map[g] = inline
            for p in g.parameters:
                self.node_map[p._id] = self.param_repl[p]
            return inline
        ng = Graph(g.name + self.relabel)
        ng.flags = dict(g.flags)
        ng.primal = g.primal
        ng.parent_hint = g.parent_hint
        self.graph_map[g] = ng
        for p in g.parameters:
            np_ = ng.add_parameter(p.debug_name)
            np_.abstract = p.abstract
            self.node_map[p._id] = np_
        return ng

    def _clone_node(self, node: Node, owner: Graph) -> Node:
        """Iterative post-order clone (deep graphs must not hit the Python
        recursion limit)."""
        if node._id in self.node_map:
            return self.node_map[node._id]
        stack: list[tuple[Node, bool]] = [(node, False)]
        while stack:
            cur, ready = stack.pop()
            if cur._id in self.node_map:
                continue
            if isinstance(cur, Constant):
                if isinstance(cur.value, Graph) and cur.value in self.family:
                    new = Constant(self.graph_map[cur.value], cur.debug_name)
                else:
                    new = Constant(self.value_clone(cur.value), cur.debug_name)
                new.abstract = cur.abstract
                self.node_map[cur._id] = new
                continue
            if isinstance(cur, Parameter):
                # parameter of a graph outside the family: free variable
                self.node_map[cur._id] = cur
                continue
            assert isinstance(cur, Apply)
            if cur.graph not in self.family:
                # apply owned by an enclosing graph: free variable — keep
                self.node_map[cur._id] = cur
                continue
            if ready:
                new_inputs = [self.node_map[i._id] for i in cur.inputs]
                new = Apply(new_inputs, self.graph_map[cur.graph], cur.debug_name)
                new.abstract = cur.abstract
                self.node_map[cur._id] = new
            else:
                stack.append((cur, True))
                for i in cur.inputs:
                    if i._id not in self.node_map:
                        stack.append((i, False))
        return self.node_map[node._id]

    def value_clone(self, value: Any) -> Any:
        """Hook: values that must be remapped on clone (e.g. symbolic env
        keys referencing nodes) override this via subclassing in ad.py."""
        return value


def clone_graph(graph: Graph, relabel: str = "") -> Graph:
    return GraphCloner(graph, relabel=relabel).clone()
