"""Direct lowering: straight-line compilation of first-order graphs.

The paper's performance claim for ST AD (§4.3, Figure 1) is that once the
adjoint has been inlined and simplified, what remains is a *straight-line
program* that can be compiled ahead of time — "the graphs become amenable
to ahead-of-time optimization" — instead of being interpreted.  The VM
(``repro.core.vm``) is the general evaluator: it handles closures, free
variables, recursion and data-dependent calls, at the price of heap task
stacks, per-node frame dictionaries and per-input dispatch.  After the
optimizer has done its job, the overwhelmingly common case is a graph with
*none* of those features left — every apply calls a primitive held in a
constant, every reachable node belongs to the root graph.

This module emits that common case as generated Python source: one
assignment per apply node in topological order, executed over the
primitives' ``jnp`` implementations.  No Frame dicts, no task stack, no
users-edge bookkeeping — the function can be run eagerly (cheap first
call) or handed to ``jax.jit`` (XLA sees the identical straight-line
program the VM trace would have produced, minus the interpretation cost).

``lowering_blockers`` reports why a graph must stay on the VM:

* a constant holding a :class:`Graph` survived optimization (residual
  recursion, or a closure passed as a value — e.g. through ``switch`` on a
  traced condition),
* an apply whose callee is not a constant primitive (higher-order call),
* a node owned by another graph (free variable: the graph is nested).

``try_lower`` returns ``None`` in those cases and the caller falls back to
the VM path (see ``jax_backend.compile_graph`` / ``api.MyiaFunction``).
"""

from __future__ import annotations

import math
from typing import Any, Callable

from .ir import (
    Apply,
    Constant,
    Graph,
    Node,
    Parameter,
    dfs_nodes,
    is_constant_graph,
    toposort,
)
from .primitives import Primitive

__all__ = ["LoweringError", "lowering_blockers", "lower_graph", "try_lower"]


class LoweringError(Exception):
    """The graph is not a first-order straight-line program."""


def lowering_blockers(graph: Graph) -> list[str]:
    """Reasons ``graph`` cannot be lowered (empty list: lowerable)."""
    blockers: list[str] = []
    if graph.return_ is None:
        return ["graph has no return node"]
    for n in dfs_nodes(graph.return_):
        if is_constant_graph(n):
            blockers.append(
                f"graph-valued constant {n.value.name!r} survived optimization "
                "(residual recursion or closure value)"
            )
        elif isinstance(n, Apply):
            if n.graph is not graph:
                blockers.append(
                    f"free variable: apply node owned by nested graph "
                    f"{n.graph and n.graph.name!r}"
                )
            fn = n.fn
            if not (isinstance(fn, Constant) and isinstance(fn.value, Primitive)):
                blockers.append(
                    f"non-primitive callee {fn!r} (higher-order or graph call)"
                )
        elif isinstance(n, Parameter) and n.graph is not graph:
            blockers.append(f"free parameter {n!r} of graph {n.graph.name!r}")
    return blockers


def _literal(value: Any) -> str | None:
    """Source literal for ``value``, or None if it must be bound by name.

    Exact-type checks only: subclasses (np.float64, IntEnum, …) may repr
    to invalid or semantically different source (e.g. numpy>=2 reprs as
    ``np.float64(1.5)``, and demoting a strong-typed numpy scalar to a
    Python literal would change jax dtype promotion) — those are bound in
    the closure environment instead."""
    if value is None:
        return "None"
    t = type(value)
    if t is bool or t is str or t is int:
        return repr(value)
    if t is float:
        return repr(value) if math.isfinite(value) else None
    if t is tuple:
        elts = [_literal(v) for v in value]
        if any(e is None for e in elts):
            return None
        inner = ", ".join(elts)
        return f"({inner},)" if len(elts) == 1 else f"({inner})"
    return None


def lower_graph(graph: Graph) -> Callable:
    """Compile a first-order straight-line graph to a Python callable.

    The generated source (kept on the result as ``fn.__lowered_source__``)
    is one assignment per apply node in topological order; primitive
    implementations and non-literal constants are bound in the closure
    namespace.  Raises :class:`LoweringError` if the graph has residual
    graph values / higher-order calls / free variables.
    """
    blockers = lowering_blockers(graph)
    if blockers:
        raise LoweringError("; ".join(blockers))

    env: dict[str, Any] = {}
    prim_names: dict[int, str] = {}  # id(prim) -> bound name
    names: dict[int, str] = {}  # node id -> source name
    params = []
    for i, p in enumerate(graph.parameters):
        names[p._id] = f"p{i}"
        params.append(f"p{i}")

    def bind_prim(prim: Primitive) -> str:
        name = prim_names.get(id(prim))
        if name is None:
            name = f"_prim_{prim.name}_{len(prim_names)}"
            prim_names[id(prim)] = name
            env[name] = prim.impl
        return name

    def ref(node: Node) -> str:
        got = names.get(node._id)
        if got is not None:
            return got
        assert isinstance(node, Constant), f"unnamed non-constant {node!r}"
        lit = _literal(node.value)
        if lit is not None:
            return lit
        name = f"_const_{len(env)}"
        env[name] = node.value
        names[node._id] = name
        return name

    lines = [f"def _lowered({', '.join(params)}):"]
    seq = 0
    for n in toposort(graph):
        if not isinstance(n, Apply):
            continue
        prim = n.fn.value
        args = ", ".join(ref(a) for a in n.args)
        name = f"v{seq}"
        seq += 1
        names[n._id] = name
        lines.append(f"    {name} = {bind_prim(prim)}({args})  # {prim.name}")
    lines.append(f"    return {ref(graph.return_)}")
    source = "\n".join(lines) + "\n"

    namespace = dict(env)
    exec(compile(source, f"<myia-lowered:{graph.name}>", "exec"), namespace)
    fn = namespace["_lowered"]
    fn.__name__ = f"lowered_{graph.name}"
    fn.__lowered_source__ = source
    fn.__lowered_env__ = env
    return fn


def try_lower(graph: Graph) -> Callable | None:
    """``lower_graph`` if possible, else None (caller falls back to the VM)."""
    try:
        return lower_graph(graph)
    except LoweringError:
        return None
