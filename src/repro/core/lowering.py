"""Direct lowering: straight-line compilation of first-order graphs.

The paper's performance claim for ST AD (§4.3, Figure 1) is that once the
adjoint has been inlined and simplified, what remains is a *straight-line
program* that can be compiled ahead of time — "the graphs become amenable
to ahead-of-time optimization" — instead of being interpreted.  The VM
(``repro.core.vm``) is the general evaluator: it handles closures, free
variables, recursion and data-dependent calls, at the price of heap task
stacks, per-node frame dictionaries and per-input dispatch.  After the
optimizer has done its job, the overwhelmingly common case is a graph with
*none* of those features left — every apply calls a primitive held in a
constant, every reachable node belongs to the root graph.

This module emits that common case as generated Python source: one
assignment per apply node in topological order, executed over the
primitives' ``jnp`` implementations.  No Frame dicts, no task stack, no
users-edge bookkeeping — the function can be run eagerly (cheap first
call) or handed to ``jax.jit`` (XLA sees the identical straight-line
program the VM trace would have produced, minus the interpretation cost).

``lowering_blockers`` reports why a graph must stay on the VM:

* a constant holding a :class:`Graph` survived optimization (residual
  recursion, or a closure passed as a value — e.g. through ``switch`` on a
  traced condition),
* an apply whose callee is not a constant primitive (higher-order call),
* a node owned by another graph (free variable: the graph is nested).

``try_lower`` returns ``None`` in those cases and the caller falls back to
the VM path (see ``jax_backend.compile_graph`` / ``api.MyiaFunction``).
"""

from __future__ import annotations

import math
from typing import Any, Callable

import numpy as np

from .closure import analyze_blockers
from .ir import (
    Apply,
    Constant,
    Graph,
    Node,
    toposort,
)
from .primitives import COLLECTIVE_NAMES, LOOP_GRAPH_ARGS, Primitive

__all__ = [
    "LoweringError",
    "analyze_blockers",
    "lowering_blockers",
    "lower_graph",
    "try_lower",
]


class LoweringError(Exception):
    """The graph is not a first-order straight-line program."""


def lowering_blockers(graph: Graph) -> list[str]:
    """Reasons ``graph`` cannot be lowered (empty list: lowerable).

    The string form of :func:`repro.core.closure.analyze_blockers` — each
    message is prefixed with its structured kind (``[recursion-shape]``,
    ``[higher-order-residual]``, …).  De-duplicated (first occurrence
    wins): a residually recursive family repeats the same graph-valued
    constant at every call site, and callers log/assert on the list — N
    copies of one message carry no extra information."""
    return [str(r) for r in analyze_blockers(graph)]


def _literal(value: Any) -> str | None:
    """Source literal for ``value``, or None if it must be bound by name.

    Exact-type checks only: subclasses (np.float64, IntEnum, …) may repr
    to invalid or semantically different source (e.g. numpy>=2 reprs as
    ``np.float64(1.5)``, and demoting a strong-typed numpy scalar to a
    Python literal would change jax dtype promotion) — those are bound in
    the closure environment instead."""
    if value is None:
        return "None"
    t = type(value)
    if t is bool or t is str or t is int:
        return repr(value)
    if t is float:
        return repr(value) if math.isfinite(value) else None
    if t is tuple:
        elts = [_literal(v) for v in value]
        if any(e is None for e in elts):
            return None
        inner = ", ".join(elts)
        return f"({inner},)" if len(elts) == 1 else f"({inner})"
    return None


def _abstract_nbytes(ab: Any) -> int:
    """Bytes of an abstract value (arrays + tuples of arrays; 0 unknown)."""
    from .infer import AArray, ATuple

    if isinstance(ab, AArray):
        n = 1
        for d in ab.shape:
            n *= int(d)
        return n * np.dtype(ab.dtype).itemsize
    if isinstance(ab, ATuple):
        return sum(_abstract_nbytes(e) for e in ab.elements)
    return 0


def _launch_nbytes(node: Apply) -> int:
    """Bytes-moved estimate for one launch: every operand read + the
    result written, from the inferred abstracts (0 when uninferred)."""
    total = _abstract_nbytes(node.abstract)
    for a in node.args:
        total += _abstract_nbytes(getattr(a, "abstract", None))
    return total


def lower_graph(graph: Graph, *, fuse: bool = False, profile: bool = False) -> Callable:
    """Compile a first-order straight-line graph to a Python callable.

    The generated source (kept on the result as ``fn.__lowered_source__``)
    is one assignment per apply node in topological order; primitive
    implementations and non-literal constants are bound in the closure
    namespace.  Raises :class:`LoweringError` if the graph has residual
    graph values / higher-order calls / free variables.

    With ``fuse=True`` the graph is first partitioned into fusion regions
    (``repro.core.fusion``); every cluster the code generator accepts is
    emitted as ONE call to its generated Pallas kernel (mode-dispatched:
    jnp oracle / Pallas interpret / compiled — see
    ``repro.kernels.codegen``), and its interior nodes disappear from the
    emitted source.  Clusters the generator declines fall back to the
    per-node jnp path — fusion never changes *whether* a graph lowers.
    The fusion plan and kernels ride on the result as
    ``fn.__fusion_plan__`` / ``fn.__fused_kernels__``.

    With ``profile=True`` every *unfused* launch (opaque op, structured
    loop, collective) is additionally wrapped in
    ``repro.obs.profile.call_profiled`` — fused kernels time themselves —
    so an armed :class:`~repro.obs.profile.Profiler` receives one record
    per launch when the result is executed eagerly.  Disarmed, each hook
    is a single module-global None-check; the default ``profile=False``
    emits byte-identical source to before the profiler existed, so the
    production path is structurally untouched.
    """
    from repro.obs import trace as obs_trace

    blockers = lowering_blockers(graph)
    if blockers:
        raise LoweringError("; ".join(blockers))
    with obs_trace.span("lower", graph=graph.name, fuse=fuse):
        return _lower_graph_body(graph, fuse, profile)


def _lower_graph_body(graph: Graph, fuse: bool, profile: bool = False) -> Callable:
    plan = None
    fused: dict[int, Any] = {}  # root node id -> FusedKernel
    skip: set[int] = set()  # interior member ids of emitted clusters
    cluster_of_root: dict[int, Any] = {}
    if fuse:
        from .fusion import partition_graph
        from repro.kernels.codegen import emit_cluster

        plan = partition_graph(graph)
        for cluster in plan.clusters:
            kernel = emit_cluster(cluster)
            if kernel is None:
                continue  # declined: this cluster stays on the jnp path
            fused[cluster.root._id] = kernel
            cluster_of_root[cluster.root._id] = cluster
            skip |= cluster.members - {cluster.root._id}
        # the attached plan must account only for clusters that actually
        # emitted — declined ones save no launches
        plan.clusters = [c for c in plan.clusters if c.root._id in fused]

    env: dict[str, Any] = {}
    if profile:
        from repro.obs import profile as obs_profile

        env["_prof"] = obs_profile.call_profiled
    prim_names: dict[int, str] = {}  # id(prim) -> bound name
    names: dict[int, str] = {}  # node id -> source name
    params = []
    for i, p in enumerate(graph.parameters):
        names[p._id] = f"p{i}"
        params.append(f"p{i}")

    def bind_prim(prim: Primitive) -> str:
        name = prim_names.get(id(prim))
        if name is None:
            name = f"_prim_{prim.name}_{len(prim_names)}"
            prim_names[id(prim)] = name
            env[name] = prim.impl
        return name

    def ref(node: Node) -> str:
        got = names.get(node._id)
        if got is not None:
            return got
        assert isinstance(node, Constant), f"unnamed non-constant {node!r}"
        lit = _literal(node.value)
        if lit is not None:
            return lit
        name = f"_const_{len(env)}"
        env[name] = node.value
        names[node._id] = name
        return name

    lines = [f"def _lowered({', '.join(params)}):"]
    seq = 0
    for n in toposort(graph):
        if not isinstance(n, Apply) or n._id in skip:
            continue
        name = f"v{seq}"
        seq += 1
        names[n._id] = name
        kernel = fused.get(n._id)
        if kernel is not None:
            cluster = cluster_of_root[n._id]
            kname = f"_fused_{len(env)}"
            env[kname] = kernel
            args = ", ".join(ref(a) for a in cluster.inputs)
            lines.append(
                f"    {name} = {kname}({args})  # fused[{kernel.n_nodes}] {kernel.name}"
            )
            continue
        prim = n.fn.value
        n_sub = LOOP_GRAPH_ARGS.get(prim.name)
        if n_sub is not None:
            # structured loop: the leading args are closed first-order
            # graphs — lower each recursively and bind the callables, so
            # the loop body pays zero interpreter overhead too.  The body
            # executes under lax control flow (traced once), so per-op
            # profiling inside it is meaningless — the whole loop is one
            # "loop"-kind launch and the sub-lowering stays uninstrumented.
            subs = []
            for sub in n.args[:n_sub]:
                assert isinstance(sub, Constant) and isinstance(sub.value, Graph)
                sname = f"_loop_{sub.value.name.split(':')[-1]}_{len(env)}"
                env[sname] = lower_graph(sub.value, fuse=fuse)
                subs.append(sname)
            rest = [ref(a) for a in n.args[n_sub:]]
            args = ", ".join(subs + rest)
            if profile:
                lines.append(
                    f"    {name} = _prof({bind_prim(prim)}, "
                    f"{prim.name + ':' + name!r}, 'loop', {_launch_nbytes(n)}, "
                    f"{args})  # {prim.name}"
                )
            else:
                lines.append(f"    {name} = {bind_prim(prim)}({args})  # {prim.name}")
            continue
        args = ", ".join(ref(a) for a in n.args)
        if profile:
            kind = "collective" if prim.name in COLLECTIVE_NAMES else "opaque"
            lines.append(
                f"    {name} = _prof({bind_prim(prim)}, "
                f"{prim.name + ':' + name!r}, {kind!r}, {_launch_nbytes(n)}, "
                f"{args})  # {prim.name}"
            )
        else:
            lines.append(f"    {name} = {bind_prim(prim)}({args})  # {prim.name}")
    lines.append(f"    return {ref(graph.return_)}")
    source = "\n".join(lines) + "\n"

    namespace = dict(env)
    exec(compile(source, f"<myia-lowered:{graph.name}>", "exec"), namespace)
    fn = namespace["_lowered"]
    fn.__name__ = f"lowered_{graph.name}"
    fn.__lowered_source__ = source
    fn.__lowered_env__ = env
    fn.__fusion_plan__ = plan
    fn.__fused_kernels__ = list(fused.values())
    return fn


def try_lower(graph: Graph, *, fuse: bool = False) -> Callable | None:
    """``lower_graph`` if possible, else None (caller falls back to the VM).

    The result is cached on the graph (``graph.flags``), keyed by the fuse
    tier: ``MyiaFunction.specialize`` and ``compile_graph`` both probe the
    same optimized clone, and each probe used to re-walk the whole graph
    (blockers scan + emission).  The entry records which graph it belongs
    to — ``clone_graph`` shallow-copies ``flags``, and a clone (which the
    pipeline then optimizes further) must NOT inherit the original's
    verdict.  The cache is only correct for graphs that are no longer
    being rewritten — which is the only time callers lower.
    """
    entry = graph.flags.get("_lower_cache")
    if entry is None or entry[0] is not graph:
        entry = (graph, {})
        graph.flags["_lower_cache"] = entry
    cache = entry[1]
    if fuse in cache:
        return cache[fuse]
    try:
        fn = lower_graph(graph, fuse=fuse)
    except LoweringError:
        fn = None
    cache[fuse] = fn
    return fn
