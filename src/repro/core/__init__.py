"""``repro.core`` — the paper's contribution: a graph-based IR with
first-class functions/closures, closure-based source-transformation AD,
call-site-specializing type/shape inference, and an optimizing pipeline
(NeurIPS 2018, "Automatic differentiation in ML: where we are and where we
should be going" — the Myia paper)."""

from . import primitives as P  # noqa: F401
from .ad import J, build_grad_graph, build_value_and_grad_graph, build_vjp_graph  # noqa: F401
from .api import MyiaFunction, grad, myia, value_and_grad, vjp  # noqa: F401
from .closure import FallbackReason, analyze_blockers, lower_loops  # noqa: F401
from .fusion import Cluster, FusionPlan, partition_graph  # noqa: F401
from .infer import InferenceError, infer  # noqa: F401
from .ir import Apply, Constant, Graph, Node, Parameter, clone_graph  # noqa: F401
from .jax_backend import (  # noqa: F401
    CacheStats,
    ProgramCache,
    compile_graph,
    compile_graph_spmd,
    trace_graph,
)
from .lowering import LoweringError, lower_graph, lowering_blockers, try_lower  # noqa: F401
from .serialize import (  # noqa: F401
    SerializeError,
    deserialize_graph,
    serialize_graph,
    structural_hash,
)
from .spmd import SpmdError, SpmdPlan, propagate, shard_graph  # noqa: F401
from .oo_tape import oo_grad, oo_value_and_grad  # noqa: F401
from .opt import OptStats, count_nodes, optimize  # noqa: F401
from .parser import MyiaSyntaxError, parse_function  # noqa: F401
from .values import Closure, EnvInstance, SymbolicKey  # noqa: F401
from .vm import VM, run_graph  # noqa: F401
