"""JAX backend: execute IR graphs under ``jax.jit``.

The paper compiled "the straight-line parts of the graph using TVM"; the
TPU-idiomatic equivalent is to hand XLA the whole optimized graph as one
straight-line program.  Two routes produce that program:

* **Direct lowering** (the fast path): ``repro.core.lowering`` emits the
  optimized first-order graph as generated Python source — one assignment
  per apply node in topological order over the primitives' ``jnp``
  implementations.  ``jax.jit`` traces that straight-line function with
  *zero* interpreter machinery in the way, and the same callable can also
  run eagerly (no XLA compile on the critical path of the first call).
* **VM trace** (the fallback): when residual graph values survive
  optimization *and* closure elimination — non-tail recursion, nested
  loops, closures selected by ``switch`` on traced values — the reference
  VM evaluates the graph and ``jax.jit`` traces *through* the
  interpreter.  Interpreter overhead is paid once at trace time (contrast
  with the OO baseline, which pays it per call).

``compile_graph`` picks automatically: lowering when
``lowering_blockers(graph)`` is empty, VM otherwise.

Data-dependent control flow: the closure-elimination tier
(``repro.core.closure``) rewrites tail-recursive families — parsed
``while``/``for`` loops, defunctionalized higher-order recursion — into
``while_loop``/``scan_loop`` primitive applies, which the lowering emits
as ``jax.lax.while_loop``/``jax.lax.scan`` with recursively-lowered
cond/step/exit callables: traced-value loop bounds compile instead of
punting to the VM.  See the fallback matrix in ``docs/pipeline.md`` for
the shapes that genuinely still need the interpreter.
"""

from __future__ import annotations

import hashlib
import json
import os
import pickle
import tempfile
from typing import Any, Callable, Sequence

import jax

from repro.obs import trace as obs_trace

from .ir import Graph
from .lowering import lower_graph, lowering_blockers, try_lower
from .serialize import (
    FORMAT_VERSION,
    SerializeError,
    deserialize_graph,
    dumps,
    loads,
    serialize_graph,
    structural_hash,
)
from .spmd import SpmdError, shard_graph
from .vm import VM

__all__ = [
    "CacheStats",
    "CompileFailed",
    "ProgramCache",
    "abstract_value_signature",
    "compile_graph",
    "compile_graph_spmd",
    "trace_graph",
    "lower_graph",
    "lowering_blockers",
]


class CompileFailed(Exception):
    """XLA compilation failed after the cache's bounded retries.

    The next rung of the degraded-mode ladder (``api.MyiaFunction``)
    catches this and falls back to the VM oracle — slow, but alive and
    correct (see docs/serving.md, "Failure modes & degraded operation").
    """


def _fault_hooks():
    """The serving fault-injection hooks, or None outside chaos runs.

    Imported lazily: ``repro.serve`` depends on ``repro.core``, so a
    module-level import here would be circular — and the cache/compile
    paths are cold enough that the cached-module lookup is free."""
    try:
        from repro.serve import faults
    except ImportError:  # pragma: no cover - serve tier absent
        return None
    return faults


def trace_graph(graph: Graph) -> Callable:
    """A plain callable evaluating the graph via the VM (traceable by jax)."""

    def run(*args: Any) -> Any:
        return VM().call(graph, tuple(args))

    run.__name__ = f"myia_{graph.name}"
    return run


def compile_graph(
    graph: Graph,
    *,
    jit: bool = True,
    donate_argnums=(),
    lower: bool = True,
    fuse: bool = False,
) -> Callable:
    """Compile ``graph`` to a callable.

    Straight-line first-order graphs are lowered directly (no VM in the
    trace); anything with residual graph values falls back to tracing the
    VM.  ``fuse=True`` selects the fusion tier: clustered regions execute
    as generated Pallas kernels (``repro.core.fusion`` +
    ``repro.kernels.codegen``), mode-selected by ``set_kernel_mode``.  The
    returned callable carries ``.lowered`` (bool) and ``.fn`` (the
    un-jitted callable) for introspection.
    """
    fn = try_lower(graph, fuse=fuse) if lower else None
    lowered = fn is not None
    if fn is None:
        fn = trace_graph(graph)

    if jit and fuse and lowered:
        # FusedKernel dispatch reads set_kernel_mode at TRACE time, so one
        # jit executable pins one mode — keep one jit per mode observed,
        # and the documented flip-and-rerun flow retraces instead of
        # silently replaying the old mode's executable.
        by_mode: dict[str, Callable] = {}

        def runner(*args: Any) -> Any:
            from repro.kernels.ops import get_kernel_mode

            mode = get_kernel_mode()
            jitted = by_mode.get(mode)
            if jitted is None:
                jitted = by_mode[mode] = jax.jit(fn, donate_argnums=donate_argnums)
            return jitted(*args)

        out = None
    else:
        out = jax.jit(fn, donate_argnums=donate_argnums) if jit else fn

        def runner(*args: Any) -> Any:
            return out(*args)

    runner.__name__ = f"myia_{graph.name}"
    runner.lowered = lowered
    runner.fn = fn
    runner.jitted = out if jit else None
    return runner


def compile_graph_spmd(
    graph: Graph,
    mesh,
    in_specs: Sequence[Any],
    *,
    jit: bool = True,
    fuse: bool = False,
) -> Callable:
    """Compile ``graph`` to a sharded callable over ``mesh`` (SPMD tier).

    The sharding propagation pass (``repro.core.spmd``) turns the
    optimized global graph into a per-shard program — collectives at the
    resharding points, shape constants localized — which lowers through
    the ordinary straight-line path (optionally fused into generated
    Pallas kernels; clusters never span a collective) and executes under
    ``jax.shard_map``.  Inputs arrive as *global* arrays; shard_map
    splits them per ``in_specs`` and reassembles global outputs.

    Raises :class:`SpmdError` when the graph cannot be sharded (residual
    recursion / higher-order calls, non-array parameters) — callers fall
    back to the single-device tier.
    """
    from repro.parallel import shard_map

    mesh_axes = dict(mesh.shape)
    sharded = shard_graph(graph, in_specs, mesh_axes)
    fn = try_lower(sharded.graph, fuse=fuse)
    if fn is None:  # pragma: no cover - shard_graph already validated
        raise SpmdError(f"per-shard program of {graph.name} failed to lower")

    def wrap() -> Callable:
        return shard_map(
            fn,
            mesh=mesh,
            in_specs=sharded.in_partition,
            out_specs=sharded.out_partition,
            check_rep=False,
        )

    if jit and fuse:
        # FusedKernel dispatch reads set_kernel_mode at TRACE time (see
        # compile_graph): keep one jit executable per observed mode
        by_mode: dict[str, Callable] = {}

        def runner(*args: Any) -> Any:
            from repro.kernels.ops import get_kernel_mode

            mode = get_kernel_mode()
            jitted = by_mode.get(mode)
            if jitted is None:
                jitted = by_mode[mode] = jax.jit(wrap())
            return jitted(*args)

        out = None
    else:
        out = jax.jit(wrap()) if jit else wrap()

        def runner(*args: Any) -> Any:
            return out(*args)

    runner.__name__ = f"myia_spmd_{graph.name}"
    runner.lowered = True
    runner.spmd = True
    runner.fn = fn
    runner.jitted = out if jit else None
    runner.sharded = sharded
    runner.plan = sharded.plan
    return runner


# ---------------------------------------------------------------------------
# Persistent AOT program cache
# ---------------------------------------------------------------------------


class CacheStats:
    """Counters from one :class:`ProgramCache` (surfaced like ``OptStats``).

    * ``hits`` / ``misses`` — cache-key lookups that found / did not find a
      durable entry,
    * ``exec_loads`` — hits answered by deserializing the stored XLA
      executable (zero recompilation: neither the pipeline's lowering nor
      XLA ran),
    * ``xla_compiles`` — actual ``.lower().compile()`` invocations this
      process performed (a warm restart of the same workload must keep
      this at 0 — pinned by the serve subprocess test),
    * ``puts`` / ``spills`` — entries written / evicted (LRU by mtime when
      over ``max_entries``),
    * ``errors`` — every degradation event, in aggregate (never fatal:
      the cache degrades to recompiling), classified further as:

      - ``corrupt_entries`` — entries whose payload would not decode
        (truncated/garbage pickle, undeserializable graph).  Each is
        **quarantined** (renamed to ``*.quarantined``, counted in
        ``quarantined``) so it is never re-read and never fatal,
      - ``io_errors`` — OS-level read/write failures (permissions, disk
        full, vanished files): the *file system* misbehaving, as opposed
        to the *bytes* being wrong,
      - the remainder of ``errors`` is benign degradation: foreign/stale
        executable blobs rebuilt from the graph payload, non-durable
        graphs served from memory only.

    * ``compile_retries`` / ``vm_fallbacks`` — the degraded-mode ladder:
      failed XLA compiles retried (bounded by ``max_compile_retries``),
      and specializations that exhausted retries and were handed to the
      VM oracle by ``api.MyiaFunction`` (see docs/serving.md).
    * ``graph_hits`` / ``graph_misses`` / ``graph_puts`` — the
      optimized-graph tier (``graph_key``/``load_graph``/``store_graph``):
      lookups of the *pre-optimization* key that found / did not find a
      stored post-optimize graph, and entries written.  A graph hit skips
      the optimize + closure-elim phases of ``compile_pipeline`` entirely.
    """

    __slots__ = (
        "hits", "misses", "exec_loads", "xla_compiles", "puts", "spills",
        "errors", "corrupt_entries", "io_errors", "quarantined",
        "compile_retries", "vm_fallbacks",
        "graph_hits", "graph_misses", "graph_puts",
    )

    def __init__(self) -> None:
        self.hits = 0
        self.misses = 0
        self.exec_loads = 0
        self.xla_compiles = 0
        self.puts = 0
        self.spills = 0
        self.errors = 0
        self.corrupt_entries = 0
        self.io_errors = 0
        self.quarantined = 0
        self.compile_retries = 0
        self.vm_fallbacks = 0
        self.graph_hits = 0
        self.graph_misses = 0
        self.graph_puts = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def graph_hit_rate(self) -> float:
        total = self.graph_hits + self.graph_misses
        return self.graph_hits / total if total else 0.0

    def as_dict(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "exec_loads": self.exec_loads,
            "xla_compiles": self.xla_compiles,
            "puts": self.puts,
            "spills": self.spills,
            "errors": self.errors,
            "corrupt_entries": self.corrupt_entries,
            "io_errors": self.io_errors,
            "quarantined": self.quarantined,
            "compile_retries": self.compile_retries,
            "vm_fallbacks": self.vm_fallbacks,
            "graph_hits": self.graph_hits,
            "graph_misses": self.graph_misses,
            "graph_puts": self.graph_puts,
            "hit_rate": round(self.hit_rate, 4),
            "graph_hit_rate": round(self.graph_hit_rate, 4),
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"CacheStats({self.as_dict()!r})"


def mesh_descriptor(mesh: Any) -> tuple | None:
    """Canonical identity of a concrete mesh: axis sizes + device ids.
    The single definition shared by the specialization key
    (``api.MyiaFunction``) and the AOT cache key — a same-shape mesh over
    different devices must never collide."""
    if mesh is None:
        return None
    return (
        tuple(sorted(mesh.shape.items())),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


def abstract_signature(example_args: Sequence[Any]) -> str:
    """Canonical string for the argument avals — the signature component of
    the cache key.  Only array arguments are supported (the AOT cache holds
    straight-line array programs; statics are baked into the graph)."""
    parts = []
    for a in example_args:
        if isinstance(a, jax.ShapeDtypeStruct):
            dt, shp = a.dtype, a.shape
        elif hasattr(a, "dtype") and hasattr(a, "shape"):
            dt, shp = a.dtype, a.shape
        else:
            raise SerializeError(f"non-array argument {type(a).__name__} in AOT signature")
        parts.append(f"{jax.numpy.dtype(dt).str}{list(shp)}")
    return ";".join(parts)


def abstract_value_signature(abstracts: Sequence[Any]) -> str:
    """Canonical string for a tuple of *inference* abstract values
    (``repro.core.infer.AScalar``/``AArray``/``ATuple``) — the signature
    component of the optimized-graph cache key.

    Known scalar *values* are part of the signature: constant propagation
    bakes them into the optimized graph, so two calls differing only in a
    static scalar must occupy different buckets.  Anything that cannot be
    canonically rendered (functions, environments, opaque statics) raises
    :class:`SerializeError` — the caller skips the graph tier."""
    from .infer import ANY, AArray, AScalar, ATuple

    def part(a: Any) -> str:
        if isinstance(a, AArray):
            return f"{a.dtype.str}{list(a.shape)}"
        if isinstance(a, ATuple):
            return "(" + ",".join(part(e) for e in a.elements) + ")"
        if isinstance(a, AScalar):
            if a.value is ANY:
                return f"{a.kind}:?"
            if a.value is None or isinstance(a.value, (bool, int, float, str)):
                return f"{a.kind}:{a.value!r}"
            raise SerializeError(
                f"opaque static value {type(a.value).__name__} in graph-cache signature"
            )
        raise SerializeError(
            f"non-durable abstract {type(a).__name__} in graph-cache signature"
        )

    return ";".join(part(a) for a in abstracts)


def _avals(example_args: Sequence[Any]) -> tuple:
    return tuple(
        a if isinstance(a, jax.ShapeDtypeStruct) else jax.ShapeDtypeStruct(a.shape, a.dtype)
        for a in example_args
    )


class ProgramCache:
    """Persistent two-tier cache of compiled programs, keyed on *what the
    program is* rather than which process built it.

    **Executable tier** (``key``/``load_or_compile``, ``<key>.pkl``)::

        structural graph hash × abstract signature × fuse/kernel-mode ×
        mesh descriptor × (jax version, serialize format, backend platform)

    Each entry stores the serialized optimized graph (``repro.core.
    serialize``) and, best-effort, the serialized XLA executable
    (``jax.experimental.serialize_executable``).  A warm process finds the
    entry, reloads the executable, and serves with **zero recompilations**;
    if the executable blob is incompatible (different machine/jaxlib) the
    stored graph is re-lowered and recompiled — never wrong, at worst slow.

    **Optimized-graph tier** (``graph_key``/``load_graph``/``store_graph``,
    ``<key>.graph.json``)::

        loose structural hash of the PRE-optimization graph ×
        abstract-value signature × opt/patterns/loops/engine config ×
        serialize format version

    The value is the canonical JSON of the post-optimize post-closure-elim
    graph, so a new specialization of a known family deserializes it and
    skips the optimize + closure-elim pipeline phases entirely (falling
    through to infer → lower → XLA, where the executable tier takes over).
    Reads are lock-free: writers publish complete entries atomically
    (``mkstemp`` + ``os.replace``), so concurrent distinct-key builds never
    block each other and same-key racers each land a valid entry with one
    survivor.  Counters are surfaced on ``.stats`` like ``OptStats``.
    """

    def __init__(
        self, path: str, *, max_entries: int = 256, max_compile_retries: int = 1
    ) -> None:
        self.path = os.path.abspath(path)
        self.max_entries = max_entries
        #: bounded retry for failed XLA compiles (rung 2 of the ladder);
        #: past it, :class:`CompileFailed` hands the caller to the VM rung
        self.max_compile_retries = max_compile_retries
        self.stats = CacheStats()
        os.makedirs(self.path, exist_ok=True)

    # -- keys --------------------------------------------------------------
    def key(
        self,
        graph: Graph,
        example_args: Sequence[Any],
        *,
        fuse: bool = False,
        kernel_mode: str | None = None,
        mesh: Any = None,
    ) -> str:
        if kernel_mode is None:
            from repro.kernels.ops import get_kernel_mode

            kernel_mode = get_kernel_mode()
        meshdesc = mesh_descriptor(mesh)
        payload = {
            "graph": structural_hash(graph),
            "sig": abstract_signature(example_args),
            "fuse": bool(fuse),
            "kernel_mode": kernel_mode,
            "mesh": meshdesc,
            "jax": jax.__version__,
            "format": FORMAT_VERSION,
            "platform": jax.devices()[0].platform,
        }
        return hashlib.sha256(
            json.dumps(payload, sort_keys=True, separators=(",", ":")).encode()
        ).hexdigest()

    def _file(self, key: str) -> str:
        return os.path.join(self.path, key + ".pkl")

    def probe(self, key: str) -> bool:
        """True when a durable executable entry exists for ``key``.

        Read-only: no stats mutation, no entry load — the explain layer's
        cache-tier verdict must not perturb the hit counters it reports."""
        return os.path.exists(self._file(key))

    def probe_graph(self, key: str) -> bool:
        """``probe`` for the optimized-graph tier (same read-only contract)."""
        return os.path.exists(self._graph_file(key))

    # -- optimized-graph tier ----------------------------------------------
    def graph_key(
        self,
        graph: Graph,
        abstracts: Sequence[Any],
        *,
        opt: bool = True,
        patterns: bool = False,
        loops: bool = True,
        engine: str = "worklist",
    ) -> str:
        """Cache key of the *pre-optimization* ``graph`` at an abstract
        signature, under one optimizer configuration.

        Raises :class:`SerializeError` when the graph or signature cannot
        be canonically keyed (runtime-only constants beyond symbolic keys
        and empty envs, opaque statics) — callers skip the tier.
        """
        payload = {
            "graph": structural_hash(graph, loose=True),
            "sig": abstract_value_signature(abstracts),
            "opt": bool(opt),
            "patterns": bool(patterns),
            "loops": bool(loops),
            "engine": engine,
            "format": FORMAT_VERSION,
        }
        return hashlib.sha256(
            json.dumps(payload, sort_keys=True, separators=(",", ":")).encode()
        ).hexdigest()

    def _graph_file(self, key: str) -> str:
        return os.path.join(self.path, key + ".graph.json")

    def load_graph(self, key: str) -> Graph | None:
        """The stored post-optimize graph for ``key``, or None.

        The read path takes no lock: writers only ever publish complete
        entries via ``os.replace``, so a reader sees either no file or a
        whole one — concurrent builders of distinct keys never serialize
        behind each other, and a corrupt entry (torn by an unclean shutdown)
        is quarantined, not fatal."""
        fpath = self._graph_file(key)
        try:
            with open(fpath, "r", encoding="utf-8") as f:
                text = f.read()
        except FileNotFoundError:
            self.stats.graph_misses += 1
            return None
        except OSError:
            self.stats.graph_misses += 1
            self.stats.io_errors += 1
            self.stats.errors += 1
            return None
        try:
            g = loads(text)
        except Exception:
            self._quarantine(fpath)
            self.stats.graph_misses += 1
            return None
        self.stats.graph_hits += 1
        try:
            os.utime(fpath)  # LRU touch
        except OSError:
            pass
        return g

    def store_graph(self, key: str, graph: Graph) -> bool:
        """Persist a post-optimize ``graph`` under ``key`` (atomic publish).

        Best-effort: a non-durable graph (residual runtime values) or a
        failing write degrades to not caching — never to an error."""
        try:
            text = dumps(graph)
        except SerializeError:
            self.stats.errors += 1
            return False
        tmp = None
        try:
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                f.write(text)
            os.replace(tmp, self._graph_file(key))
            self.stats.graph_puts += 1
        except OSError:
            self.stats.errors += 1
            self.stats.io_errors += 1
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
            return False
        self._evict(".graph.json")
        return True

    # -- main entry point --------------------------------------------------
    def load_or_compile(
        self,
        graph: Graph,
        example_args: Sequence[Any],
        *,
        fuse: bool = False,
        lowered_fn: Callable | None = None,
        mesh: Any = None,
    ) -> Callable:
        """An AOT-compiled callable for ``graph`` at ``example_args``'s
        avals, answered from disk when possible.

        Raises :class:`SerializeError` when the graph/arguments cannot be
        made durable (VM-fallback graphs, non-array args) — callers fall
        back to the ordinary jit tiers.
        """
        key = self.key(graph, example_args, fuse=fuse, mesh=mesh)
        avals = _avals(example_args)
        with obs_trace.span("cache.lookup", graph=graph.name) as sp:
            entry = self._read(key)
            if entry is not None:
                runner = self._from_entry(entry, avals, fuse=fuse, fpath=self._file(key))
                if runner is not None:
                    self.stats.hits += 1
                    sp.set(verdict="hit")
                    runner.cache_key = key
                    return runner
            self.stats.misses += 1
            sp.set(verdict="miss")
        # miss: compile fresh from the live graph and persist
        fn = lowered_fn if lowered_fn is not None else try_lower(graph, fuse=fuse)
        if fn is None:
            raise SerializeError(f"graph {graph.name} does not lower (VM fallback)")
        compiled = self._compile(fn, avals, tag=f"fresh:{graph.name}")
        with obs_trace.span("cache.write", graph=graph.name):
            self._write(key, graph, compiled)
        runner = _aot_runner(compiled)
        runner.cache_key = key
        return runner

    # -- internals ---------------------------------------------------------
    def _compile(self, fn: Callable, avals: tuple, *, tag: str) -> Any:
        """One XLA compile, with bounded retry (rung 2 of the ladder).

        Transient compile failures (injected by the chaos harness; OOM /
        backend flakes in the wild) are retried up to
        ``max_compile_retries`` times; a persistent failure raises
        :class:`CompileFailed` so the caller can take the VM rung."""
        fh = _fault_hooks()
        last: Exception | None = None
        for attempt in range(self.max_compile_retries + 1):
            if attempt:
                self.stats.compile_retries += 1
            try:
                if fh is not None:
                    fh.on_compile(tag)
                with obs_trace.span("xla.compile", tag=tag, attempt=attempt):
                    compiled = jax.jit(fn).lower(*avals).compile()
            except Exception as e:
                last = e
                continue
            self.stats.xla_compiles += 1
            return compiled
        raise CompileFailed(
            f"XLA compile of {tag} failed after "
            f"{self.max_compile_retries + 1} attempts"
        ) from last

    def _quarantine(self, fpath: str) -> None:
        """Rename a corrupt entry aside: ``<key>.pkl.quarantined`` no
        longer matches the ``.pkl`` suffix, so it is never re-read (and
        never re-written over — the key's next ``_write`` creates a
        fresh ``.pkl``).  Quarantine failure degrades to deletion; both
        paths leave the cache consistent and the process alive."""
        self.stats.corrupt_entries += 1
        self.stats.errors += 1
        try:
            os.replace(fpath, fpath + ".quarantined")
            self.stats.quarantined += 1
        except OSError:
            try:
                os.unlink(fpath)
                self.stats.quarantined += 1
            except OSError:
                self.stats.io_errors += 1

    def _read(self, key: str) -> dict | None:
        fpath = self._file(key)
        if not os.path.exists(fpath):
            return None
        fh = _fault_hooks()
        if fh is not None:
            fh.on_cache_read(fpath)
        try:
            with open(fpath, "rb") as f:
                entry = pickle.load(f)
        except OSError:
            self.stats.io_errors += 1
            self.stats.errors += 1
            return None
        except Exception:
            # truncated / garbage bytes: the entry itself is poison
            self._quarantine(fpath)
            return None
        if not isinstance(entry, dict) or "graph" not in entry:
            self._quarantine(fpath)  # decoded, but not a cache entry
            return None
        try:
            os.utime(fpath)  # LRU touch
        except OSError:
            self.stats.io_errors += 1
        return entry

    def _from_entry(
        self, entry: dict, avals: tuple, *, fuse: bool, fpath: str | None = None
    ) -> Callable | None:
        if entry.get("exec") is not None:
            try:
                from jax.experimental import serialize_executable

                compiled = serialize_executable.deserialize_and_load(
                    entry["exec"], entry["in_tree"], entry["out_tree"]
                )
                self.stats.exec_loads += 1
                return _aot_runner(compiled)
            except Exception:
                self.stats.errors += 1  # foreign/stale executable: rebuild
        try:
            g = deserialize_graph(entry["graph"])
        except Exception:
            # exec blob unusable AND graph payload undecodable: corrupt
            if fpath is not None:
                self._quarantine(fpath)
            else:
                self.stats.corrupt_entries += 1
                self.stats.errors += 1
            return None
        try:
            fn = try_lower(g, fuse=fuse)
            if fn is None:
                return None
            return _aot_runner(self._compile(fn, avals, tag=f"entry:{g.name}"))
        except CompileFailed:
            raise
        except Exception:
            self.stats.errors += 1
            return None

    def _write(self, key: str, graph: Graph, compiled: Any) -> None:
        try:
            payload = serialize_graph(graph)
        except SerializeError:
            self.stats.errors += 1
            return  # graph not durable: serve from memory only
        blob = in_tree = out_tree = None
        try:
            from jax.experimental import serialize_executable

            blob, in_tree, out_tree = serialize_executable.serialize(compiled)
        except Exception:
            self.stats.errors += 1  # entry still useful: graph-level reuse
        entry = {"graph": payload, "exec": blob, "in_tree": in_tree, "out_tree": out_tree}
        tmp = None
        try:
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                pickle.dump(entry, f)
            os.replace(tmp, self._file(key))
            self.stats.puts += 1
        except Exception as e:
            # disk full / permissions / unpicklable tree — the write layer,
            # not the entry bytes
            self.stats.errors += 1
            if isinstance(e, OSError):
                self.stats.io_errors += 1
            if tmp is not None:  # don't leak .tmp files into the cache dir
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
            return
        self._evict()

    def _evict(self, suffix: str = ".pkl") -> None:
        """Bound one tier's entry count (LRU by mtime).  Tiers evict
        independently: a burst of graph-tier puts never spills executables."""
        try:
            files = [
                os.path.join(self.path, n)
                for n in os.listdir(self.path)
                if n.endswith(suffix)
            ]
            if len(files) <= self.max_entries:
                return
            files.sort(key=os.path.getmtime)
            for f in files[: len(files) - self.max_entries]:
                os.remove(f)
                self.stats.spills += 1
        except OSError:
            self.stats.errors += 1
            self.stats.io_errors += 1


def _aot_runner(compiled: Any) -> Callable:
    def runner(*args: Any) -> Any:
        return compiled(*args)

    runner.lowered = True
    runner.aot = True
    runner.compiled = compiled
    return runner
