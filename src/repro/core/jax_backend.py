"""JAX backend: execute IR graphs under ``jax.jit``.

The paper compiled "the straight-line parts of the graph using TVM"; the
TPU-idiomatic equivalent is to hand XLA the whole optimized graph as one
straight-line program.  Two routes produce that program:

* **Direct lowering** (the fast path): ``repro.core.lowering`` emits the
  optimized first-order graph as generated Python source — one assignment
  per apply node in topological order over the primitives' ``jnp``
  implementations.  ``jax.jit`` traces that straight-line function with
  *zero* interpreter machinery in the way, and the same callable can also
  run eagerly (no XLA compile on the critical path of the first call).
* **VM trace** (the fallback): when residual graph values survive
  optimization *and* closure elimination — non-tail recursion, nested
  loops, closures selected by ``switch`` on traced values — the reference
  VM evaluates the graph and ``jax.jit`` traces *through* the
  interpreter.  Interpreter overhead is paid once at trace time (contrast
  with the OO baseline, which pays it per call).

``compile_graph`` picks automatically: lowering when
``lowering_blockers(graph)`` is empty, VM otherwise.

Data-dependent control flow: the closure-elimination tier
(``repro.core.closure``) rewrites tail-recursive families — parsed
``while``/``for`` loops, defunctionalized higher-order recursion — into
``while_loop``/``scan_loop`` primitive applies, which the lowering emits
as ``jax.lax.while_loop``/``jax.lax.scan`` with recursively-lowered
cond/step/exit callables: traced-value loop bounds compile instead of
punting to the VM.  See the fallback matrix in ``docs/pipeline.md`` for
the shapes that genuinely still need the interpreter.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax

from .ir import Graph
from .lowering import lower_graph, lowering_blockers, try_lower
from .spmd import SpmdError, shard_graph
from .vm import VM

__all__ = [
    "compile_graph",
    "compile_graph_spmd",
    "trace_graph",
    "lower_graph",
    "lowering_blockers",
]


def trace_graph(graph: Graph) -> Callable:
    """A plain callable evaluating the graph via the VM (traceable by jax)."""

    def run(*args: Any) -> Any:
        return VM().call(graph, tuple(args))

    run.__name__ = f"myia_{graph.name}"
    return run


def compile_graph(
    graph: Graph,
    *,
    jit: bool = True,
    donate_argnums=(),
    lower: bool = True,
    fuse: bool = False,
) -> Callable:
    """Compile ``graph`` to a callable.

    Straight-line first-order graphs are lowered directly (no VM in the
    trace); anything with residual graph values falls back to tracing the
    VM.  ``fuse=True`` selects the fusion tier: clustered regions execute
    as generated Pallas kernels (``repro.core.fusion`` +
    ``repro.kernels.codegen``), mode-selected by ``set_kernel_mode``.  The
    returned callable carries ``.lowered`` (bool) and ``.fn`` (the
    un-jitted callable) for introspection.
    """
    fn = try_lower(graph, fuse=fuse) if lower else None
    lowered = fn is not None
    if fn is None:
        fn = trace_graph(graph)

    if jit and fuse and lowered:
        # FusedKernel dispatch reads set_kernel_mode at TRACE time, so one
        # jit executable pins one mode — keep one jit per mode observed,
        # and the documented flip-and-rerun flow retraces instead of
        # silently replaying the old mode's executable.
        by_mode: dict[str, Callable] = {}

        def runner(*args: Any) -> Any:
            from repro.kernels.ops import get_kernel_mode

            mode = get_kernel_mode()
            jitted = by_mode.get(mode)
            if jitted is None:
                jitted = by_mode[mode] = jax.jit(fn, donate_argnums=donate_argnums)
            return jitted(*args)

        out = None
    else:
        out = jax.jit(fn, donate_argnums=donate_argnums) if jit else fn

        def runner(*args: Any) -> Any:
            return out(*args)

    runner.__name__ = f"myia_{graph.name}"
    runner.lowered = lowered
    runner.fn = fn
    runner.jitted = out if jit else None
    return runner


def compile_graph_spmd(
    graph: Graph,
    mesh,
    in_specs: Sequence[Any],
    *,
    jit: bool = True,
    fuse: bool = False,
) -> Callable:
    """Compile ``graph`` to a sharded callable over ``mesh`` (SPMD tier).

    The sharding propagation pass (``repro.core.spmd``) turns the
    optimized global graph into a per-shard program — collectives at the
    resharding points, shape constants localized — which lowers through
    the ordinary straight-line path (optionally fused into generated
    Pallas kernels; clusters never span a collective) and executes under
    ``jax.shard_map``.  Inputs arrive as *global* arrays; shard_map
    splits them per ``in_specs`` and reassembles global outputs.

    Raises :class:`SpmdError` when the graph cannot be sharded (residual
    recursion / higher-order calls, non-array parameters) — callers fall
    back to the single-device tier.
    """
    from repro.parallel import shard_map

    mesh_axes = dict(mesh.shape)
    sharded = shard_graph(graph, in_specs, mesh_axes)
    fn = try_lower(sharded.graph, fuse=fuse)
    if fn is None:  # pragma: no cover - shard_graph already validated
        raise SpmdError(f"per-shard program of {graph.name} failed to lower")

    def wrap() -> Callable:
        return shard_map(
            fn,
            mesh=mesh,
            in_specs=sharded.in_partition,
            out_specs=sharded.out_partition,
            check_rep=False,
        )

    if jit and fuse:
        # FusedKernel dispatch reads set_kernel_mode at TRACE time (see
        # compile_graph): keep one jit executable per observed mode
        by_mode: dict[str, Callable] = {}

        def runner(*args: Any) -> Any:
            from repro.kernels.ops import get_kernel_mode

            mode = get_kernel_mode()
            jitted = by_mode.get(mode)
            if jitted is None:
                jitted = by_mode[mode] = jax.jit(wrap())
            return jitted(*args)

        out = None
    else:
        out = jax.jit(wrap()) if jit else wrap()

        def runner(*args: Any) -> Any:
            return out(*args)

    runner.__name__ = f"myia_spmd_{graph.name}"
    runner.lowered = True
    runner.spmd = True
    runner.fn = fn
    runner.jitted = out if jit else None
    runner.sharded = sharded
    runner.plan = sharded.plan
    return runner
