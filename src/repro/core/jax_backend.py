"""JAX backend: execute IR graphs under ``jax.jit``.

The paper compiled "the straight-line parts of the graph using TVM"; the
TPU-idiomatic equivalent is to *trace* the whole optimized graph once with
JAX — every primitive's implementation is jnp — and let XLA compile the
resulting straight-line program.  Interpreter overhead is paid once at
trace time (contrast with the OO baseline, which pays it per call).

Data-dependent control flow: conditions that stay concrete (python ints)
unroll during tracing, exactly like the loop-specialization the inferencer
performs; genuinely traced-value recursion must use the VM backend.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

from .ir import Graph
from .vm import VM

__all__ = ["compile_graph", "trace_graph"]


def trace_graph(graph: Graph) -> Callable:
    """A plain callable evaluating the graph (traceable by jax)."""

    def run(*args: Any) -> Any:
        return VM().call(graph, tuple(args))

    run.__name__ = f"myia_{graph.name}"
    return run


def compile_graph(graph: Graph, *, jit: bool = True, donate_argnums=()) -> Callable:
    fn = trace_graph(graph)
    if not jit:
        return fn
    return jax.jit(fn, donate_argnums=donate_argnums)
