"""Round-trippable graph serialization + canonical structural hashing.

The paper's payoff for a closure-capable graph IR is that the optimized
program is a first-class *artifact* — "amenable to ahead-of-time
optimization" — yet until now nothing the pipeline produced outlived the
Python process.  This module makes optimized graphs durable:

* :func:`serialize_graph` / :func:`deserialize_graph` — a canonical,
  JSON-able encoding of a *closed* graph family (the root graph plus
  every graph it references, e.g. ``while_loop``/``scan_loop``
  sub-graphs).  Deserialize → re-lower reproduces the exact same
  straight-line program: the round trip is bit-identical under ``jit``
  (pinned by ``tests/core/test_serialize.py`` over the closure-elim and
  worklist corpora).
* :func:`structural_hash` — a content hash of the same encoding with all
  debug names stripped, so it is stable across process runs (node ids,
  dict ordering and clone relabels never leak in) and identical for
  structurally-identical graphs.  This is the first component of the AOT
  program-cache key (``repro.core.jax_backend.ProgramCache``).

What serializes: parameters, applies, and constants holding scalars,
strings, tuples, dtypes, numpy/jax arrays, :class:`Primitive`\\ s (by
registry name) and nested :class:`Graph`\\ s.  What doesn't: runtime-only
values (closures, gradient environments, symbolic keys) and free
variables into graphs outside the family — those only survive in
VM-fallback graphs, which are not AOT artifacts; :class:`SerializeError`
is raised and callers skip the cache.

Loose (hash-only) mode
----------------------

``structural_hash(g, loose=True)`` additionally admits the two runtime
value kinds that appear in *pre-optimization* adjoint graphs — symbolic
keys (encoded positionally, by the canonical index of the node they
reference) and empty gradient environments — so the optimized-graph
cache tier (``jax_backend.ProgramCache.graph_key``) can key on the
program *before* the optimizer runs.  Loose payloads are tagged and
refuse to deserialize: the encoding is an identity, not an artifact.
"""

from __future__ import annotations

import base64
import hashlib
import json
from typing import Any

import numpy as np

from .ir import Apply, Constant, Graph, Node, Parameter
from .primitives import PRIMITIVES, Primitive

__all__ = [
    "FORMAT_VERSION",
    "SerializeError",
    "serialize_graph",
    "deserialize_graph",
    "dumps",
    "loads",
    "structural_hash",
]

#: bump when the encoding changes — part of every ProgramCache key, so a
#: format change can never resurrect stale artifacts
FORMAT_VERSION = 1


class SerializeError(Exception):
    """The graph family contains values that cannot be made durable."""


_RUNTIME = None


def _runtime():
    """Lazily-bound (jax, jnp, EnvInstance, SymbolicKey) — deferred so
    importing this module stays cheap, memoized so the per-value encoder
    doesn't pay the import-machinery lookup on every constant (the loose
    hash sits on the compile pipeline's cache-lookup path)."""
    global _RUNTIME
    if _RUNTIME is None:
        import jax
        import jax.numpy as jnp

        from .values import EnvInstance, SymbolicKey

        _RUNTIME = (jax, jnp, EnvInstance, SymbolicKey)
    return _RUNTIME


# ---------------------------------------------------------------------------
# Canonical enumeration
# ---------------------------------------------------------------------------


def _enumerate_family(
    root: Graph, *, loose: bool = False
) -> tuple[list[Graph], list[Node], dict[int, int]]:
    """Deterministic numbering of the closed family below ``root``.

    Graphs are numbered in first-reference order starting from the root;
    nodes get one global post-order numbering (inputs always precede
    users), derived purely from the graphs' structure — never from node
    ids or set iteration — so two processes building the same program
    assign identical indices.

    ``loose=True`` (hash-only mode) additionally enumerates the nodes
    referenced by :class:`SymbolicKey` constants before the constants
    themselves, so a key can be encoded as the canonical index of its
    referent.
    """
    SymbolicKey = _runtime()[3]

    graphs: list[Graph] = []
    gidx: dict[int, int] = {}
    nodes: list[Node] = []
    nidx: dict[int, int] = {}
    deferred_keys: set[int] = set()

    def register_graph(g: Graph) -> None:
        if id(g) in gidx:
            return
        gidx[id(g)] = len(graphs)
        graphs.append(g)
        for p in g.parameters:
            if p._id not in nidx:
                nidx[p._id] = len(nodes)
                nodes.append(p)

    def visit(start: Node) -> None:
        stack: list[tuple[Node, bool]] = [(start, False)]
        while stack:
            n, ready = stack.pop()
            if n._id in nidx:
                continue
            if ready:
                nidx[n._id] = len(nodes)
                nodes.append(n)
                continue
            if isinstance(n, Constant):
                if isinstance(n.value, Graph):
                    register_graph(n.value)
                elif loose and isinstance(n.value, SymbolicKey):
                    ref = n.value.node
                    if ref._id not in nidx:
                        if n._id in deferred_keys:
                            # referent unreachable or cyclic through this
                            # constant: no canonical index exists
                            raise SerializeError(
                                f"symbolic key referent {ref!r} cannot be enumerated"
                            )
                        deferred_keys.add(n._id)
                        stack.append((n, False))
                        stack.append((ref, False))
                        continue
                nidx[n._id] = len(nodes)
                nodes.append(n)
                continue
            if isinstance(n, Parameter):
                if loose and n.graph is not None:
                    # pre-opt closures reference free variables of scopes
                    # not reachable as graph constants; for hashing only,
                    # pull the owning scope into the enumeration (its
                    # structure is part of the program's identity)
                    register_graph(n.graph)
                    continue
                # parameter of an unregistered graph: free variable into a
                # scope outside the family
                raise SerializeError(
                    f"free parameter {n!r} of graph "
                    f"{n.graph.name if n.graph else '?'} is not in the family"
                )
            assert isinstance(n, Apply)
            if loose and n.graph is not None:
                register_graph(n.graph)  # same: keep encode-time gidx total
            stack.append((n, True))
            for inp in reversed(n.inputs):
                if inp._id not in nidx:
                    stack.append((inp, False))

    register_graph(root)
    i = 0
    while i < len(graphs):
        g = graphs[i]
        if g.return_ is None:
            raise SerializeError(f"graph {g.name} has no return node")
        visit(g.return_)
        i += 1
    return graphs, nodes, gidx


# ---------------------------------------------------------------------------
# Value encoding
# ---------------------------------------------------------------------------


def _enc_array(kind: str, arr: np.ndarray) -> dict:
    return {
        "t": kind,
        "dtype": arr.dtype.str,
        "shape": list(arr.shape),
        "data": base64.b64encode(np.ascontiguousarray(arr).tobytes()).decode("ascii"),
    }


def _enc_value(
    v: Any,
    gidx: dict[int, int],
    *,
    nidx: dict[int, int] | None = None,
    loose: bool = False,
) -> Any:
    jax, jnp, EnvInstance, SymbolicKey = _runtime()

    if loose and isinstance(v, SymbolicKey):
        # hash-only: a key is identified by the canonical index of the
        # node it references (enumerated by _enumerate_family in loose
        # mode) — process-stable, never an object id
        i = nidx.get(v.node._id) if nidx is not None else None
        if i is None:
            raise SerializeError(f"symbolic key referent {v.node!r} not in family")
        return {"t": "symkey", "v": i}
    if loose and isinstance(v, EnvInstance):
        if len(v):
            # a populated runtime env is not structure; refuse the key
            raise SerializeError("non-empty gradient environment constant")
        return {"t": "env0"}
    if v is None:
        return {"t": "none"}
    t = type(v)
    if t is bool:
        return {"t": "bool", "v": v}
    if t is int:
        return {"t": "int", "v": v}
    if t is float:
        # repr round-trips exactly, including inf/-inf/nan (json can't)
        return {"t": "float", "v": repr(v)}
    if t is str:
        return {"t": "str", "v": v}
    if t is tuple:
        return {"t": "tuple", "v": [_enc_value(e, gidx, nidx=nidx, loose=loose) for e in v]}
    if isinstance(v, np.dtype):
        return {"t": "dtype", "v": v.str}
    if isinstance(v, type):
        try:
            return {"t": "dtype_cls", "v": np.dtype(v).str}
        except TypeError:
            raise SerializeError(f"cannot serialize type constant {v!r}")
    if isinstance(v, Primitive):
        return {"t": "prim", "v": v.name}
    if isinstance(v, Graph):
        gi = gidx.get(id(v))
        if gi is None:
            raise SerializeError(f"graph constant {v.name} escapes the family")
        return {"t": "graph", "v": gi}
    if isinstance(v, np.generic):
        return _enc_array("npscalar", np.asarray(v))
    if isinstance(v, np.ndarray):
        return _enc_array("np", v)
    if isinstance(v, (jnp.ndarray, jax.Array)):
        if isinstance(v, jax.core.Tracer):
            raise SerializeError("tracer constant cannot be serialized")
        return _enc_array("jax", np.asarray(v))
    raise SerializeError(f"cannot serialize constant of type {type(v).__name__}: {v!r}")


def _dec_prim(name: str) -> Primitive:
    p = PRIMITIVES.get(name)
    if p is None:
        # kernel primitives register on import of repro.kernels.ops
        import repro.kernels.ops  # noqa: F401

        p = PRIMITIVES.get(name)
    if p is None:
        raise SerializeError(f"unknown primitive {name!r} (missing registration?)")
    return p


def _dec_value(e: Any, graphs: list[Graph]) -> Any:
    jnp = _runtime()[1]

    t = e["t"]
    if t == "none":
        return None
    if t in ("bool", "int", "str"):
        return e["v"]
    if t == "float":
        return float(e["v"])
    if t == "tuple":
        return tuple(_dec_value(x, graphs) for x in e["v"])
    if t == "dtype":
        return np.dtype(e["v"])
    if t == "dtype_cls":
        return np.dtype(e["v"]).type
    if t == "prim":
        return _dec_prim(e["v"])
    if t == "graph":
        return graphs[e["v"]]
    if t in ("np", "jax", "npscalar"):
        arr = np.frombuffer(
            base64.b64decode(e["data"]), dtype=np.dtype(e["dtype"])
        ).reshape(tuple(e["shape"]))
        if t == "jax":
            return jnp.asarray(arr)
        if t == "npscalar":
            return arr.reshape(()).copy()[()]
        return arr.copy()
    raise SerializeError(f"unknown value tag {t!r}")


# ---------------------------------------------------------------------------
# Graph <-> payload
# ---------------------------------------------------------------------------


def serialize_graph(root: Graph, *, names: bool = True, loose: bool = False) -> dict:
    """Encode the closed family below ``root`` as a JSON-able dict.

    ``names=False`` strips graph/parameter/node debug names — the form
    :func:`structural_hash` digests, so renames and clone relabels never
    change the hash.  ``loose=True`` admits symbolic-key / empty-env
    constants (pre-optimization adjoint graphs) for hashing only — the
    payload is tagged and :func:`deserialize_graph` rejects it.
    """
    graphs, nodes, gidx = _enumerate_family(root, loose=loose)
    nidx = {n._id: i for i, n in enumerate(nodes)}
    enc_nodes: list[dict] = []
    for n in nodes:
        if isinstance(n, Parameter):
            rec: dict = {"k": "p", "g": gidx[id(n.graph)]}
        elif isinstance(n, Apply):
            if id(n.graph) not in gidx:
                raise SerializeError(
                    f"apply node owned by out-of-family graph {n.graph!r}"
                )
            rec = {"k": "a", "g": gidx[id(n.graph)], "in": [nidx[i._id] for i in n.inputs]}
        else:
            assert isinstance(n, Constant)
            rec = {"k": "c", "v": _enc_value(n.value, gidx, nidx=nidx, loose=loose)}
        if names and n.debug_name:
            rec["n"] = n.debug_name
        enc_nodes.append(rec)
    enc_graphs = []
    for g in graphs:
        enc_graphs.append(
            {
                "name": g.name if names else "",
                "params": [nidx[p._id] for p in g.parameters],
                "ret": nidx[g.return_._id],
            }
        )
    payload = {"version": FORMAT_VERSION, "graphs": enc_graphs, "nodes": enc_nodes}
    if loose:
        payload["loose"] = True
    return payload


def deserialize_graph(payload: dict) -> Graph:
    """Rebuild the root graph (and its family) from :func:`serialize_graph`."""
    if payload.get("version") != FORMAT_VERSION:
        raise SerializeError(
            f"format version mismatch: {payload.get('version')} != {FORMAT_VERSION}"
        )
    if payload.get("loose"):
        raise SerializeError("loose (hash-only) payloads cannot be deserialized")
    graphs = [Graph(e["name"]) for e in payload["graphs"]]
    nodes: list[Node | None] = [None] * len(payload["nodes"])
    # parameters first (graph shells own them)
    for gi, ge in enumerate(payload["graphs"]):
        for pi in ge["params"]:
            rec = payload["nodes"][pi]
            assert rec["k"] == "p" and rec["g"] == gi
            nodes[pi] = graphs[gi].add_parameter(rec.get("n", ""))
    # constants + applies in index order (inputs always have lower indices)
    for i, rec in enumerate(payload["nodes"]):
        if nodes[i] is not None:
            continue
        k = rec["k"]
        if k == "c":
            c = Constant(_dec_value(rec["v"], graphs), rec.get("n", ""))
            nodes[i] = c
        elif k == "a":
            inputs = []
            for j in rec["in"]:
                inp = nodes[j]
                if inp is None:
                    raise SerializeError(f"node {i} references unbuilt input {j}")
                inputs.append(inp)
            nodes[i] = Apply(inputs, graphs[rec["g"]], rec.get("n", ""))
        else:
            raise SerializeError(f"stray parameter record at {i} (not owned by a graph)")
    for g, ge in zip(graphs, payload["graphs"]):
        ret = nodes[ge["ret"]]
        assert ret is not None
        g.set_return(ret)
    return graphs[0]


def dumps(root: Graph, *, names: bool = True, loose: bool = False) -> str:
    """Canonical JSON text of :func:`serialize_graph` (sorted keys, no
    whitespace — byte-stable across processes)."""
    return json.dumps(
        serialize_graph(root, names=names, loose=loose),
        sort_keys=True,
        separators=(",", ":"),
    )


def loads(text: str) -> Graph:
    return deserialize_graph(json.loads(text))


def structural_hash(root: Graph, *, loose: bool = False) -> str:
    """Hex content hash of the name-stripped canonical encoding.

    Stable across process runs and identical for structurally-identical
    graphs — the graph component of the AOT program-cache key.
    ``loose=True`` admits pre-optimization adjoint graphs (symbolic keys,
    empty gradient environments) — the graph component of the
    optimized-graph cache key (``ProgramCache.graph_key``)."""
    return hashlib.sha256(dumps(root, names=False, loose=loose).encode("utf-8")).hexdigest()
