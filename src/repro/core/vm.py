"""Reference interpreter (VM) for the IR.

Demand-driven, explicit-stack evaluation:

* evaluating a graph constant that has free variables yields a
  :class:`Closure <repro.core.values.Closure>` capturing the current frame,
* ``switch`` is strict in its *function* arguments (closure creation is
  cheap) but the **call** of the selected branch is what recurses — so
  recursion guarded by conditionals terminates,
* the work stack lives on the heap: arbitrarily deep recursion (loops are
  tail calls in this IR) cannot blow the Python C stack.

The same evaluator doubles as the JAX backend's *fallback* executor: all
array primitives are implemented with ``jnp``, so ``jax.jit`` can *trace
through* the VM — the interpreter overhead is paid once at trace time, and
XLA compiles the traced straight-line program (our analogue of the paper's
"compile the straight-line parts with TVM").  Optimized first-order graphs
skip the VM entirely: ``repro.core.lowering`` emits them as straight-line
Python callables, and the VM only serves graphs with residual graph values
(recursion, higher-order calls) — see ``docs/pipeline.md``.
"""

from __future__ import annotations

from typing import Any

from .ir import Apply, Constant, Graph, Node
from .primitives import Primitive
from .values import Closure

__all__ = ["VM", "run_graph"]

_MISSING = object()


class Frame:
    __slots__ = ("graph", "parent", "values")

    def __init__(self, graph: Graph, parent: "Frame | None") -> None:
        self.graph = graph
        self.parent = parent
        self.values: dict[int, Any] = {}

    def lookup_frame(self, node: Node) -> "Frame":
        g = node.graph
        f: Frame | None = self
        while f is not None:
            if f.graph is g:
                return f
            f = f.parent
        raise RuntimeError(
            f"free variable {node!r} of graph {g and g.name} not found in frame chain"
        )


class VM:
    """Explicit-stack evaluator."""

    def __init__(self, max_steps: int | None = None) -> None:
        self.max_steps = max_steps

    def call(self, fn: Any, args: tuple) -> Any:
        dest: list[Any] = [_MISSING]
        # task kinds:
        #   ("call", fnval, argvals, dest)
        #   ("eval", node, frame, dest|None)   -> memoize into owning frame
        #   ("apply", node, frame, dest|None)  -> inputs already evaluated
        #   ("store", node, frame, cell)       -> copy cell into frame memo
        tasks: list[tuple] = [("call", fn, tuple(args), dest)]
        steps = 0
        while tasks:
            steps += 1
            if self.max_steps is not None and steps > self.max_steps:
                raise RuntimeError("VM step budget exceeded")
            task = tasks.pop()
            kind = task[0]

            if kind == "call":
                _, fnval, argvals, d = task
                self._do_call(tasks, fnval, argvals, d)

            elif kind == "eval":
                _, node, frame, d = task
                val = self._quick_value(node, frame)
                if val is not _MISSING:
                    if d is not None:
                        d[0] = val
                    continue
                if isinstance(node, Apply):
                    tasks.append(("apply", node, frame, d))
                    owner = frame if node.graph is frame.graph else frame.lookup_frame(node)
                    for inp in node.inputs:
                        # constants need no eval task: _quick_value resolves
                        # them at apply time (also avoids creating every
                        # graph-constant Closure twice)
                        if not isinstance(inp, Constant):
                            tasks.append(("eval", inp, owner, None))
                else:  # pragma: no cover - parameters are always bound
                    raise RuntimeError(f"unbound node {node!r}")

            elif kind == "apply":
                _, node, frame, d = task
                owner = frame if node.graph is frame.graph else frame.lookup_frame(node)
                if node._id in owner.values:
                    if d is not None:
                        d[0] = owner.values[node._id]
                    continue
                vals = []
                for inp in node.inputs:
                    v = self._quick_value(inp, owner)
                    assert v is not _MISSING, f"input {inp!r} not evaluated"
                    vals.append(v)
                fnval, argvals = vals[0], tuple(vals[1:])
                if isinstance(fnval, Primitive):
                    res = fnval.impl(*argvals)
                    owner.values[node._id] = res
                    if d is not None:
                        d[0] = res
                else:
                    cell: list[Any] = [_MISSING]
                    tasks.append(("store", node, owner, cell, d))
                    self._do_call(tasks, fnval, argvals, cell)

            elif kind == "store":
                _, node, frame, cell, d = task
                assert cell[0] is not _MISSING
                frame.values[node._id] = cell[0]
                if d is not None:
                    d[0] = cell[0]

        assert dest[0] is not _MISSING
        return dest[0]

    # -- helpers -------------------------------------------------------------
    def _quick_value(self, node: Node, frame: Frame) -> Any:
        """Value of a node if immediately available (constant / memoized).

        Graph constants *always* capture the current frame: capture is
        cheap, and deciding statically whether a graph needs its defining
        frame is subtle under recursion (a recursive reference to an
        enclosing graph must not sever the chain)."""
        if isinstance(node, Constant):
            v = node.value
            if isinstance(v, Graph):
                return Closure(v, frame)
            return v
        owner = frame if node.graph is frame.graph else frame.lookup_frame(node)
        return owner.values.get(node._id, _MISSING)

    def _do_call(self, tasks: list, fnval: Any, argvals: tuple, dest: list) -> None:
        if isinstance(fnval, Primitive):
            dest[0] = fnval.impl(*argvals)
            return
        if isinstance(fnval, Closure):
            graph, parent = fnval.graph, fnval.frame
        elif isinstance(fnval, Graph):
            graph, parent = fnval, None
        else:
            raise TypeError(f"cannot call value of type {type(fnval).__name__}: {fnval!r}")
        if len(argvals) != len(graph.parameters):
            raise TypeError(
                f"{graph.name} expects {len(graph.parameters)} args, got {len(argvals)}"
            )
        frame = Frame(graph, parent)
        for p, v in zip(graph.parameters, argvals):
            frame.values[p._id] = v
        tasks.append(("eval", graph.return_, frame, dest))


def run_graph(graph: Graph, *args: Any) -> Any:
    return VM().call(graph, tuple(args))
