"""Fusion: partition optimized graphs into kernel-sized regions (paper §4).

PR 1's direct lowering emits *one jnp call per apply node*.  XLA re-fuses
much of that, but the paper's closing argument — ST adjoints become
"amenable to ahead-of-time optimization", and Myia's intended use is
exposing "efficient low-level kernels … as primitives" — asks the
*compiler* to own that decision.  This module is the analysis half of the
fusion subsystem: it walks an optimized, shape-inferred first-order graph
and groups apply nodes into **clusters**, each of which the code generator
(``repro.kernels.codegen``) can emit as one generated Pallas kernel.

Classification (shape information comes from ``infer``'s ``node.abstract``
annotations):

* **elementwise** — add/mul/tanh/… applied at the cluster's body shape;
  computed per block inside the kernel,
* **broadcast**  — ``unreduce`` / ``broadcast_to`` *into* the body shape;
  legal only at the cluster boundary (their input is by construction
  smaller than the body shape, so they prepare kernel operands),
* **reduction**  — ``reduce_sum`` / ``reduce_max`` / ``unbroadcast``;
  legal only as a cluster's *root* (the single output),
* **opaque**     — everything else (matmul, reshape, tuple machinery,
  registered Pallas primitives, …): never fused, always a cluster
  boundary.

Cluster legality (checked during greedy growth, so every produced cluster
is legal by construction):

1. **single output** — only the root's value may be consumed outside the
   cluster: an interior node is absorbed only if *every* user edge points
   at a node already in the cluster (and it is not the graph's return
   node).  Because all paths out of the region then go through the root,
   absorbing a producer can never create a cycle between clusters — a
   cluster input that depended on the root (or on any interior node)
   would imply a cycle in the original DAG.
2. **dominated inputs** — every cluster input is an ancestor of the root,
   so the fused call can be emitted exactly where the root stood in the
   topological order.
3. **shape/dtype compatibility** — every member's output shape equals the
   cluster body shape (elementwise per block); broadcast members' static
   arguments (target shape / axes / keepdims) must be constants; a
   reduction root's axes/keepdims (or target shape) must be constants.

Growth is greedy and maximal: roots are attempted in reverse topological
order (consumers first), so a cluster reaches as far up its operand tree
as legality allows.  Clusters smaller than ``min_cluster_size`` are
discarded — launching a kernel for one or two elementwise ops costs more
than XLA's own fusion — and their nodes remain available as roots for
later (smaller) attempts.
"""

from __future__ import annotations

from typing import Any

from .infer import AArray
from .ir import Apply, Constant, Graph, Node, is_constant_graph, toposort
from .primitives import COLLECTIVE_NAMES as COLLECTIVES, Primitive

__all__ = [
    "ELEMENTWISE",
    "BROADCAST",
    "REDUCTION",
    "COLLECTIVES",
    "DeclineReason",
    "classify",
    "Cluster",
    "FusionPlan",
    "explain_partition",
    "partition_graph",
]

#: primitive names computed pointwise at the body shape
ELEMENTWISE = frozenset({
    "add", "sub", "mul", "div", "power", "integer_pow", "neg",
    "exp", "log", "tanh", "sigmoid", "relu", "sqrt", "rsqrt",
    "sin", "cos", "square", "absolute", "sign", "erf",
    "maximum", "minimum", "where", "cast",
    "lt", "gt", "le", "ge", "eq", "ne",
    "bool_and", "bool_or", "bool_not",
})

#: primitives that broadcast a smaller operand INTO the body shape
BROADCAST = frozenset({"broadcast_to", "unreduce"})

#: primitives that reduce the body shape DOWN to the output shape
REDUCTION = frozenset({"reduce_sum", "reduce_max", "unbroadcast"})


class DeclineReason:
    """Why a node stayed out of every fusion cluster (or a whole cluster
    was declined by codegen): a machine-readable kind + human detail.

    Mirrors :class:`repro.core.closure.FallbackReason` — the explain layer
    (``repro.obs.explain``) reports these as structured reason *objects*,
    never bare strings, so downstream tooling can pivot on ``kind``."""

    #: non-primitive call / no array abstract: nothing to fuse
    NOT_ARRAY = "no-array-abstract"
    #: an SPMD collective: a cluster must never span a resharding point
    COLLECTIVE = "collective-boundary"
    #: an opaque primitive (matmul, reshape, tuple machinery, …)
    OPAQUE = "opaque-primitive"
    #: broadcast/reduction static config (shape/axes) is not constant
    NON_CONST_STATIC = "non-constant-static-args"
    #: the legal region around this node is under min_cluster_size
    TOO_SMALL = "region-too-small"
    #: an interior value is consumed outside the region (2nd output needed)
    ESCAPES = "value-escapes-region"
    #: rank-0 / empty body: no kernel to win
    EMPTY_BODY = "empty-or-scalar-body"
    #: a neighboring cluster (grown from a later consumer) claimed the region
    CLAIMED = "claimed-by-neighbor"
    #: the partitioner clustered it but codegen could not express it
    CODEGEN = "codegen-declined"

    __slots__ = ("kind", "detail")

    def __init__(self, kind: str, detail: str) -> None:
        self.kind = kind
        self.detail = detail

    def __str__(self) -> str:
        return f"[{self.kind}] {self.detail}"

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"DeclineReason({self.kind!r}, {self.detail!r})"

    def as_dict(self) -> dict:
        return {"kind": self.kind, "detail": self.detail}


def _prim_of(node: Node) -> Primitive | None:
    if not isinstance(node, Apply):
        return None
    fn = node.fn
    if isinstance(fn, Constant) and isinstance(fn.value, Primitive):
        return fn.value
    return None


def _shape_of(node: Node) -> tuple[int, ...] | None:
    """Array shape from the inferred abstract; None if not an array (or
    the inferencer never annotated the node)."""
    ab = node.abstract
    if isinstance(ab, AArray):
        return ab.shape
    return None


def _dtype_of(node: Node) -> Any:
    ab = node.abstract
    return ab.dtype if isinstance(ab, AArray) else None


def classify(node: Node) -> str:
    """One of ``"elementwise" | "broadcast" | "reduction" | "opaque"``.

    Classification is *shape-aware*: an elementwise primitive only counts
    as such when the node actually produced an array (scalar arithmetic on
    loop counters stays opaque), and broadcast/reduction require their
    static arguments (shape / axes / keepdims) to be constants.

    SPMD collectives (``psum_axes`` & co., inserted by ``repro.core.spmd``
    at resharding points) are opaque *by fiat*, not by omission: a fusion
    cluster must never span a resharding point — the values on either
    side live at different shardings, so a single kernel body cannot
    compute across one.
    """
    p = _prim_of(node)
    if p is not None and p.name in COLLECTIVES:
        return "opaque"
    if p is None or _shape_of(node) is None and p.name not in REDUCTION:
        return "opaque"
    if p.name in ELEMENTWISE:
        return "elementwise"
    if p.name in BROADCAST:
        return "broadcast" if _static_args_const(node) else "opaque"
    if p.name in REDUCTION:
        return "reduction" if _static_args_const(node) else "opaque"
    return "opaque"


def _static_args_const(node: Apply) -> bool:
    """broadcast/reduction prims carry static config after the data arg:
    ``broadcast_to(x, shp)``, ``unreduce(x, shp, axes, keepdims)``,
    ``reduce_sum(x, axes, keepdims)``, ``unbroadcast(x, shp)`` — all of it
    must be constant for codegen to bake it into the kernel."""
    return all(isinstance(a, Constant) for a in node.args[1:])


class Cluster:
    """A legal fusion region: ``order`` (members, producers first) feeding
    the single-output ``root``; ``inputs`` are the external value edges in
    first-use order (constants excluded — codegen embeds those)."""

    __slots__ = ("root", "members", "order", "inputs", "kind", "body_shape")

    def __init__(
        self,
        root: Apply,
        order: list[Apply],
        inputs: list[Node],
        kind: str,
        body_shape: tuple[int, ...],
    ) -> None:
        self.root = root
        self.members = {n._id for n in order}
        self.order = order
        self.inputs = inputs
        self.kind = kind  # "map" (elementwise root) | "reduce" (reduction root)
        self.body_shape = body_shape

    @property
    def out_shape(self) -> tuple[int, ...]:
        return _shape_of(self.root) or ()

    @property
    def out_dtype(self):
        return _dtype_of(self.root)

    def __len__(self) -> int:
        return len(self.order)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        prims = "+".join(n.fn.value.name for n in self.order)
        return f"<Cluster {self.kind} {list(self.body_shape)} {prims}>"


class FusionPlan:
    """All clusters of one graph + the launch accounting the benchmarks
    report (``launches_before``: apply nodes in the unfused lowering;
    ``launches_after``: unfused applies + one call per cluster)."""

    __slots__ = ("graph", "clusters", "n_applies")

    def __init__(self, graph: Graph, clusters: list[Cluster], n_applies: int) -> None:
        self.graph = graph
        self.clusters = clusters
        self.n_applies = n_applies

    def cluster_of(self, node: Node) -> Cluster | None:
        for c in self.clusters:
            if node._id in c.members:
                return c
        return None

    @property
    def fused_nodes(self) -> int:
        return sum(len(c) for c in self.clusters)

    @property
    def launches_before(self) -> int:
        return self.n_applies

    @property
    def launches_after(self) -> int:
        return self.n_applies - self.fused_nodes + len(self.clusters)

    @property
    def nodes_per_cluster(self) -> float:
        return self.fused_nodes / len(self.clusters) if self.clusters else 0.0

    def stats(self) -> dict:
        return {
            "n_clusters": len(self.clusters),
            "fused_nodes": self.fused_nodes,
            "launches_before": self.launches_before,
            "launches_after": self.launches_after,
            "nodes_per_cluster": round(self.nodes_per_cluster, 2),
            "cluster_sizes": sorted((len(c) for c in self.clusters), reverse=True),
        }


def _grow(
    graph: Graph, root: Apply, assigned: set[int], live: set[int]
) -> list[Apply] | None:
    """Greedy maximal growth from ``root``; returns members in discovery
    order (consumers first) or None if the root itself is ineligible."""
    kind = classify(root)
    if kind == "reduction":
        body = root.args[0]
        body_shape = _shape_of(body)
        if body_shape is None:
            return None
    elif kind in ("elementwise", "broadcast"):
        body_shape = _shape_of(root)
    else:
        return None
    if not body_shape or any(d <= 0 for d in body_shape):
        return None  # rank-0 / empty bodies: no kernel to win (codegen declines)

    members: set[int] = {root._id}
    order = [root]
    # broadcast members are boundaries: their (smaller) data input is a
    # kernel operand prepared by the wrapper, so growth stops behind them
    work = list(root.args[:1]) if kind == "reduction" else (
        [] if kind == "broadcast" else list(root.args)
    )
    while work:
        p = work.pop()
        if not isinstance(p, Apply) or p._id in members or p._id in assigned:
            continue
        if p is graph.return_:
            continue  # the return value must stay materialized
        cls = classify(p)
        if cls not in ("elementwise", "broadcast"):
            continue  # reductions are root-only; opaque never fuses
        if _shape_of(p) != body_shape:
            continue  # operand at another shape: stays a cluster input
        # single-output check over LIVE users only: the optimizer's rewrites
        # can leave stale user edges from orphaned (unreachable) nodes, and
        # those must not pin a value as "escaping"
        if not all(u._id in members for (u, _i) in p.users if u._id in live):
            continue  # value escapes the region: fusing would need a 2nd output
        members.add(p._id)
        order.append(p)
        if cls == "elementwise":
            work.extend(p.args)
    return order


def _collect_inputs(order: list[Apply], members: set[int]) -> list[Node]:
    seen: set[int] = set()
    inputs: list[Node] = []
    for n in order:  # producers first: stable, dominance-ordered
        for a in n.args:
            if a._id in members or a._id in seen:
                continue
            if isinstance(a, Constant) and not is_constant_graph(a):
                continue  # embedded by codegen (literal or closure-bound)
            seen.add(a._id)
            inputs.append(a)
    return inputs


def partition_graph(graph: Graph, *, min_cluster_size: int = 3) -> FusionPlan:
    """Partition ``graph`` (optimized + inferred, first-order) into fusion
    clusters.  Nodes without array abstracts, opaque primitives and
    too-small regions are simply left out — the lowering keeps emitting
    them as individual jnp calls, so partitioning never fails.
    """
    from repro.obs import trace as obs_trace

    sp = obs_trace.span("fuse.partition", graph=graph.name)
    with sp:
        plan = _partition_graph_body(graph, min_cluster_size)
        sp.set(n_applies=plan.n_applies, clusters=len(plan.clusters))
    return plan


def classify_reason(node: Node) -> DeclineReason | None:
    """The structured reason :func:`classify` returned ``"opaque"`` for
    ``node``, or None when the node is fusible (elementwise / broadcast /
    reduction)."""
    p = _prim_of(node)
    if p is None:
        return DeclineReason(
            DeclineReason.NOT_ARRAY, "callee is not a constant primitive"
        )
    if p.name in COLLECTIVES:
        return DeclineReason(
            DeclineReason.COLLECTIVE,
            f"{p.name} marks a resharding point; clusters never span one",
        )
    if _shape_of(node) is None and p.name not in REDUCTION:
        return DeclineReason(
            DeclineReason.NOT_ARRAY,
            f"{p.name} produced no array abstract (scalar or uninferred)",
        )
    if p.name in (BROADCAST | REDUCTION) and not _static_args_const(node):
        return DeclineReason(
            DeclineReason.NON_CONST_STATIC,
            f"{p.name} static config (shape/axes/keepdims) is not constant",
        )
    if classify(node) == "opaque":
        return DeclineReason(
            DeclineReason.OPAQUE, f"{p.name} has no elementwise kernel body"
        )
    return None


def explain_partition(
    graph: Graph, *, min_cluster_size: int = 3
) -> tuple[FusionPlan, dict[int, DeclineReason]]:
    """Partition ``graph`` AND explain every un-clustered apply node.

    Returns ``(plan, declines)`` where ``declines`` maps node ``_id`` →
    :class:`DeclineReason` for every apply the partitioner left out.  The
    reasons re-run the same legality checks the partitioner used, against
    the final assignment, so "too small" / "escapes" verdicts reflect the
    regions that actually formed."""
    plan = partition_graph(graph, min_cluster_size=min_cluster_size)
    assigned: set[int] = set()
    for c in plan.clusters:
        assigned |= c.members
    topo = [n for n in toposort(graph) if isinstance(n, Apply)]
    live = {n._id for n in topo}
    declines: dict[int, DeclineReason] = {}
    for node in topo:
        if node._id in assigned:
            continue
        reason = classify_reason(node)
        if reason is not None:
            declines[node._id] = reason
            continue
        # fusible class, yet unfused: replay growth against the final
        # assignment to see what held the region back
        grown = _grow(graph, node, assigned, live)
        if grown is None:
            declines[node._id] = DeclineReason(
                DeclineReason.EMPTY_BODY,
                "body shape is rank-0/empty; no kernel to win",
            )
        elif len(grown) < min_cluster_size:
            neighbors = any(
                u._id in assigned for (u, _i) in node.users if u._id in live
            ) or any(
                isinstance(a, Apply) and a._id in assigned for a in node.args
            )
            n_users = len({u._id for (u, _i) in node.users if u._id in live})
            if neighbors:
                declines[node._id] = DeclineReason(
                    DeclineReason.CLAIMED,
                    f"legal region is {len(grown)} node(s) < min "
                    f"{min_cluster_size}; adjacent values already belong to "
                    "an emitted cluster",
                )
            elif n_users > 1:
                declines[node._id] = DeclineReason(
                    DeclineReason.ESCAPES,
                    f"value feeds {n_users} consumers; absorbing it would "
                    "need a second cluster output",
                )
            else:
                declines[node._id] = DeclineReason(
                    DeclineReason.TOO_SMALL,
                    f"legal region is {len(grown)} node(s), below "
                    f"min_cluster_size={min_cluster_size}",
                )
        else:
            declines[node._id] = DeclineReason(
                DeclineReason.CLAIMED,
                f"a {len(grown)}-node region is legal here but its nodes "
                "were claimed by a cluster grown from a later consumer",
            )
    return plan, declines


def _partition_graph_body(graph: Graph, min_cluster_size: int) -> FusionPlan:
    topo = [n for n in toposort(graph) if isinstance(n, Apply)]
    topo_index = {n._id: i for i, n in enumerate(topo)}
    live = set(topo_index)
    assigned: set[int] = set()
    clusters: list[Cluster] = []
    for root in reversed(topo):  # consumers first → maximal regions
        if root._id in assigned:
            continue
        grown = _grow(graph, root, assigned, live)
        if grown is None or len(grown) < min_cluster_size:
            continue
        order = sorted(grown, key=lambda n: topo_index[n._id])  # producers first
        kind = "reduce" if classify(root) == "reduction" else "map"
        members = {n._id for n in order}
        body_shape = (
            _shape_of(root.args[0]) if kind == "reduce" else _shape_of(root)
        )
        clusters.append(
            Cluster(root, order, _collect_inputs(order, members), kind, body_shape)
        )
        assigned |= members
    clusters.sort(key=lambda c: topo_index[c.root._id])
    return FusionPlan(graph, clusters, len(topo))
