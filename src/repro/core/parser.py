"""Python-subset frontend (paper §4.1).

Parses a *pure* subset of Python into the graph IR:

* functions (including nested defs and lambdas — closures come for free
  from the free-variable representation), recursion,
* ``if``/``while``/``for i in range(...)`` — converted to the functional
  form: each basic block is a graph, jumps are tail calls, conditionals are
  ``switch(cond, true_graph, false_graph)()``,
* tuples, arithmetic/comparison/boolean operators, calls.

Mutating statements (``x[i] = v``, ``x += y``) are **forbidden**, exactly as
in the paper ("We currently forbid these statements in Myia").
"""

from __future__ import annotations

import ast
import inspect
import textwrap
import types
from typing import Any, Callable

from . import primitives as P
from .ir import Constant, Graph, Node
from .primitives import Primitive

__all__ = ["parse_function", "MyiaSyntaxError", "macro"]


class MyiaSyntaxError(Exception):
    pass


_PARSE_CACHE: dict[Any, Graph] = {}


def macro(expand: Callable) -> Callable:
    """Decorator factory: mark a callable as a parse-time macro.  The parser
    calls ``fn.__myia_macro_expand__(parser, block, ast_args)``."""

    def mark(fn: Callable) -> Callable:
        fn.__is_myia_macro__ = True
        fn.__myia_macro_expand__ = expand
        return fn

    return mark


_BINOPS = {
    ast.Add: P.add,
    ast.Sub: P.sub,
    ast.Mult: P.mul,
    ast.Div: P.div,
    ast.Pow: P.power,
    ast.FloorDiv: P.floordiv,
    ast.Mod: P.mod,
    ast.MatMult: P.matmul,
}

_CMPOPS = {
    ast.Lt: P.lt,
    ast.Gt: P.gt,
    ast.LtE: P.le,
    ast.GtE: P.ge,
    ast.Eq: P.eq,
    ast.NotEq: P.ne,
}

_ATTRS = {
    "T": P.mT,
    "mT": P.mT,
    "shape": P.shape,
    "dtype": P.dtype_of,
}

_BUILTINS: dict[str, Primitive] = {
    "len": P.tuple_len,
    "abs": P.absolute,
    "max": P.maximum,
    "min": P.minimum,
}


def _assigned_names(stmts: list[ast.stmt]) -> list[str]:
    """Names (syntactically) assigned anywhere in a suite, in first-seen
    order — these become the parameters of continuation/loop blocks."""
    out: list[str] = []

    def add(name: str) -> None:
        if name not in out:
            out.append(name)

    def visit_target(t: ast.expr) -> None:
        if isinstance(t, ast.Name):
            add(t.id)
        elif isinstance(t, ast.Tuple):
            for e in t.elts:
                visit_target(e)

    def visit(s: ast.stmt) -> None:
        if isinstance(s, ast.Assign):
            for t in s.targets:
                visit_target(t)
        elif isinstance(s, ast.AnnAssign) and isinstance(s.target, ast.Name):
            add(s.target.id)
        elif isinstance(s, ast.FunctionDef):
            add(s.name)
        elif isinstance(s, ast.If):
            for b in (*s.body, *s.orelse):
                visit(b)
        elif isinstance(s, ast.While):
            for b in (*s.body, *s.orelse):
                visit(b)
        elif isinstance(s, ast.For):
            visit_target(s.target)
            for b in (*s.body, *s.orelse):
                visit(b)

    for s in stmts:
        visit(s)
    return out


class Block:
    """A basic block: a graph plus local name bindings and a lexical parent."""

    __slots__ = ("graph", "bindings", "parent", "parser")

    def __init__(self, graph: Graph, parent: "Block | None", parser: "Parser") -> None:
        self.graph = graph
        self.bindings: dict[str, Node] = {}
        self.parent = parent
        self.parser = parser

    def bind(self, name: str, node: Node) -> None:
        self.bindings[name] = node

    def read(self, name: str) -> Node:
        blk: Block | None = self
        while blk is not None:
            if name in blk.bindings:
                return blk.bindings[name]
            blk = blk.parent
        return self.parser.resolve_global(name)


class _LoopCtx:
    __slots__ = ("incr_graph", "loop_names", "after_const")

    def __init__(self, incr_graph: Graph, loop_names: list[str], after_const: Constant):
        #: graph to tail-call on `continue` (header for while, incr for for)
        self.incr_graph = incr_graph
        self.loop_names = loop_names
        self.after_const = after_const


#: continuation spec: (graph_to_jump_to, names_passed_as_args) or None
#: (None means: falling off the end returns None from the function)
Cont = "tuple[Graph, list[str]] | None"


class Parser:
    def __init__(self, fn: Callable) -> None:
        self.fn = fn
        self.globals = getattr(fn, "__globals__", {})
        self.closure_vars: dict[str, Any] = {}
        if getattr(fn, "__closure__", None):
            for name, cell in zip(fn.__code__.co_freevars, fn.__closure__):
                try:
                    self.closure_vars[name] = cell.cell_contents
                except ValueError:
                    pass
        self.loop_stack: list[_LoopCtx] = []

    # -- name resolution ---------------------------------------------------
    def resolve_global(self, name: str) -> Node:
        if name in self.closure_vars:
            return self.value_to_node(self.closure_vars[name], name)
        if name in self.globals:
            return self.value_to_node(self.globals[name], name)
        if name in _BUILTINS:
            return Constant(_BUILTINS[name], name)
        raise MyiaSyntaxError(f"name {name!r} is not defined in the Myia subset")

    def value_to_node(self, value: Any, name: str = "") -> Node:
        if isinstance(value, (Primitive, Graph)):
            return Constant(value, name)
        factory = getattr(value, "__myia_graph_factory__", None)
        if factory is not None:  # @myia-decorated function
            return Constant(factory(), name)
        if isinstance(value, types.FunctionType) and not getattr(
            value, "__is_myia_macro__", False
        ):
            return Constant(parse_function(value), name)
        return Constant(value, name)

    # -- entry ---------------------------------------------------------------
    def parse(self, target: Graph | None = None) -> Graph:
        src = textwrap.dedent(inspect.getsource(self.fn))
        tree = ast.parse(src)
        fndef = tree.body[0]
        if not isinstance(fndef, ast.FunctionDef):
            raise MyiaSyntaxError("expected a function definition")
        module_block = Block(Graph("__module__"), None, self)
        return self.process_function(fndef, module_block, graph=target)

    # -- functions -----------------------------------------------------------
    def process_function(
        self,
        node: ast.FunctionDef | ast.Lambda,
        parent: Block | None,
        graph: Graph | None = None,
    ) -> Graph:
        name = getattr(node, "name", "<lambda>")
        g = graph if graph is not None else Graph(name)
        args = node.args
        if args.vararg or args.kwarg or args.kwonlyargs or args.defaults or args.posonlyargs:
            raise MyiaSyntaxError(f"{name}: only plain positional parameters are supported")
        block = Block(g, parent, self)
        # direct recursion by name
        block.bind(name, Constant(g, name))
        for a in args.args:
            p = g.add_parameter(a.arg)
            block.bind(a.arg, p)
        if isinstance(node, ast.Lambda):
            g.set_return(self.expr(block, node.body))
        else:
            self.process_stmts(block, list(node.body), None)
        return g

    def make_thunk(self, block: Block, expr: ast.expr, name: str) -> Graph:
        """A zero-arg nested graph evaluating ``expr`` (for lazy branches)."""
        g = Graph(name)
        b = Block(g, block, self)
        g.set_return(self.expr(b, expr))
        return g

    # -- statements ------------------------------------------------------------
    def process_stmts(self, block: Block, stmts: list[ast.stmt], cont) -> None:
        """Process a suite inside ``block``.  ``cont`` is the fall-through
        continuation ``(graph, arg_names)`` or None (end of function)."""
        while True:
            if not stmts:
                self._fall_through(block, cont)
                return
            s = stmts[0]
            rest = stmts[1:]
            if isinstance(s, ast.FunctionDef):
                # Hoist a run of consecutive defs: bind all names first so
                # sibling functions can recurse mutually.
                defs = [s]
                while rest and isinstance(rest[0], ast.FunctionDef):
                    defs.append(rest[0])
                    rest = rest[1:]
                graphs = [Graph(d.name) for d in defs]
                for d, dg in zip(defs, graphs):
                    block.bind(d.name, Constant(dg, d.name))
                for d, dg in zip(defs, graphs):
                    self.process_function(d, block, graph=dg)
                stmts = rest
                continue
            if isinstance(s, ast.Return):
                val = self.expr(block, s.value) if s.value is not None else Constant(None)
                block.graph.set_return(val)
                return
            if isinstance(s, ast.If):
                self._process_if(block, s, rest, cont)
                return
            if isinstance(s, ast.While):
                self._process_while(block, s, rest, cont)
                return
            if isinstance(s, ast.For):
                self._process_for(block, s, rest, cont)
                return
            if isinstance(s, ast.Break):
                ctx = self._loop_ctx()
                block.graph.set_return(block.graph.apply(ctx.after_const))
                return
            if isinstance(s, ast.Continue):
                ctx = self._loop_ctx()
                args = [block.read(n) for n in ctx.loop_names]
                block.graph.set_return(block.graph.apply(Constant(ctx.incr_graph), *args))
                return
            self._process_simple(block, s)
            stmts = rest

    def _fall_through(self, block: Block, cont) -> None:
        if block.graph.return_ is not None:
            return
        if cont is None:
            block.graph.set_return(Constant(None))
        else:
            cont_g, names = cont
            args = [self._read_or_none(block, n) for n in names]
            block.graph.set_return(block.graph.apply(Constant(cont_g), *args))

    def _loop_ctx(self) -> _LoopCtx:
        if not self.loop_stack:
            raise MyiaSyntaxError("break/continue outside loop")
        return self.loop_stack[-1]

    def _read_or_none(self, block: Block, name: str) -> Node:
        try:
            return block.read(name)
        except MyiaSyntaxError:
            return Constant(None)

    def _process_simple(self, block: Block, s: ast.stmt) -> None:
        if isinstance(s, ast.Assign):
            if len(s.targets) != 1:
                raise MyiaSyntaxError("chained assignment is not supported")
            target = s.targets[0]
            if isinstance(target, (ast.Subscript, ast.Attribute)):
                raise MyiaSyntaxError(
                    "mutating assignment (x[i] = v / x.a = v) is forbidden in the "
                    "pure Myia subset (paper §4.1)"
                )
            val = self.expr(block, s.value)
            self._bind_target(block, target, val)
        elif isinstance(s, ast.AnnAssign):
            if s.value is not None and isinstance(s.target, ast.Name):
                block.bind(s.target.id, self.expr(block, s.value))
        elif isinstance(s, ast.AugAssign):
            raise MyiaSyntaxError(
                "augmented assignment (x += y) is forbidden in the pure Myia "
                "subset (paper §4.1); write x = x + y"
            )
        elif isinstance(s, ast.Expr):
            if isinstance(s.value, ast.Constant) and isinstance(s.value.value, str):
                return  # docstring
            raise MyiaSyntaxError("expression statements have no effect in a pure language")
        elif isinstance(s, ast.Pass):
            return
        else:
            raise MyiaSyntaxError(f"unsupported statement: {type(s).__name__}")

    def _bind_target(self, block: Block, target: ast.expr, val: Node) -> None:
        if isinstance(target, ast.Name):
            val.debug_name = val.debug_name or target.id
            block.bind(target.id, val)
        elif isinstance(target, ast.Tuple):
            for i, elt in enumerate(target.elts):
                if isinstance(elt, ast.Starred):
                    raise MyiaSyntaxError("starred unpacking is not supported")
                item = block.graph.apply(P.tuple_getitem, val, Constant(i))
                self._bind_target(block, elt, item)
        else:
            raise MyiaSyntaxError(f"unsupported assignment target: {type(target).__name__}")

    # -- control flow -------------------------------------------------------
    def _process_if(self, block: Block, s: ast.If, rest: list[ast.stmt], cont) -> None:
        cond = self.expr(block, s.test)
        assigned = _assigned_names([*s.body, *s.orelse])
        after = Graph(f"{block.graph.name}:after_if")
        ablock = Block(after, block, self)
        for n in assigned:
            ablock.bind(n, after.add_parameter(n))

        tb = Graph(f"{block.graph.name}:if_true")
        self.process_stmts(Block(tb, block, self), list(s.body), (after, assigned))
        fb = Graph(f"{block.graph.name}:if_false")
        self.process_stmts(Block(fb, block, self), list(s.orelse), (after, assigned))

        sel = block.graph.apply(P.switch, cond, Constant(tb), Constant(fb))
        block.graph.set_return(block.graph.apply(sel))
        self.process_stmts(ablock, rest, cont)

    def _process_while(self, block: Block, s: ast.While, rest: list[ast.stmt], cont) -> None:
        if s.orelse:
            raise MyiaSyntaxError("while/else is not supported")
        loop_names = _assigned_names(s.body)
        header = Graph(f"{block.graph.name}:while_header")
        hblock = Block(header, block, self)
        for n in loop_names:
            hblock.bind(n, header.add_parameter(n))

        # enter the loop
        entry_args = [self._read_or_none(block, n) for n in loop_names]
        block.graph.set_return(block.graph.apply(Constant(header), *entry_args))

        cond = self.expr(hblock, s.test)
        after = Graph(f"{block.graph.name}:after_while")
        ablock = Block(after, hblock, self)

        body_g = Graph(f"{block.graph.name}:while_body")
        self.loop_stack.append(_LoopCtx(header, loop_names, Constant(after)))
        try:
            # body falls through -> loop back to header
            self.process_stmts(Block(body_g, hblock, self), list(s.body), (header, loop_names))
        finally:
            self.loop_stack.pop()
        sel = header.apply(P.switch, cond, Constant(body_g), Constant(after))
        header.set_return(header.apply(sel))

        self.process_stmts(ablock, rest, cont)

    def _process_for(self, block: Block, s: ast.For, rest: list[ast.stmt], cont) -> None:
        if s.orelse:
            raise MyiaSyntaxError("for/else is not supported")
        if not (
            isinstance(s.iter, ast.Call)
            and isinstance(s.iter.func, ast.Name)
            and s.iter.func.id == "range"
        ):
            raise MyiaSyntaxError("only `for i in range(...)` loops are supported")
        if not isinstance(s.target, ast.Name):
            raise MyiaSyntaxError("for loop target must be a simple name")
        ivar = s.target.id
        rargs = [self.expr(block, a) for a in s.iter.args]
        if len(rargs) == 1:
            start, stop, step = Constant(0), rargs[0], Constant(1)
        elif len(rargs) == 2:
            start, stop, step = rargs[0], rargs[1], Constant(1)
        elif len(rargs) == 3:
            start, stop, step = rargs
        else:
            raise MyiaSyntaxError("range() takes 1-3 arguments")

        body_names = _assigned_names(s.body)
        loop_names = [ivar] + [n for n in body_names if n != ivar]
        header = Graph(f"{block.graph.name}:for_header")
        hblock = Block(header, block, self)
        for n in loop_names:
            hblock.bind(n, header.add_parameter(n))

        entry_args = [start] + [self._read_or_none(block, n) for n in loop_names[1:]]
        block.graph.set_return(block.graph.apply(Constant(header), *entry_args))

        i_node = hblock.read(ivar)
        if isinstance(step, Constant) and isinstance(step.value, int) and step.value < 0:
            cond = header.apply(P.gt, i_node, stop)
        else:
            cond = header.apply(P.lt, i_node, stop)

        after = Graph(f"{block.graph.name}:after_for")
        ablock = Block(after, hblock, self)

        # `incr` shim: bump the induction variable, jump back to the header
        incr = Graph(f"{block.graph.name}:for_incr")
        inc_params = [incr.add_parameter(n) for n in loop_names]
        next_i = incr.apply(P.add, inc_params[0], step)
        incr.set_return(incr.apply(Constant(header), next_i, *inc_params[1:]))

        body_g = Graph(f"{block.graph.name}:for_body")
        self.loop_stack.append(_LoopCtx(incr, loop_names, Constant(after)))
        try:
            self.process_stmts(Block(body_g, hblock, self), list(s.body), (incr, loop_names))
        finally:
            self.loop_stack.pop()
        sel = header.apply(P.switch, cond, Constant(body_g), Constant(after))
        header.set_return(header.apply(sel))

        self.process_stmts(ablock, rest, cont)

    # -- expressions -----------------------------------------------------------
    def expr(self, block: Block, e: ast.expr) -> Node:
        g = block.graph
        if isinstance(e, ast.Constant):
            if isinstance(e.value, (int, float, bool, str)) or e.value is None:
                return Constant(e.value)
            raise MyiaSyntaxError(f"unsupported constant: {e.value!r}")
        if isinstance(e, ast.Name):
            return block.read(e.id)
        if isinstance(e, ast.BinOp):
            # x ** <int literal> → integer_pow: its backpropagator has no
            # log term, so it is NaN-safe for negative bases (like jax)
            if (
                isinstance(e.op, ast.Pow)
                and isinstance(e.right, ast.Constant)
                and isinstance(e.right.value, int)
                and not isinstance(e.right.value, bool)
            ):
                return g.apply(P.integer_pow, self.expr(block, e.left), Constant(e.right.value))
            op = _BINOPS.get(type(e.op))
            if op is None:
                raise MyiaSyntaxError(f"unsupported operator: {type(e.op).__name__}")
            return g.apply(op, self.expr(block, e.left), self.expr(block, e.right))
        if isinstance(e, ast.UnaryOp):
            if isinstance(e.op, ast.USub):
                return g.apply(P.neg, self.expr(block, e.operand))
            if isinstance(e.op, ast.UAdd):
                return self.expr(block, e.operand)
            if isinstance(e.op, ast.Not):
                return g.apply(P.bool_not, self.expr(block, e.operand))
            raise MyiaSyntaxError(f"unsupported unary op: {type(e.op).__name__}")
        if isinstance(e, ast.Compare):
            left = self.expr(block, e.left)
            result = None
            for op, comparator in zip(e.ops, e.comparators):
                prim = _CMPOPS.get(type(op))
                if prim is None:
                    raise MyiaSyntaxError(f"unsupported comparison: {type(op).__name__}")
                right = self.expr(block, comparator)
                c = g.apply(prim, left, right)
                result = c if result is None else g.apply(P.bool_and, result, c)
                left = right
            return result
        if isinstance(e, ast.BoolOp):
            # short-circuit via switch over thunks (lazy rhs)
            node = self.expr(block, e.values[0])
            for v in e.values[1:]:
                rhs = self.make_thunk(block, v, "bool_rhs")
                keep = Graph("bool_lhs")
                keep.set_return(node)
                if isinstance(e.op, ast.And):
                    sel = g.apply(P.switch, node, Constant(rhs), Constant(keep))
                else:
                    sel = g.apply(P.switch, node, Constant(keep), Constant(rhs))
                node = g.apply(sel)
            return node
        if isinstance(e, ast.IfExp):
            cond = self.expr(block, e.test)
            t = self.make_thunk(block, e.body, "ifexp_true")
            f = self.make_thunk(block, e.orelse, "ifexp_false")
            sel = g.apply(P.switch, cond, Constant(t), Constant(f))
            return g.apply(sel)
        if isinstance(e, ast.Call):
            return self._process_call(block, e)
        if isinstance(e, ast.Tuple):
            return g.apply(P.make_tuple, *[self.expr(block, x) for x in e.elts])
        if isinstance(e, ast.Subscript):
            val = self.expr(block, e.value)
            if isinstance(e.slice, ast.Slice):
                raise MyiaSyntaxError("slicing is not supported; use slice_axis()")
            idx = self.expr(block, e.slice)
            return g.apply(P.tuple_getitem, val, idx)
        if isinstance(e, ast.Attribute):
            if isinstance(e.value, ast.Name):
                # module attribute access (np.float32, jnp.float32, ...)
                try:
                    base_val = None
                    if e.value.id in self.closure_vars:
                        base_val = self.closure_vars[e.value.id]
                    elif e.value.id in self.globals:
                        base_val = self.globals[e.value.id]
                    if isinstance(base_val, types.ModuleType):
                        return self.value_to_node(getattr(base_val, e.attr), e.attr)
                except AttributeError:
                    pass
            base = self.expr(block, e.value)
            if e.attr in _ATTRS:
                return g.apply(_ATTRS[e.attr], base)
            if e.attr == "ndim":
                return g.apply(P.tuple_len, g.apply(P.shape, base))
            raise MyiaSyntaxError(f"unsupported attribute: .{e.attr}")
        if isinstance(e, ast.Lambda):
            return Constant(self.process_function(e, block))
        raise MyiaSyntaxError(f"unsupported expression: {type(e).__name__}")

    def _static_value(self, e: ast.expr) -> tuple[bool, Any]:
        """Resolve an expression to a Python value at parse time if it is a
        plain global/closure name or a module attribute chain."""
        if isinstance(e, ast.Name):
            if e.id in self.closure_vars:
                return True, self.closure_vars[e.id]
            if e.id in self.globals:
                return True, self.globals[e.id]
            return False, None
        if isinstance(e, ast.Attribute):
            ok, base = self._static_value(e.value)
            if ok and isinstance(base, types.ModuleType) and hasattr(base, e.attr):
                return True, getattr(base, e.attr)
            return False, None
        return False, None

    def _process_call(self, block: Block, e: ast.Call) -> Node:
        if e.keywords:
            raise MyiaSyntaxError("keyword arguments are not supported")
        for a in e.args:
            if isinstance(a, ast.Starred):
                raise MyiaSyntaxError("star-args are not supported")
        # macro expansion (e.g. grad) — parse-time, per paper Fig. 1
        ok, val = self._static_value(e.func)
        if ok and getattr(val, "__is_myia_macro__", False):
            return val.__myia_macro_expand__(self, block, e.args)
        fn = self.expr(block, e.func)
        args = [self.expr(block, a) for a in e.args]
        return block.graph.apply(fn, *args)


def parse_function(fn: Callable) -> Graph:
    """Parse a Python function into the IR (cached by function object).

    The shell graph is registered in the cache BEFORE parsing the body, so
    module-level mutual recursion (f referencing g referencing f through
    their globals) resolves to the in-progress graph instead of looping."""
    from repro.obs import trace as obs_trace

    key = getattr(fn, "__wrapped__", fn)
    if key in _PARSE_CACHE:
        return _PARSE_CACHE[key]
    g = Graph(getattr(key, "__name__", "<fn>"))
    _PARSE_CACHE[key] = g
    try:
        with obs_trace.span("parse", fn=g.name):
            Parser(key).parse(target=g)
    except BaseException:
        _PARSE_CACHE.pop(key, None)  # don't cache a half-parsed shell
        raise
    return g
