"""Primitive operations of the IR.

Every primitive carries
* ``impl``   — the runtime implementation (jnp, with Python-scalar fast
  paths so that loop counters stay concrete and control flow can unroll),
* ``bprop``  — its *backpropagator definition*: a Python function in the
  Myia subset, parsed lazily into an IR graph by the frontend.  Per the
  paper §3.2: "The backpropagators of primitives are known."  Because the
  bprop is itself IR, the AD transform can be applied to it again —
  reverse-over-reverse works.
* an optional ``infer`` rule (structural prims); array prims default to
  abstract evaluation via ``jax.eval_shape`` in the inferencer.

Pallas TPU kernels register themselves here as primitives with hand-written
backpropagators (see ``repro.kernels``) — exactly the paper's "write
efficient low-level kernels and their derivatives in a low-level language
and expose them to Myia as primitives".
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .values import EnvInstance, gadd_values, zeros_like_value

__all__ = ["Primitive", "PRIMITIVES", "register_primitive", "COLLECTIVE_NAMES"]

_PY_NUM = (bool, int, float)


def _all_py(*xs: Any) -> bool:
    return all(isinstance(x, _PY_NUM) for x in xs)


class Primitive:
    """A named primitive with implementation + backpropagator definition."""

    def __init__(
        self,
        name: str,
        impl: Callable,
        *,
        bprop: Callable | str | None = None,
        vararg: bool = False,
        infer: Callable | None = None,
    ) -> None:
        self.name = name
        self.impl = impl
        #: Python function (Myia subset) computing input gradients, with
        #: signature ``(x1..xn, out, dout) -> (dx1..dxn)``; the string
        #: "zeros" means all-zero gradients (non-differentiable prim);
        #: None means AD must special-case it (make_tuple, …).
        self.bprop = bprop
        self.vararg = vararg
        self.infer = infer
        self._bprop_graph = None  # parsed lazily by repro.core.ad

    def __call__(self, *args: Any) -> Any:
        return self.impl(*args)

    def __repr__(self) -> str:  # pragma: no cover
        return f"<Prim {self.name}>"


PRIMITIVES: dict[str, Primitive] = {}


def register_primitive(
    name: str,
    impl: Callable,
    *,
    bprop: Callable | str | None = None,
    vararg: bool = False,
    infer: Callable | None = None,
) -> Primitive:
    p = Primitive(name, impl, bprop=bprop, vararg=vararg, infer=infer)
    PRIMITIVES[name] = p
    return p


# ===========================================================================
# Implementations
# ===========================================================================


def _impl_add(x, y):
    return x + y if _all_py(x, y) else jnp.add(x, y)


def _impl_sub(x, y):
    return x - y if _all_py(x, y) else jnp.subtract(x, y)


def _impl_mul(x, y):
    return x * y if _all_py(x, y) else jnp.multiply(x, y)


def _impl_div(x, y):
    return x / y if _all_py(x, y) else jnp.divide(x, y)


def _impl_pow(x, y):
    return x**y if _all_py(x, y) else jnp.power(x, y)


def _impl_floordiv(x, y):
    return x // y if _all_py(x, y) else jnp.floor_divide(x, y)


def _impl_mod(x, y):
    return x % y if _all_py(x, y) else jnp.mod(x, y)


def _impl_neg(x):
    return -x if _all_py(x) else jnp.negative(x)


def _cmp(py, jx):
    def impl(a, b):
        return py(a, b) if _all_py(a, b) else jx(a, b)

    return impl


def _impl_switch(c, t, f):
    if isinstance(c, (bool, np.bool_)):
        return t if c else f
    if isinstance(c, jnp.ndarray) and not isinstance(c, jax.core.Tracer):
        return t if bool(c) else f
    # traced condition: only valid for array-like branches
    return jnp.where(c, t, f)


def _impl_shape(x):
    if isinstance(x, _PY_NUM):
        return ()
    return tuple(int(d) for d in x.shape)


def _impl_unbroadcast(x, shp):
    shp = tuple(shp)
    if isinstance(x, _PY_NUM):
        return x
    if shp == ():
        return jnp.sum(x)
    ndiff = x.ndim - len(shp)
    if ndiff > 0:
        x = jnp.sum(x, axis=tuple(range(ndiff)))
    axes = tuple(i for i, (a, b) in enumerate(zip(x.shape, shp)) if b == 1 and a != 1)
    if axes:
        x = jnp.sum(x, axis=axes, keepdims=True)
    return x


def _norm_axes(axes, ndim):
    if axes is None:
        return tuple(range(ndim))
    if isinstance(axes, int):
        axes = (axes,)
    return tuple(sorted(a % ndim for a in axes))


def _impl_reduce_sum(x, axes, keepdims):
    return jnp.sum(x, axis=axes if axes is None else tuple(axes), keepdims=keepdims)


def _impl_reduce_max(x, axes, keepdims):
    return jnp.max(x, axis=axes if axes is None else tuple(axes), keepdims=keepdims)


def _impl_unreduce(x, shp, axes, keepdims):
    shp = tuple(shp)
    x = jnp.asarray(x)
    if not keepdims:
        for a in _norm_axes(axes, len(shp)):
            x = jnp.expand_dims(x, a)
    return jnp.broadcast_to(x, shp)


def _impl_axes_size(x, axes):
    shp = _impl_shape(x)
    return int(np.prod([shp[a] for a in _norm_axes(axes, len(shp))])) if shp else 1


def _impl_mT(x):
    return jnp.swapaxes(x, -1, -2)


def _impl_take(x, idx):
    return jnp.take(x, idx, axis=0)


def _impl_index_add(base, idx, val):
    return jnp.asarray(base).at[idx].add(val)


def _impl_slice_axis(x, axis, start, stop):
    return jax.lax.slice_in_dim(x, start, stop, axis=axis)


def _impl_pad_zeros_axis(x, axis, before, after):
    pads = [(0, 0)] * jnp.ndim(x)
    pads[axis] = (before, after)
    return jnp.pad(x, pads)


def _impl_concat_axis(xs, axis):
    return jnp.concatenate(list(xs), axis=axis)


def _impl_concat_grad(xs, axis, dout):
    outs = []
    off = 0
    for x in xs:
        n = x.shape[axis]
        outs.append(jax.lax.slice_in_dim(dout, off, off + n, axis=axis))
        off += n
    return tuple(outs)


def _impl_cast(x, dtype):
    return jnp.asarray(x, dtype=dtype)


def _impl_dtype_of(x):
    if isinstance(x, (bool, np.bool_)):
        return jnp.bool_.dtype if hasattr(jnp.bool_, "dtype") else np.dtype(bool)
    if isinstance(x, int):
        return np.dtype("int32")
    if isinstance(x, float):
        return np.dtype("float32")
    return x.dtype


def _impl_stop_gradient(x):
    return x if _all_py(x) else jax.lax.stop_gradient(x)


def _impl_env_setitem(env: EnvInstance, key, val):
    return env.set(key, val)


def _impl_env_getitem(env: EnvInstance, key, default):
    return env.get(key, default)


def _impl_invert_permutation(perm):
    return tuple(int(i) for i in np.argsort(np.asarray(perm)))


def _impl_tuple_getitem(t, i):
    return t[i]


def _impl_tuple_setitem(t, i, v):
    lst = list(t)
    lst[i] = v
    return tuple(lst)


def _impl_one_hot(idx, num, dtype):
    return jax.nn.one_hot(idx, num, dtype=dtype)


# ---------------------------------------------------------------------------
# Collectives (SPMD tier).  These primitives only execute inside a
# ``shard_map`` region: their axis names must be bound by the surrounding
# mesh.  They are inserted by ``repro.core.spmd`` *after* AD and
# optimization (resharding points of the propagated sharding), so they
# carry no backpropagators — differentiating through one is a pipeline
# ordering bug and must fail loudly, not return zeros.  ``axes`` is a
# tuple of mesh axis names; ``sizes`` the matching mesh axis sizes
# (baked in by the SPMD transform so shape inference needs no mesh).
# ---------------------------------------------------------------------------


def _impl_psum_axes(x, axes):
    return jax.lax.psum(x, tuple(axes))


def _impl_pmax_axes(x, axes):
    return jax.lax.pmax(x, tuple(axes))


def _impl_all_gather_axes(x, axes, dim, sizes):
    out = x
    # gather innermost axis first so the outermost axis ends up as the
    # slowest-varying block — matching shard_slice's linearized index
    for a in reversed(tuple(axes)):
        out = jax.lax.all_gather(out, a, axis=dim, tiled=True)
    return out


def _impl_shard_slice(x, axes, dim, sizes):
    idx = 0
    for a, s in zip(tuple(axes), tuple(sizes)):
        idx = idx * s + jax.lax.axis_index(a)
    block = x.shape[dim] // int(np.prod(sizes))
    return jax.lax.dynamic_slice_in_dim(x, idx * block, block, axis=dim)


#: primitive names that communicate across shards (or re-partition a
#: replicated value).  Fusion classifies these as opaque — a cluster can
#: never span a resharding point — and the optimizer never folds them.
COLLECTIVE_NAMES = frozenset(
    {"psum_axes", "pmax_axes", "all_gather_axes", "shard_slice"}
)


# ---------------------------------------------------------------------------
# Structured loops (closure-elimination tier).  ``repro.core.closure``
# rewrites residual recursive families (parsed while/for loops, nested
# loop SCCs, affine non-tail self-recursion) into these primitives.
# They register with ``bprop=None`` like the collectives, but for a
# different reason: their adjoints are not pointwise VJP rules — they are
# *loop-shaped* ("don't unroll the adjoint"), so ``repro.core.ad``'s
# JTransformer differentiates the primitive applies directly, emitting a
# reversed scan over saved-carry stacks (``scan_loop``) or a trip-counted,
# checkpointed backward while (``while_loop``).  The pre-grad pipeline
# (``ad._prepare_primal``) lowers parsed loops *before* J so grad sees
# these primitives rather than raw recursion.  ``cond``/``step``/``exit``
# arrive as *closed first-order graphs* (bound as lowered callables on the
# direct path, as Closures on the VM path); the trailing arguments split
# at ``n_carry`` into the loop carry (the header parameters) and the
# loop-invariant closure environment (threaded unchanged to every call).
# ---------------------------------------------------------------------------


def _call_loop_fn(f: Any, args: tuple) -> Any:
    """Call a loop sub-function: a lowered Python callable (direct path)
    or a Graph/Closure evaluated by the reference VM (fallback path)."""
    from .ir import Graph
    from .values import Closure

    if isinstance(f, (Graph, Closure)):
        from .vm import VM

        return VM().call(f, tuple(args))
    return f(*args)


def _loop_retype_carry(step_f: Callable, carry: tuple) -> tuple:
    """Promote the init carry to the step's output types.  jax requires the
    while/scan carry to be type-stable; Python-literal inits (weak types)
    routinely disagree with the step's strong jnp results, and one
    promotion round resolves every case our rewriter can produce."""
    spec = jax.eval_shape(step_f, carry)
    return jax.tree_util.tree_map(lambda i, s: jnp.asarray(i, s.dtype), carry, spec)


def _impl_while_loop(cond, step, exit_, n_carry, *args):
    carry = tuple(args[:n_carry])
    extras = tuple(args[n_carry:])

    def cond_f(c):
        return _call_loop_fn(cond, (*c, *extras))

    def step_f(c):
        return tuple(_call_loop_fn(step, (*c, *extras)))

    try:
        out = jax.lax.while_loop(cond_f, step_f, carry)
    except TypeError:
        out = jax.lax.while_loop(cond_f, step_f, _loop_retype_carry(step_f, carry))
    return _call_loop_fn(exit_, (*tuple(out), *extras))


def _impl_scan_loop(step, exit_, length, n_carry, *args):
    carry = tuple(args[:n_carry])
    extras = tuple(args[n_carry:])

    def step_f(c):
        return tuple(_call_loop_fn(step, (*c, *extras)))

    def body(c, _):
        return step_f(c), None

    try:
        out, _ = jax.lax.scan(body, carry, None, length=int(length))
    except TypeError:
        out, _ = jax.lax.scan(
            body, _loop_retype_carry(step_f, carry), None, length=int(length)
        )
    return _call_loop_fn(exit_, (*tuple(out), *extras))


#: loop primitives and, per name, how many leading arguments are
#: graph-valued sub-functions (legal graph constants for the lowerer)
LOOP_GRAPH_ARGS: dict[str, int] = {"while_loop": 3, "scan_loop": 2}
LOOP_NAMES = frozenset(LOOP_GRAPH_ARGS)


# ===========================================================================
# Registration.  bprop functions are defined at the end of this module and
# attached afterwards (they reference the prim globals below).
# ===========================================================================

add = register_primitive("add", _impl_add)
sub = register_primitive("sub", _impl_sub)
mul = register_primitive("mul", _impl_mul)
div = register_primitive("div", _impl_div)
power = register_primitive("power", _impl_pow)


def _impl_integer_pow(x, n):
    if _all_py(x, n):
        return x**n
    return jax.lax.integer_pow(x, int(n))


integer_pow = register_primitive("integer_pow", _impl_integer_pow)
floordiv = register_primitive("floordiv", _impl_floordiv, bprop="zeros")
mod = register_primitive("mod", _impl_mod, bprop="zeros")
neg = register_primitive("neg", _impl_neg)

exp = register_primitive("exp", lambda x: jnp.exp(x))
log = register_primitive("log", lambda x: jnp.log(x))
tanh = register_primitive("tanh", lambda x: jnp.tanh(x))
sigmoid = register_primitive("sigmoid", lambda x: jax.nn.sigmoid(x))
relu = register_primitive("relu", lambda x: jnp.maximum(x, 0))
sqrt = register_primitive("sqrt", lambda x: jnp.sqrt(x))
rsqrt = register_primitive(
    "rsqrt", lambda x: jax.lax.rsqrt(jnp.asarray(x, jnp.result_type(x, 1.0)))
)
sin = register_primitive("sin", lambda x: jnp.sin(x))
cos = register_primitive("cos", lambda x: jnp.cos(x))
square = register_primitive("square", lambda x: jnp.square(x))
absolute = register_primitive("absolute", lambda x: abs(x) if _all_py(x) else jnp.abs(x))
sign = register_primitive("sign", lambda x: jnp.sign(x), bprop="zeros")
erf = register_primitive("erf", lambda x: jax.lax.erf(jnp.asarray(x, jnp.result_type(x, 1.0))))

lt = register_primitive("lt", _cmp(lambda a, b: a < b, jnp.less), bprop="zeros")
gt = register_primitive("gt", _cmp(lambda a, b: a > b, jnp.greater), bprop="zeros")
le = register_primitive("le", _cmp(lambda a, b: a <= b, jnp.less_equal), bprop="zeros")
ge = register_primitive("ge", _cmp(lambda a, b: a >= b, jnp.greater_equal), bprop="zeros")
eq = register_primitive("eq", _cmp(lambda a, b: a == b, jnp.equal), bprop="zeros")
ne = register_primitive("ne", _cmp(lambda a, b: a != b, jnp.not_equal), bprop="zeros")
bool_and = register_primitive(
    "bool_and", _cmp(lambda a, b: a and b, jnp.logical_and), bprop="zeros"
)
bool_or = register_primitive("bool_or", _cmp(lambda a, b: a or b, jnp.logical_or), bprop="zeros")
bool_not = register_primitive(
    "bool_not", lambda x: (not x) if _all_py(x) else jnp.logical_not(x), bprop="zeros"
)

maximum = register_primitive(
    "maximum", lambda x, y: max(x, y) if _all_py(x, y) else jnp.maximum(x, y)
)
minimum = register_primitive(
    "minimum", lambda x, y: min(x, y) if _all_py(x, y) else jnp.minimum(x, y)
)
where = register_primitive("where", lambda c, a, b: jnp.where(c, a, b))

matmul = register_primitive("matmul", lambda a, b: jnp.matmul(a, b))
mT = register_primitive("mT", _impl_mT)
transpose = register_primitive("transpose", lambda x, perm: jnp.transpose(x, tuple(perm)))
reshape = register_primitive("reshape", lambda x, shp: jnp.reshape(x, tuple(shp)))
broadcast_to = register_primitive("broadcast_to", lambda x, shp: jnp.broadcast_to(x, tuple(shp)))
unbroadcast = register_primitive("unbroadcast", _impl_unbroadcast)
reduce_sum = register_primitive("reduce_sum", _impl_reduce_sum)
reduce_max = register_primitive("reduce_max", _impl_reduce_max)
unreduce = register_primitive("unreduce", _impl_unreduce)

shape = register_primitive("shape", _impl_shape, bprop="zeros")
axes_size = register_primitive("axes_size", _impl_axes_size, bprop="zeros")
dtype_of = register_primitive("dtype_of", _impl_dtype_of, bprop="zeros")
invert_permutation = register_primitive(
    "invert_permutation", _impl_invert_permutation, bprop="zeros"
)
cast = register_primitive("cast", _impl_cast)

take = register_primitive("take", _impl_take)
index_add = register_primitive("index_add", _impl_index_add)
slice_axis = register_primitive("slice_axis", _impl_slice_axis)
pad_zeros_axis = register_primitive("pad_zeros_axis", _impl_pad_zeros_axis)
concat_axis = register_primitive("concat_axis", _impl_concat_axis)
concat_grad = register_primitive("concat_grad", _impl_concat_grad)
one_hot = register_primitive("one_hot", _impl_one_hot, bprop="zeros")

# collectives: bprop=None — AD through a resharding point must fail loudly
psum_axes = register_primitive("psum_axes", _impl_psum_axes)
pmax_axes = register_primitive("pmax_axes", _impl_pmax_axes)
all_gather_axes = register_primitive("all_gather_axes", _impl_all_gather_axes)
shard_slice = register_primitive("shard_slice", _impl_shard_slice)

# structured loops: bprop=None — their adjoints are loop-shaped, built by
# ad.JTransformer._j_while/_j_scan rather than a pointwise VJP rule
while_loop = register_primitive("while_loop", _impl_while_loop, vararg=True)
scan_loop = register_primitive("scan_loop", _impl_scan_loop, vararg=True)

switch = register_primitive("switch", _impl_switch)
stop_gradient = register_primitive("stop_gradient", _impl_stop_gradient)

make_tuple = register_primitive("make_tuple", lambda *xs: tuple(xs), vararg=True, bprop=None)
tuple_getitem = register_primitive("tuple_getitem", _impl_tuple_getitem)
tuple_setitem = register_primitive("tuple_setitem", _impl_tuple_setitem)
tuple_len = register_primitive("tuple_len", lambda t: len(t), bprop="zeros")

gadd = register_primitive("gadd", gadd_values)
zeros_like = register_primitive("zeros_like", zeros_like_value)

env_setitem = register_primitive("env_setitem", _impl_env_setitem)
env_getitem = register_primitive("env_getitem", _impl_env_getitem)

# ===========================================================================
# Backpropagator definitions (Myia-subset Python; parsed, never executed).
# Signature: (args..., out, dout) -> tuple of gradients w.r.t. args.
# ===========================================================================


def _bprop_add(x, y, out, dout):
    return (unbroadcast(dout, shape(x)), unbroadcast(dout, shape(y)))


def _bprop_sub(x, y, out, dout):
    return (unbroadcast(dout, shape(x)), unbroadcast(neg(dout), shape(y)))


def _bprop_mul(x, y, out, dout):
    return (unbroadcast(mul(dout, y), shape(x)), unbroadcast(mul(dout, x), shape(y)))


def _bprop_div(x, y, out, dout):
    return (
        unbroadcast(div(dout, y), shape(x)),
        unbroadcast(neg(div(mul(dout, x), mul(y, y))), shape(y)),
    )


def _bprop_power(x, y, out, dout):
    return (
        unbroadcast(mul(dout, mul(y, power(x, sub(y, 1)))), shape(x)),
        unbroadcast(mul(dout, mul(out, log(x))), shape(y)),
    )


def _bprop_integer_pow(x, n, out, dout):
    # no log term: safe for negative bases (cf. jax.lax.integer_pow)
    return (mul(dout, mul(n, integer_pow(x, sub(n, 1)))), zeros_like(n))


def _bprop_neg(x, out, dout):
    return (neg(dout),)


def _bprop_exp(x, out, dout):
    return (mul(dout, out),)


def _bprop_log(x, out, dout):
    return (div(dout, x),)


def _bprop_tanh(x, out, dout):
    return (mul(dout, sub(1.0, mul(out, out))),)


def _bprop_sigmoid(x, out, dout):
    return (mul(dout, mul(out, sub(1.0, out))),)


def _bprop_relu(x, out, dout):
    return (mul(dout, cast(gt(x, 0), dtype_of(dout))),)


def _bprop_sqrt(x, out, dout):
    return (div(mul(dout, 0.5), out),)


def _bprop_rsqrt(x, out, dout):
    return (div(mul(mul(dout, -0.5), out), x),)


def _bprop_sin(x, out, dout):
    return (mul(dout, cos(x)),)


def _bprop_cos(x, out, dout):
    return (neg(mul(dout, sin(x))),)


def _bprop_square(x, out, dout):
    return (mul(dout, mul(2.0, x)),)


def _bprop_absolute(x, out, dout):
    return (mul(dout, sign(x)),)


def _bprop_erf(x, out, dout):
    return (mul(dout, mul(1.1283791670955126, exp(neg(mul(x, x))))),)


def _bprop_maximum(x, y, out, dout):
    return (
        unbroadcast(mul(dout, cast(ge(x, y), dtype_of(dout))), shape(x)),
        unbroadcast(mul(dout, cast(lt(x, y), dtype_of(dout))), shape(y)),
    )


def _bprop_minimum(x, y, out, dout):
    return (
        unbroadcast(mul(dout, cast(le(x, y), dtype_of(dout))), shape(x)),
        unbroadcast(mul(dout, cast(gt(x, y), dtype_of(dout))), shape(y)),
    )


def _bprop_where(c, a, b, out, dout):
    return (
        zeros_like(c),
        unbroadcast(mul(dout, cast(c, dtype_of(dout))), shape(a)),
        unbroadcast(mul(dout, cast(bool_not(c), dtype_of(dout))), shape(b)),
    )


def _bprop_matmul(a, b, out, dout):
    return (
        unbroadcast(matmul(dout, mT(b)), shape(a)),
        unbroadcast(matmul(mT(a), dout), shape(b)),
    )


def _bprop_mT(x, out, dout):
    return (mT(dout),)


def _bprop_transpose(x, perm, out, dout):
    return (transpose(dout, invert_permutation(perm)), zeros_like(perm))


def _bprop_reshape(x, shp, out, dout):
    return (reshape(dout, shape(x)), zeros_like(shp))


def _bprop_broadcast_to(x, shp, out, dout):
    return (unbroadcast(dout, shape(x)), zeros_like(shp))


def _bprop_unbroadcast(x, shp, out, dout):
    return (broadcast_to(dout, shape(x)), zeros_like(shp))


def _bprop_reduce_sum(x, axes, keepdims, out, dout):
    return (unreduce(dout, shape(x), axes, keepdims), zeros_like(axes), zeros_like(keepdims))


def _bprop_unreduce(x, shp, axes, keepdims, out, dout):
    return (
        reduce_sum(dout, axes, keepdims),
        zeros_like(shp),
        zeros_like(axes),
        zeros_like(keepdims),
    )


def _bprop_reduce_max(x, axes, keepdims, out, dout):
    m = cast(eq(x, unreduce(out, shape(x), axes, keepdims)), dtype_of(dout))
    cnt = reduce_sum(m, axes, keepdims)
    return (
        mul(m, unreduce(div(dout, cnt), shape(x), axes, keepdims)),
        zeros_like(axes),
        zeros_like(keepdims),
    )


def _bprop_cast(x, dtype, out, dout):
    return (cast(dout, dtype_of(x)), zeros_like(dtype))


def _bprop_take(x, idx, out, dout):
    return (index_add(zeros_like(x), idx, dout), zeros_like(idx))


def _bprop_index_add(base, idx, val, out, dout):
    return (dout, zeros_like(idx), take(dout, idx))


def _bprop_slice_axis(x, axis, start, stop, out, dout):
    total = tuple_getitem(shape(x), axis)
    return (
        pad_zeros_axis(dout, axis, start, sub(total, stop)),
        zeros_like(axis),
        zeros_like(start),
        zeros_like(stop),
    )


def _bprop_pad_zeros_axis(x, axis, before, after, out, dout):
    n = tuple_getitem(shape(x), axis)
    return (
        slice_axis(dout, axis, before, add(before, n)),
        zeros_like(axis),
        zeros_like(before),
        zeros_like(after),
    )


def _bprop_concat_axis(xs, axis, out, dout):
    return (concat_grad(xs, axis, dout), zeros_like(axis))


def _bprop_concat_grad(xs, axis, dout_in, out, dout):
    return (zeros_like(xs), zeros_like(axis), concat_axis(dout, axis))


def _bprop_switch(c, t, f, out, dout):
    return (zeros_like(c), switch(c, dout, zeros_like(t)), switch(c, zeros_like(f), dout))


def _bprop_stop_gradient(x, out, dout):
    return (zeros_like(x),)


def _bprop_gadd(x, y, out, dout):
    return (dout, dout)


def _bprop_zeros_like(x, out, dout):
    return (zeros_like(x),)


def _bprop_tuple_getitem(t, i, out, dout):
    return (tuple_setitem(zeros_like(t), i, dout), zeros_like(i))


def _bprop_tuple_setitem(t, i, v, out, dout):
    return (tuple_setitem(dout, i, zeros_like(v)), zeros_like(i), tuple_getitem(dout, i))


def _bprop_env_setitem(env, key, val, out, dout):
    return (
        env_setitem(dout, key, zeros_like(val)),
        zeros_like(key),
        env_getitem(dout, key, zeros_like(val)),
    )


def _bprop_env_getitem(env, key, default, out, dout):
    return (
        env_setitem(zeros_like(env), key, dout),
        zeros_like(key),
        zeros_like(default),
    )


_BPROPS = {
    "add": _bprop_add,
    "sub": _bprop_sub,
    "mul": _bprop_mul,
    "div": _bprop_div,
    "power": _bprop_power,
    "integer_pow": _bprop_integer_pow,
    "neg": _bprop_neg,
    "exp": _bprop_exp,
    "log": _bprop_log,
    "tanh": _bprop_tanh,
    "sigmoid": _bprop_sigmoid,
    "relu": _bprop_relu,
    "sqrt": _bprop_sqrt,
    "rsqrt": _bprop_rsqrt,
    "sin": _bprop_sin,
    "cos": _bprop_cos,
    "square": _bprop_square,
    "absolute": _bprop_absolute,
    "erf": _bprop_erf,
    "maximum": _bprop_maximum,
    "minimum": _bprop_minimum,
    "where": _bprop_where,
    "matmul": _bprop_matmul,
    "mT": _bprop_mT,
    "transpose": _bprop_transpose,
    "reshape": _bprop_reshape,
    "broadcast_to": _bprop_broadcast_to,
    "unbroadcast": _bprop_unbroadcast,
    "reduce_sum": _bprop_reduce_sum,
    "unreduce": _bprop_unreduce,
    "reduce_max": _bprop_reduce_max,
    "cast": _bprop_cast,
    "take": _bprop_take,
    "index_add": _bprop_index_add,
    "slice_axis": _bprop_slice_axis,
    "pad_zeros_axis": _bprop_pad_zeros_axis,
    "concat_axis": _bprop_concat_axis,
    "concat_grad": _bprop_concat_grad,
    "switch": _bprop_switch,
    "stop_gradient": _bprop_stop_gradient,
    "gadd": _bprop_gadd,
    "zeros_like": _bprop_zeros_like,
    "tuple_getitem": _bprop_tuple_getitem,
    "tuple_setitem": _bprop_tuple_setitem,
    "env_setitem": _bprop_env_setitem,
    "env_getitem": _bprop_env_getitem,
}

for _name, _fn in _BPROPS.items():
    PRIMITIVES[_name].bprop = _fn
