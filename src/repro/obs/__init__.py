"""Process-wide observability: tracing spans + unified metrics.

* ``repro.obs.trace`` — nested ``span()`` context managers over a bounded
  in-memory buffer, armed via ``tracing(tracer)`` (module-global hook
  with a None-check fast path: zero overhead disarmed), exported as
  Chrome trace-event JSON (Perfetto) or a text phase summary.
* ``repro.obs.metrics`` — counters / gauges / fixed-bucket histograms and
  the flat dotted-key ``snapshot()`` schema absorbing ``OptStats``,
  ``CacheStats`` and the serve engine's stats behind one surface, plus the
  Prometheus text exposition (``to_prometheus``).
* ``repro.obs.profile`` — the runtime profiler: per-launch wall time and
  bytes-moved attribution against the HBM roofline, armed via
  ``profiling(profiler)`` (same zero-overhead-disarmed contract).
* ``repro.obs.explain`` — the compile-decision explain layer:
  ``MyiaFunction.explain()`` reports, per-stage IR dumps.

See ``docs/observability.md`` for the span taxonomy and worked examples.
"""

from .explain import ExplainReport, explain_function, explain_graph
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    flatten,
    snapshot,
    to_prometheus,
)
from .profile import NULL_PROBE, Profiler, profiling
from .trace import (
    MARK_NAMES,
    NULL_SPAN,
    SPAN_NAMES,
    SpanRecord,
    Tracer,
    active,
    mark,
    span,
    tracing,
)

__all__ = [
    "Counter",
    "ExplainReport",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "explain_function",
    "explain_graph",
    "flatten",
    "snapshot",
    "to_prometheus",
    "MARK_NAMES",
    "NULL_PROBE",
    "NULL_SPAN",
    "Profiler",
    "profiling",
    "SPAN_NAMES",
    "SpanRecord",
    "Tracer",
    "active",
    "mark",
    "span",
    "tracing",
]
