"""Process-wide observability: tracing spans + unified metrics.

* ``repro.obs.trace`` — nested ``span()`` context managers over a bounded
  in-memory buffer, armed via ``tracing(tracer)`` (module-global hook
  with a None-check fast path: zero overhead disarmed), exported as
  Chrome trace-event JSON (Perfetto) or a text phase summary.
* ``repro.obs.metrics`` — counters / gauges / fixed-bucket histograms and
  the flat dotted-key ``snapshot()`` schema absorbing ``OptStats``,
  ``CacheStats`` and the serve engine's stats behind one surface.

See ``docs/observability.md`` for the span taxonomy and worked examples.
"""

from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    flatten,
    snapshot,
)
from .trace import (
    NULL_SPAN,
    SpanRecord,
    Tracer,
    active,
    mark,
    span,
    tracing,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "flatten",
    "snapshot",
    "NULL_SPAN",
    "SpanRecord",
    "Tracer",
    "active",
    "mark",
    "span",
    "tracing",
]
