"""Runtime profiler: per-launch wall time + bytes-moved attribution for
lowered programs, against the HBM roofline.

PR 7's tracer shows where *compile* time goes; this module is the runtime
half of the instrument panel.  An armed profiler receives one record per
launch — each fused cluster, opaque op, structured loop, and collective —
with the wall time of that launch and a bytes-moved estimate derived from
the inferred abstracts (inputs + output, the minimum HBM traffic a
perfectly-fused kernel would pay).  From those it derives

    achieved_gbps     = bytes_moved / wall_s / 1e9
    roofline_fraction = achieved_gbps / peak_gbps      (819 GB/s HBM,
                                                        benchmarks/roofline.py)

per launch site, so "fused" can be judged as "closer to the roofline",
not just "fewer launches" — the acceptance bar the Fusion v2 ROADMAP item
is gated on.

Arming follows the ``faults.py`` / ``trace.py`` module-global pattern:

    prof = Profiler()
    with profiling(prof):
        f(x)                      # instrumented launches record themselves
    print(prof.attribution_table())
    prof.export_counters(tracer)  # Perfetto counter tracks (GB/s over time)

Disarmed, every hook is one module-global read returning the shared
:data:`NULL_PROBE` singleton — no allocation, no clock read (pinned
structurally by ``tests/obs/test_profile.py``, like ``NULL_SPAN``).

Timing semantics: a launch is timed eagerly — the hook calls the op,
blocks on the result (``jax.block_until_ready``), and stamps the wall
clock.  Under a ``jax.jit`` trace the Python hook would run once at trace
time and measure nothing, so the instrumented lowering
(``lower_graph(g, profile=True)``) is only executed *eagerly* by the
profiled runner (``CompileOptions.profile``); hooks also pass tracer
arguments straight through, so an armed profiler never corrupts an outer
jit trace.
"""

from __future__ import annotations

import contextlib
import threading
import time
from typing import Any

__all__ = [
    "HBM_PEAK_GBPS",
    "NULL_PROBE",
    "Profiler",
    "active",
    "call_profiled",
    "profiling",
]

#: the bandwidth model profiled launches are judged against —
#: ``benchmarks/roofline.py``'s 819 GB/s HBM per chip (TPU v5e)
HBM_PEAK_GBPS = 819.0

#: launch kinds, in attribution-table order
KINDS = ("fused", "opaque", "loop", "collective")


class LaunchSite:
    """Aggregated stats for one launch site (one emitted kernel / one
    lowered op): call count, total wall, bytes per launch, and the derived
    bandwidth numbers."""

    __slots__ = ("name", "kind", "calls", "total_s", "nbytes", "min_s", "max_s")

    def __init__(self, name: str, kind: str, nbytes: int) -> None:
        self.name = name
        self.kind = kind
        self.calls = 0
        self.total_s = 0.0
        self.nbytes = int(nbytes)  # per launch, from inferred abstracts
        self.min_s = float("inf")
        self.max_s = 0.0

    def add(self, dur_s: float) -> None:
        self.calls += 1
        self.total_s += dur_s
        if dur_s < self.min_s:
            self.min_s = dur_s
        if dur_s > self.max_s:
            self.max_s = dur_s

    @property
    def mean_s(self) -> float:
        return self.total_s / self.calls if self.calls else 0.0

    def achieved_gbps(self) -> float | None:
        """Bytes over mean launch wall; None when unattributable (no byte
        estimate — e.g. a loop whose body traffic the abstracts can't see)."""
        if not self.calls or not self.nbytes or self.total_s <= 0.0:
            return None
        return self.nbytes * self.calls / self.total_s / 1e9


class Profiler:
    """Bounded per-launch-site aggregation + a per-sample ring for the
    Perfetto counter export.  Thread-safe (one lock on record)."""

    def __init__(
        self, peak_gbps: float = HBM_PEAK_GBPS, max_samples: int = 4096
    ) -> None:
        self.peak_gbps = float(peak_gbps)
        self.max_samples = int(max_samples)
        self.sites: dict[tuple[str, str], LaunchSite] = {}
        #: (monotonic ts, site name, dur_s, gbps | None) — newest-wins ring
        self.samples: list[tuple[float, str, float, float | None]] = []
        self.dropped_samples = 0
        self._lock = threading.Lock()

    # -- recording ---------------------------------------------------------
    def record(self, name: str, kind: str, dur_s: float, nbytes: int) -> None:
        with self._lock:
            site = self.sites.get((name, kind))
            if site is None:
                site = self.sites[(name, kind)] = LaunchSite(name, kind, nbytes)
            site.add(dur_s)
            gbps = (nbytes / dur_s / 1e9) if (nbytes and dur_s > 0.0) else None
            if len(self.samples) < self.max_samples:
                self.samples.append((time.monotonic(), name, dur_s, gbps))
            else:
                self.dropped_samples += 1

    # -- derived views -----------------------------------------------------
    def roofline_fraction(self, gbps: float | None) -> float | None:
        """Fraction of the HBM roofline, clamped to (0, 1] — a site beating
        the model (cache-resident CPU runs) saturates at 1.0 rather than
        reporting an impossible >1 fraction."""
        if gbps is None or gbps <= 0.0:
            return None
        return min(1.0, gbps / self.peak_gbps)

    def rows(self) -> list[dict]:
        """One JSON-scalar dict per launch site, hottest first."""
        out = []
        for site in sorted(self.sites.values(), key=lambda s: -s.total_s):
            gbps = site.achieved_gbps()
            frac = self.roofline_fraction(gbps)
            out.append({
                "name": site.name,
                "kind": site.kind,
                "calls": site.calls,
                "total_ms": round(site.total_s * 1e3, 4),
                "mean_us": round(site.mean_s * 1e6, 2),
                "bytes_per_launch": site.nbytes,
                "achieved_gbps": round(gbps, 3) if gbps is not None else None,
                # 9 digits: a positive bandwidth must never round to a 0.0 fraction
                "roofline_fraction": round(frac, 9) if frac is not None else None,
            })
        return out

    def aggregate(self, kind: str | None = None) -> dict:
        """Totals over all sites (or one ``kind``): summed bytes over
        summed wall — the workload-level bandwidth the bench rows report."""
        sites = [
            s for s in self.sites.values() if kind is None or s.kind == kind
        ]
        total_s = sum(s.total_s for s in sites)
        total_bytes = sum(s.nbytes * s.calls for s in sites)
        calls = sum(s.calls for s in sites)
        gbps = (total_bytes / total_s / 1e9) if (total_bytes and total_s > 0) else None
        frac = self.roofline_fraction(gbps)
        return {
            "kind": kind or "all",
            "sites": len(sites),
            "calls": calls,
            "total_ms": round(total_s * 1e3, 4),
            "total_bytes": total_bytes,
            "achieved_gbps": round(gbps, 3) if gbps is not None else None,
            "roofline_fraction": round(frac, 9) if frac is not None else None,
        }

    def as_dict(self) -> dict:
        return {
            "peak_gbps": self.peak_gbps,
            "sites": self.rows(),
            "totals": {k: self.aggregate(k) for k in KINDS},
            "dropped_samples": self.dropped_samples,
        }

    # -- exporters ---------------------------------------------------------
    def attribution_table(self, top: int = 20) -> str:
        """The terminal view: hottest launch sites with bandwidth columns.

        ``—`` marks sites without a byte estimate (no array abstracts to
        cost, e.g. a whole structured loop); their wall time still counts."""
        lines = [
            f"{'launch site':<40} {'kind':<10} {'calls':>6} {'total_ms':>9} "
            f"{'mean_us':>9} {'GB/s':>8} {'roofline':>9}"
        ]
        for r in self.rows()[:top]:
            gbps = "—" if r["achieved_gbps"] is None else f"{r['achieved_gbps']:.1f}"
            frac = (
                "—"
                if r["roofline_fraction"] is None
                else f"{r['roofline_fraction'] * 100:.1f}%"
            )
            lines.append(
                f"{r['name']:<40} {r['kind']:<10} {r['calls']:>6} "
                f"{r['total_ms']:>9.2f} {r['mean_us']:>9.1f} {gbps:>8} {frac:>9}"
            )
        agg = self.aggregate()
        gbps = agg["achieved_gbps"]
        lines.append(
            f"{'TOTAL':<40} {'':<10} {agg['calls']:>6} {agg['total_ms']:>9.2f} "
            f"{'':>9} {gbps if gbps is not None else '—':>8} "
            f"{'' if gbps is None else format(agg['roofline_fraction'] * 100, '.1f') + '%':>9}"
        )
        if self.dropped_samples:
            lines.append(
                f"[{self.dropped_samples} samples dropped at "
                f"max_samples={self.max_samples}; aggregates unaffected]"
            )
        return "\n".join(lines)

    def export_counters(self, tracer: Any) -> int:
        """Replay the per-launch samples into ``tracer`` as Perfetto
        counter tracks: one ``profile.gbps.<site>`` series per launch site
        plus the per-launch ``profile.launch_ms`` series.  Returns the
        number of counter events emitted."""
        n = 0
        for ts, name, dur_s, gbps in self.samples:
            tracer.counter("profile.launch_ms", dur_s * 1e3, ts=ts, site=name)
            n += 1
            if gbps is not None:
                tracer.counter(f"profile.gbps.{name}", gbps, ts=ts)
                n += 1
        return n


# ---------------------------------------------------------------------------
# Module-global arming (the faults.py / trace.py pattern)
# ---------------------------------------------------------------------------

_ACTIVE: Profiler | None = None


def active() -> Profiler | None:
    """The armed profiler, or None (the production disarmed state)."""
    return _ACTIVE


@contextlib.contextmanager
def profiling(profiler: Profiler | None):
    """Arm ``profiler`` process-wide for the dynamic extent of the block.
    ``profiling(None)`` is a no-op block (mirrors ``tracing(None)``)."""
    global _ACTIVE
    prev = _ACTIVE
    if profiler is not None:
        _ACTIVE = profiler
    try:
        yield profiler
    finally:
        _ACTIVE = prev


class _NullProbe:
    """The disarmed fast path: a shared, stateless no-op probe.
    ``probe(...)`` returns this singleton without allocating anything —
    the structural-zero-overhead contract, pinned by identity in tests."""

    __slots__ = ()

    def __enter__(self) -> "_NullProbe":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL_PROBE = _NullProbe()


class _LiveProbe:
    """Times one launch on the armed profiler (blocks on the result via
    the caller handing it back through :meth:`done`)."""

    __slots__ = ("_prof", "_name", "_kind", "_nbytes", "_t0")

    def __init__(self, prof: Profiler, name: str, kind: str, nbytes: int) -> None:
        self._prof = prof
        self._name = name
        self._kind = kind
        self._nbytes = nbytes
        self._t0 = time.perf_counter()

    def __enter__(self) -> "_LiveProbe":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self._prof.record(
                self._name, self._kind, time.perf_counter() - self._t0, self._nbytes
            )
        return False


def probe(name: str, kind: str = "opaque", nbytes: int = 0):
    """A context manager timing one launch: :data:`NULL_PROBE` disarmed
    (one global read, no allocation), a live probe when armed."""
    p = _ACTIVE
    if p is None:
        return NULL_PROBE
    return _LiveProbe(p, name, kind, nbytes)


def _block(out: Any) -> Any:
    """Force async dispatch to finish so the probe measures the launch,
    not the enqueue.  Tolerates non-jax values (tuples of arrays are
    handled by jax itself)."""
    try:
        import jax

        return jax.block_until_ready(out)
    except Exception:
        return out


def call_profiled(fn: Any, name: str, kind: str, nbytes: int, *args: Any) -> Any:
    """The hook the instrumented lowering emits around every launch:
    disarmed it is a single global None-check and a tail call; armed it
    times ``fn(*args)`` to completion and records one launch.

    Tracer arguments pass straight through untimed — timing a traced
    launch would record trace-time, not run-time, and the instrumented
    source must stay jit-traceable for the fallback path."""
    p = _ACTIVE
    if p is None:
        return fn(*args)
    import jax

    if any(isinstance(a, jax.core.Tracer) for a in args):
        return fn(*args)
    t0 = time.perf_counter()
    out = _block(fn(*args))
    p.record(name, kind, time.perf_counter() - t0, nbytes)
    return out
