"""Compile-decision explain layer: "why did the compiler do that?".

Every tier of the pipeline makes silent decisions — the fusion
partitioner declines a region, the SPMD propagator replicates a dim, the
cache tier misses, a loop adjoint picks a checkpoint policy, a residual
closure forces the VM — and until now the only way to see them was to
read four subsystems' internals.  :func:`explain_graph` (surfaced as
``MyiaFunction.explain(*example_args)``) runs the real pipeline on a
private clone and returns one structured, JSON-serializable
:class:`ExplainReport`:

* **fusion** — per-cluster verdict (``emitted`` / ``declined`` with a
  structured :class:`~repro.core.fusion.DeclineReason`) and a per-node
  decision (``fused`` into which cluster, or ``unfused`` with a reason
  object — never a bare string),
* **sharding** — the SPMD spec per parameter and per node dim-by-dim, or
  the structured reason the tier did not engage,
* **cache** — graph-tier and exec-tier verdicts (``graph-hit`` / ``miss``
  / ``exec-hit`` / ``cold`` / ``unkeyable`` / ``disabled``) with the keys,
* **loops** — the checkpoint policy and slot budget each structured-loop
  adjoint will record with,
* **fallback** — the residual :class:`~repro.core.closure.FallbackReason`
  list when the graph stays on the VM,
* **phases** — the compile-phase wall-time breakdown from a private
  tracer armed for the run.

``dump_ir="dir/"`` additionally writes the IR after every pipeline stage
as deterministic, diffable text (``00-input.ir``, ``01-cloned.ir``, …)
printed by :func:`format_graph` — names assigned in topological order, so
two dumps of structurally equal graphs are textually equal.

All ``repro.core`` imports are function-local: ``repro.obs`` stays
importable without jax, and core modules import ``repro.obs`` freely.
"""

from __future__ import annotations

import json
import os
from typing import Any

__all__ = [
    "ExplainReport",
    "explain_function",
    "explain_graph",
    "format_graph",
]


# ---------------------------------------------------------------------------
# Deterministic IR printer (the dump_ir format)
# ---------------------------------------------------------------------------


def _fmt_abstract(ab: Any) -> str:
    return "?" if ab is None else repr(ab)


def _node_names(graph: Any) -> dict[int, str]:
    """Stable names for one graph: ``p{i}`` parameters, ``v{i}`` applies in
    topological order — the same scheme the lowering emits, so an explain
    report and a lowered source line up."""
    from repro.core.ir import Apply, toposort

    names: dict[int, str] = {}
    for i, p in enumerate(graph.parameters):
        names[p._id] = f"p{i}"
    seq = 0
    for n in toposort(graph):
        if isinstance(n, Apply):
            names[n._id] = f"v{seq}"
            seq += 1
    return names


def format_graph(graph: Any) -> str:
    """Print ``graph`` (and every sub-graph constant it references,
    breadth-first) as deterministic text: one assignment per apply in
    topological order, abstracts as trailing comments.  Structurally equal
    graphs print equal text — the property that makes ``dump_ir`` stage
    dumps diffable."""
    from repro.core.ir import Apply, Constant, Graph, toposort

    queue = [graph]
    seen = {id(graph)}
    blocks: list[str] = []
    while queue:
        g = queue.pop(0)
        names = _node_names(g)

        def ref(node: Any) -> str:
            got = names.get(node._id)
            if got is not None:
                return got
            if isinstance(node, Constant):
                if isinstance(node.value, Graph):
                    if id(node.value) not in seen:
                        seen.add(id(node.value))
                        queue.append(node.value)
                    return f"@{node.value.name}"
                return repr(node.value)
            return f"<foreign:{node!r}>"  # free variable: owned elsewhere

        params = ", ".join(
            f"{names[p._id]}: {_fmt_abstract(p.abstract)}" for p in g.parameters
        )
        lines = [f"graph {g.name}({params}):"]
        for n in toposort(g):
            if not isinstance(n, Apply):
                continue
            fn = n.fn
            if isinstance(fn, Constant) and hasattr(fn.value, "name"):
                callee = fn.value.name
            else:
                callee = ref(fn)
            args = ", ".join(ref(a) for a in n.args)
            lines.append(
                f"  {names[n._id]} = {callee}({args})"
                f"  # {_fmt_abstract(n.abstract)}"
            )
        lines.append(f"  return {ref(g.return_)}")
        blocks.append("\n".join(lines))
    return "\n\n".join(blocks) + "\n"


# ---------------------------------------------------------------------------
# The report object
# ---------------------------------------------------------------------------


class ExplainReport:
    """A structured compile report: plain JSON-serializable data plus
    terminal/text renderers.  ``as_dict()`` → ``to_json()`` →
    ``from_json()`` round-trips exactly (pinned by tests)."""

    __slots__ = ("data",)

    def __init__(self, data: dict) -> None:
        self.data = data

    def __getitem__(self, key: str) -> Any:
        return self.data[key]

    def as_dict(self) -> dict:
        return self.data

    def to_json(self, *, indent: int | None = 2) -> str:
        return json.dumps(self.data, indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ExplainReport":
        return cls(json.loads(text))

    def summary(self) -> str:
        """The terminal view: one line per decision domain, then the
        non-obvious verdicts (declined clusters, unfused nodes, fallback
        reasons) spelled out."""
        d = self.data
        fus = d.get("fusion", {})
        lines = [f"explain: {d.get('program')}  sig={d.get('signature')}"]
        if fus.get("enabled"):
            nodes = fus.get("nodes", [])
            fused = sum(1 for n in nodes if n["decision"] == "fused")
            lines.append(
                f"  fusion: {len(fus.get('clusters', []))} clusters, "
                f"{fused}/{len(nodes)} applies fused"
            )
            for c in fus.get("clusters", []):
                if c["verdict"] != "emitted":
                    r = c.get("reason", {})
                    lines.append(
                        f"    cluster {c['cluster']} ({c['kind']}, size "
                        f"{c['size']}) declined: [{r.get('kind')}] {r.get('detail')}"
                    )
        else:
            r = fus.get("reason", {})
            lines.append(f"  fusion: off ([{r.get('kind')}] {r.get('detail')})")
        sh = d.get("sharding", {})
        lines.append(f"  sharding: {sh.get('verdict')}")
        for tier in d.get("cache", []):
            lines.append(f"  cache[{tier['tier']}]: {tier['verdict']}")
        for lp in d.get("loops", []):
            lines.append(
                f"  loop {lp['node']} ({lp['loop']}): checkpoint "
                f"{lp['checkpoint_policy']} ({lp['slots']} slots)"
            )
        fb = d.get("fallback", {})
        if fb.get("reasons"):
            for r in fb["reasons"]:
                lines.append(f"  vm-fallback: [{r.get('kind')}] {r.get('detail')}")
        else:
            lines.append("  lowers: straight-line (no VM fallback)")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Section builders (each returns plain JSON data; reasons are dicts with
# at least {"kind", "detail"} — never bare strings)
# ---------------------------------------------------------------------------


def _reason(kind: str, detail: str) -> dict:
    return {"kind": kind, "detail": detail}


def _fusion_section(g: Any, options: Any) -> dict:
    if not options.fuse:
        return {
            "enabled": False,
            "reason": _reason(
                "fusion-disabled",
                "CompileOptions.fuse is False; every apply lowers as one "
                "jnp launch",
            ),
        }
    from repro.core.fusion import explain_partition
    from repro.core.ir import Apply, toposort
    from repro.kernels.codegen import emit_cluster_explained

    names = _node_names(g)
    plan, declines = explain_partition(g)
    clusters: list[dict] = []
    member_of: dict[int, int] = {}
    cluster_reason: dict[int, dict | None] = {}
    for i, c in enumerate(plan.clusters):
        kernel, reason = emit_cluster_explained(c)
        entry: dict[str, Any] = {
            "cluster": i,
            "kind": c.kind,
            "root": names.get(c.root._id, f"#{c.root._id}"),
            "size": len(c.members),
            "verdict": "emitted" if kernel is not None else "declined",
        }
        if kernel is not None:
            entry["name"] = kernel.name
            entry["bytes_moved"] = kernel.bytes_moved
        if reason is not None:
            entry["reason"] = reason.as_dict()
        clusters.append(entry)
        for m in c.members:
            member_of[m] = i
            cluster_reason[m] = reason.as_dict() if reason is not None else None
    nodes: list[dict] = []
    for n in toposort(g):
        if not isinstance(n, Apply):
            continue
        op = n.fn.value.name if hasattr(n.fn.value, "name") else repr(n.fn)
        row: dict[str, Any] = {"node": names[n._id], "op": op}
        ci = member_of.get(n._id)
        if ci is not None and cluster_reason[n._id] is None:
            row["decision"] = "fused"
            row["cluster"] = ci
        elif ci is not None:
            row["decision"] = "unfused"
            row["cluster"] = ci
            row["reason"] = cluster_reason[n._id]
        else:
            row["decision"] = "unfused"
            dr = declines.get(n._id)
            row["reason"] = (
                dr.as_dict()
                if dr is not None
                else _reason(
                    "unclassified",
                    "partitioner left this node out without a recorded reason",
                )
            )
        nodes.append(row)
    return {"enabled": True, "clusters": clusters, "nodes": nodes}


def _render_spec(spec: Any) -> Any:
    """A sharding spec as JSON: per-dim lists of mesh axis names,
    ``"scalar"`` for the non-array sentinel, nested lists for tuples."""
    from repro.core.spmd import _SCALAR, _TSpec

    if spec == _SCALAR:
        return "scalar"
    if isinstance(spec, _TSpec):
        return [_render_spec(e) for e in spec.elements]
    if spec is None:
        return None
    return [list(dim) for dim in spec]


def _sharding_section(g: Any, options: Any) -> dict:
    if options.in_specs is None:
        return {
            "verdict": "unsharded",
            "reason": _reason(
                "no-in-specs", "CompileOptions.in_specs not set; SPMD tier inert"
            ),
        }
    import jax

    from repro.parallel import current_mesh_context

    ctx = current_mesh_context()
    if ctx is None or not isinstance(ctx.mesh, jax.sharding.Mesh):
        return {
            "verdict": "unsharded",
            "reason": _reason(
                "no-active-mesh",
                "in_specs configured but no concrete mesh context is active",
            ),
        }
    from repro.core.ir import Apply, toposort
    from repro.core.spmd import SpmdError, propagate

    mesh_axes = dict(ctx.mesh.shape)
    try:
        plan = propagate(g, options.in_specs, mesh_axes)
    except SpmdError as e:
        return {
            "verdict": "fallback-single-device",
            "mesh": mesh_axes,
            "reason": _reason("spmd-error", str(e)),
        }
    names = _node_names(g)
    params = [
        {"param": names[p._id], "spec": _render_spec(plan.spec_of(p))}
        for p in g.parameters
    ]
    nodes = []
    for n in toposort(g):
        if not isinstance(n, Apply):
            continue
        op = n.fn.value.name if hasattr(n.fn.value, "name") else repr(n.fn)
        row = {"node": names[n._id], "op": op, "spec": _render_spec(plan.spec_of(n))}
        post = plan.post.get(n._id)
        if post:
            row["post"] = [[kind, list(axes)] for kind, axes in post]
        nodes.append(row)
    return {
        "verdict": "sharded",
        "mesh": mesh_axes,
        "params": params,
        "nodes": nodes,
        "out_spec": _render_spec(plan.out_spec),
    }


def _graph_cache_tier(base: Any, abstracts: tuple | None, options: Any) -> dict:
    """The graph-tier verdict, probed read-only.  Must run BEFORE the
    pipeline: the explain run itself stores into the graph cache on a
    miss, so probing afterwards could never report ``miss``."""
    gcache = options.graph_cache
    if gcache is None:
        return {"tier": "graph", "verdict": "disabled"}
    if abstracts is None:
        return {
            "tier": "graph",
            "verdict": "unkeyable",
            "reason": _reason("no-abstracts", "argument abstracts unavailable"),
        }
    from repro.core.serialize import SerializeError

    try:
        gkey = gcache.graph_key(
            base, abstracts, opt=options.opt, patterns=options.patterns
        )
    except SerializeError as e:
        return {
            "tier": "graph",
            "verdict": "unkeyable",
            "reason": _reason("serialize-error", str(e)),
        }
    return {
        "tier": "graph",
        "verdict": "graph-hit" if gcache.probe_graph(gkey) else "miss",
        "key": gkey,
    }


def _cache_section(
    graph_tier: dict, g: Any, example_args: tuple, options: Any
) -> list[dict]:
    """Graph-tier (pre-computed) then exec-tier verdicts, read-only
    (``probe``: no stats mutation, no entry load — explain never warms
    the caches it reports on, except through the pipeline run itself)."""
    tiers: list[dict] = [graph_tier]
    pcache = options.program_cache
    if pcache is None:
        tiers.append({"tier": "exec", "verdict": "disabled"})
    else:
        from repro.core.serialize import SerializeError

        try:
            key = pcache.key(g, example_args, fuse=options.fuse)
        except SerializeError as e:
            tiers.append({
                "tier": "exec",
                "verdict": "unkeyable",
                "reason": _reason("serialize-error", str(e)),
            })
        else:
            tiers.append({
                "tier": "exec",
                "verdict": "exec-hit" if pcache.probe(key) else "cold",
                "key": key,
            })
    return tiers


def _loops_section(g: Any, options: Any) -> list[dict]:
    from repro.core.ad import _policy_slots
    from repro.core.ir import Apply, Constant, Graph, toposort
    from repro.core.primitives import LOOP_GRAPH_ARGS

    policy = options.checkpoint_policy
    out: list[dict] = []
    queue = [g]
    seen = {id(g)}
    while queue:
        cur = queue.pop(0)
        names = _node_names(cur)
        for n in toposort(cur):
            if not isinstance(n, Apply):
                continue
            prim = n.fn.value if isinstance(n.fn, Constant) else None
            pname = getattr(prim, "name", None)
            if pname in LOOP_GRAPH_ARGS:
                out.append({
                    "graph": cur.name,
                    "node": names[n._id],
                    "loop": pname,
                    "checkpoint_policy": str(policy),
                    "slots": _policy_slots(policy),
                })
            for a in n.args:
                if (
                    isinstance(a, Constant)
                    and isinstance(a.value, Graph)
                    and id(a.value) not in seen
                ):
                    seen.add(id(a.value))
                    queue.append(a.value)
    return out


def _fallback_section(g: Any, options: Any) -> dict:
    from repro.core.closure import analyze_blockers

    reasons = [r.as_dict() for r in analyze_blockers(g)]
    out = {"lowers": not reasons, "reasons": reasons}
    if options.backend == "vm":
        out["lowers"] = False
        out.setdefault("reasons", []).append(
            _reason("backend-vm", "CompileOptions.backend forces the reference VM")
        )
    return out


def _options_section(options: Any) -> dict:
    return {
        "backend": options.backend,
        "opt": options.opt,
        "fuse": options.fuse,
        "patterns": options.patterns,
        "profile": getattr(options, "profile", False),
        "checkpoint_policy": str(options.checkpoint_policy),
        "in_specs": repr(options.in_specs) if options.in_specs is not None else None,
        "program_cache": options.program_cache is not None,
        "graph_cache": options.graph_cache is not None,
    }


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def explain_graph(
    graph: Any,
    example_args: tuple,
    options: Any = None,
    *,
    name: str | None = None,
    dump_ir: str | None = None,
) -> ExplainReport:
    """Run the real pipeline on ``graph`` at ``example_args`` and explain
    every compile decision.  ``options`` is a
    :class:`~repro.core.api.CompileOptions` (defaults constructed when
    None); ``dump_ir`` writes per-stage IR text into that directory."""
    from repro.core.api import CompileOptions, compile_pipeline
    from repro.core.infer import InferenceError, abstract_of_value
    from repro.obs import trace as obs_trace

    if options is None:
        options = CompileOptions()
    try:
        abstracts = tuple(abstract_of_value(a) for a in example_args)
    except InferenceError:
        abstracts = None

    stages: list[tuple[str, str]] = [("input", format_graph(graph))]

    def snap(stage: str, g: Any) -> None:
        stages.append((stage, format_graph(g)))

    tracer = obs_trace.Tracer()
    with obs_trace.tracing(tracer):
        with obs_trace.span("explain.report", graph=graph.name):
            graph_tier = _graph_cache_tier(graph, abstracts, options)
            g = compile_pipeline(graph, abstracts, options=options, snapshot=snap)
            fusion = _fusion_section(g, options)
            sharding = _sharding_section(g, options)
            cache = _cache_section(graph_tier, g, example_args, options)
            loops = _loops_section(g, options)
            fallback = _fallback_section(g, options)

    data = {
        "program": name or graph.name,
        "signature": [repr(a) for a in abstracts] if abstracts is not None else None,
        "options": _options_section(options),
        "phases_ms": tracer.phase_totals_ms(),
        "fusion": fusion,
        "sharding": sharding,
        "cache": cache,
        "loops": loops,
        "fallback": fallback,
        "ir_stages": [s for s, _ in stages],
    }
    if dump_ir is not None:
        os.makedirs(dump_ir, exist_ok=True)
        paths = []
        for i, (stage, text) in enumerate(stages):
            p = os.path.join(dump_ir, f"{i:02d}-{stage}.ir")
            with open(p, "w", encoding="utf-8") as f:
                f.write(text)
            paths.append(p)
        data["ir_dumps"] = paths
    return ExplainReport(data)


def explain_function(
    fn: Any, example_args: tuple, *, dump_ir: str | None = None
) -> ExplainReport:
    """Explain a :class:`~repro.core.api.MyiaFunction` at a concrete call
    signature — resolves pending AD transforms exactly like
    ``specialize`` does, so the report describes the graph that would
    actually compile."""
    from repro.core.infer import InferenceError, abstract_of_value

    try:
        example = tuple(abstract_of_value(a) for a in example_args)
    except InferenceError:
        example = None
    base = fn._resolved_graph(example) if fn.transforms else fn.graph
    return explain_graph(
        base, example_args, fn.options, name=fn.__name__, dump_ir=dump_ir
    )
