"""Process-wide tracing: nested spans over the compile pipeline and the
serving runtime, exportable to Chrome trace-event JSON (loads directly in
Perfetto / ``chrome://tracing``) or a text phase summary.

The ROADMAP's compile-time item starts with "profile and fix the
superlinear costs" — impossible while timing exists only as scattered,
schema-incompatible counters.  This module gives every pipeline phase
(parse → AD → infer → optimize → closure-elim → fuse → lower → XLA) and
every serve-request lifecycle step one shared, structured instrument:

    tracer = Tracer()
    with tracing(tracer):
        f(x)                        # compile spans recorded as a side effect
    tracer.write_chrome_trace("out.json")   # open in https://ui.perfetto.dev
    print(tracer.phase_summary())

Design rules (same pattern as ``repro.serve.faults``):

* **module-global hook, None-check fast path** — instrumentation sites
  call ``span("optimize")`` unconditionally; when no tracer is armed the
  call is one global read returning a shared singleton null span, and the
  hot paths (worklist pops, decode steps) do **zero** buffer work.  The
  disarmed-overhead test in ``tests/obs/test_trace.py`` pins this.
* **exception safety** — ``span`` is a context manager; the record is
  closed (with an ``error`` attr) even when the body raises, so a failing
  XLA compile still shows up with its true duration.
* **bounded buffer** — the tracer keeps at most ``max_events`` records
  (drops counted in ``dropped``, peak occupancy in ``high_water``), so an
  armed long-running server cannot leak memory through its telemetry.

Span taxonomy: see ``docs/observability.md`` for the full table mapping
each pipeline stage to its span name.
"""

from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Any

__all__ = [
    "MARK_NAMES",
    "NULL_SPAN",
    "SPAN_NAMES",
    "SpanRecord",
    "Tracer",
    "active",
    "mark",
    "span",
    "tracing",
]

#: The span-name taxonomy: every legal ``span(...)`` name, one place.
#:
#: ``check_bench.py`` gates metrics derived from these exact strings
#: (``pipeline_phase_ms.optimize`` descends by span name), so a renamed
#: or ad-hoc span silently un-arms a CI gate.  Both the registry test
#: (``tests/obs/test_trace.py``) and the ``scripts/lint.py`` AST check
#: fail on a ``span("...")`` literal that is not listed here — add new
#: names HERE first, then use them.
SPAN_NAMES = frozenset({
    # compile pipeline (see docs/observability.md for the stage mapping)
    "parse",
    "ad.grad",
    "specialize",
    "compile_pipeline",
    "clone",
    "infer",
    "optimize",
    "opt.rules",
    "opt.inline_wave",
    "opt.defunctionalize",
    "closure.lower_loops",
    "closure.analyze_blockers",
    "fuse.partition",
    "lower",
    "xla.compile",
    "xla.tier0_compile",
    # cache tiers (AOT executables + optimized graphs)
    "cache.lookup",
    "cache.write",
    "cache.graph_lookup",
    "cache.graph_write",
    # serving runtime
    "serve.prefill",
    "serve.decode_step",
    # runtime profiler / explain layer
    "explain.report",
})

#: Every legal ``mark(...)`` (instant event) name — same contract as
#: :data:`SPAN_NAMES` (``serve.engine.request_telemetry`` reconstructs
#: request lifecycles from these exact strings).
MARK_NAMES = frozenset({
    "serve.submit",
    "serve.admitted",
    "serve.first_token",
    "serve.terminal",
})


class SpanRecord:
    """One closed (or still-open) span: name, wall-clock interval, nesting
    depth, thread, and structured attributes.  ``t0``/``t1`` are
    ``time.monotonic()`` timestamps (the same clock the serve engine uses
    for TTFT/deadlines, so span math and engine telemetry agree exactly);
    instant marks have ``t1 == t0``."""

    __slots__ = ("name", "t0", "t1", "depth", "tid", "attrs", "kind")

    def __init__(
        self, name: str, t0: float, depth: int, tid: int, attrs: dict, kind: str = "span"
    ) -> None:
        self.name = name
        self.t0 = t0
        self.t1: float | None = None
        self.depth = depth
        self.tid = tid
        self.attrs = attrs
        self.kind = kind  # "span" (duration) | "mark" (instant) | "counter" (sample)

    @property
    def dur_s(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "t0": self.t0,
            "t1": self.t1,
            "dur_ms": round(self.dur_s * 1e3, 4),
            "depth": self.depth,
            "tid": self.tid,
            "kind": self.kind,
            "attrs": dict(self.attrs),
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"SpanRecord({self.name!r}, dur={self.dur_s * 1e3:.2f}ms, {self.attrs!r})"


class _LiveSpan:
    """Context manager for one armed span.  Closes its record exactly once
    — on normal exit or on raise (the exception type lands in the record's
    ``error`` attr and propagates)."""

    __slots__ = ("_tracer", "_rec")

    def __init__(self, tracer: "Tracer", rec: SpanRecord) -> None:
        self._tracer = tracer
        self._rec = rec

    def set(self, **attrs: Any) -> "_LiveSpan":
        """Attach attributes discovered mid-span (counts, cache verdicts)."""
        self._rec.attrs.update(attrs)
        return self

    @property
    def dur_s(self) -> float:
        """Duration once closed (0.0 while open) — lets a call site feed a
        histogram from the span it already paid the clock reads for."""
        return self._rec.dur_s

    def __enter__(self) -> "_LiveSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        rec = self._rec
        rec.t1 = time.monotonic()
        if exc_type is not None:
            rec.attrs["error"] = exc_type.__name__
        self._tracer._close(rec)
        return False  # never swallow


class _NullSpan:
    """The disarmed fast path: a shared, stateless, reusable no-op span.
    ``span(...)`` returns this singleton without allocating anything."""

    __slots__ = ()

    dur_s = 0.0

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """A bounded in-memory span buffer plus export/aggregation helpers.

    Thread-aware (per-thread depth tracking, a lock only on record append)
    but cheap: one armed span costs two ``time.monotonic()`` calls, one
    small object, and one list append."""

    def __init__(self, max_events: int = 100_000) -> None:
        self.max_events = int(max_events)
        self.events: list[SpanRecord] = []
        self.dropped = 0
        #: peak buffer occupancy — benches record this next to wall time so
        #: a trajectory diff can tell "bench got slower" from
        #: "instrumentation got heavier"
        self.high_water = 0
        self._lock = threading.Lock()
        self._depth = threading.local()

    # -- recording ---------------------------------------------------------
    def span(self, name: str, attrs: dict) -> _LiveSpan:
        depth = getattr(self._depth, "d", 0)
        self._depth.d = depth + 1
        rec = SpanRecord(
            name, time.monotonic(), depth, threading.get_ident(), attrs
        )
        return _LiveSpan(self, rec)

    def _close(self, rec: SpanRecord) -> None:
        self._depth.d = max(getattr(self._depth, "d", 1) - 1, 0)
        self._append(rec)

    def mark(self, name: str, attrs: dict, ts: float | None = None) -> None:
        """Record an instant event (``ts`` defaults to now; pass an
        explicit timestamp to pin the mark to an externally measured
        moment, e.g. the engine's ``submitted_at``)."""
        t = time.monotonic() if ts is None else ts
        rec = SpanRecord(
            name, t, getattr(self._depth, "d", 0), threading.get_ident(), attrs,
            kind="mark",
        )
        rec.t1 = t
        self._append(rec)

    def counter(self, name: str, value: float, ts: float | None = None, **attrs) -> None:
        """Record one sample of a counter track (a time series, e.g. the
        profiler's achieved-GB/s per launch).  Exports as a Chrome ``C``
        (counter) event, which Perfetto renders as a stacked track."""
        t = time.monotonic() if ts is None else ts
        rec = SpanRecord(
            name, t, 0, threading.get_ident(),
            {"value": float(value), **attrs}, kind="counter",
        )
        rec.t1 = t
        self._append(rec)

    def _append(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self.events) >= self.max_events:
                self.dropped += 1
                return
            self.events.append(rec)
            if len(self.events) > self.high_water:
                self.high_water = len(self.events)

    # -- queries -----------------------------------------------------------
    def find(self, name: str) -> list[SpanRecord]:
        return [e for e in self.events if e.name == name]

    def total_s(self, name: str) -> float:
        return sum(e.dur_s for e in self.find(name))

    def phase_totals_ms(self, parent: str | None = None) -> dict[str, float]:
        """Aggregate span durations by name, in ms.

        With ``parent`` given, only spans strictly one level below the
        first ``parent`` span's depth AND inside its interval are counted
        — the direct-child phase breakdown whose sum approximates the
        parent's own duration (the ``pipeline_phase_ms`` bench metric)."""
        out: dict[str, float] = {}
        if parent is None:
            for e in self.events:
                if e.kind == "span":
                    out[e.name] = out.get(e.name, 0.0) + e.dur_s * 1e3
            return {k: round(v, 3) for k, v in out.items()}
        roots = self.find(parent)
        if not roots:
            return {}
        p = roots[0]
        for e in self.events:
            if (
                e.kind == "span"
                and e.depth == p.depth + 1
                and e.t0 >= p.t0
                and (e.t1 or e.t0) <= (p.t1 or float("inf"))
            ):
                out[e.name] = out.get(e.name, 0.0) + e.dur_s * 1e3
        return {k: round(v, 3) for k, v in out.items()}

    # -- exporters ---------------------------------------------------------
    def chrome_trace(self) -> dict:
        """The buffer as a Chrome trace-event JSON object (the ``X``
        complete-event / ``i`` instant-event flavor) — loads unmodified in
        Perfetto (https://ui.perfetto.dev) and ``chrome://tracing``.
        Timestamps are rebased to the earliest event so the viewer opens
        at t=0."""
        if self.events:
            base = min(e.t0 for e in self.events)
        else:
            base = 0.0
        evs = []
        for e in self.events:
            args = {k: _jsonable(v) for k, v in e.attrs.items()}
            row: dict[str, Any] = {
                "name": e.name,
                "cat": e.name.split(".", 1)[0],
                "pid": 1,
                "tid": e.tid % 1_000_000,
                "ts": round((e.t0 - base) * 1e6, 1),
                "args": args,
            }
            if e.kind == "mark":
                row["ph"] = "i"
                row["s"] = "t"  # thread-scoped instant
            elif e.kind == "counter":
                row["ph"] = "C"  # Perfetto counter track: args are series
                row["args"] = {"value": args.get("value", 0.0)}
            else:
                row["ph"] = "X"
                row["dur"] = round(e.dur_s * 1e6, 1)
            evs.append(row)
        return {
            "traceEvents": evs,
            "displayTimeUnit": "ms",
            "otherData": {"dropped": self.dropped, "high_water": self.high_water},
        }

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)

    def phase_summary(self, top: int = 20) -> str:
        """A text flame-ish summary: per-name total / count / mean,
        sorted by total time — the terminal-friendly first look before
        opening the full trace in Perfetto."""
        agg: dict[str, tuple[float, int]] = {}
        for e in self.events:
            if e.kind != "span":
                continue
            tot, n = agg.get(e.name, (0.0, 0))
            agg[e.name] = (tot + e.dur_s, n + 1)
        lines = [f"{'span':<32} {'total_ms':>10} {'count':>7} {'mean_ms':>9}"]
        for name, (tot, n) in sorted(agg.items(), key=lambda kv: -kv[1][0])[:top]:
            lines.append(f"{name:<32} {tot * 1e3:>10.2f} {n:>7} {tot * 1e3 / n:>9.3f}")
        if self.dropped:
            lines.append(f"[{self.dropped} events dropped at max_events={self.max_events}]")
        return "\n".join(lines)


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return repr(v)


# ---------------------------------------------------------------------------
# Module-global arming (the faults.py pattern: None-check fast path)
# ---------------------------------------------------------------------------

_ACTIVE: Tracer | None = None


def active() -> Tracer | None:
    """The armed tracer, or None (the production disarmed state)."""
    return _ACTIVE


@contextlib.contextmanager
def tracing(tracer: Tracer | None):
    """Arm ``tracer`` process-wide for the dynamic extent of the block.
    ``tracing(None)`` is a no-op block, so call sites can thread an
    optional tracer without branching."""
    global _ACTIVE
    prev = _ACTIVE
    if tracer is not None:
        _ACTIVE = tracer
    try:
        yield tracer
    finally:
        _ACTIVE = prev


def span(name: str, **attrs: Any):
    """Open a span named ``name`` on the armed tracer.

    Disarmed, this is the hot-path fast exit: one global read, return the
    shared :data:`NULL_SPAN` — no allocation, no clock read, no buffer
    work (pinned by the disarmed-overhead test)."""
    t = _ACTIVE
    if t is None:
        return NULL_SPAN
    return t.span(name, attrs)


def mark(name: str, ts: float | None = None, **attrs: Any) -> None:
    """Record an instant event on the armed tracer (no-op disarmed)."""
    t = _ACTIVE
    if t is None:
        return
    t.mark(name, attrs, ts=ts)
