"""Unified metrics: counters / gauges / fixed-bucket histograms behind one
flat, dotted-key ``snapshot()`` schema.

The repo grew three incompatible counter surfaces — ``OptStats.as_dict()``
(nested rule-hit dicts), ``CacheStats.as_dict()`` (flat but its own
names), and the serve engine's ad-hoc stats dict — so every bench writer
invented its own JSON keys and ``check_bench.py`` had to know all of
them.  This module is the single schema:

    snapshot(opt=opt_stats, cache=cache.stats, serve=engine_stats)
    # -> {"opt.rule_hits.gadd_zero": 31, "opt.inlined_calls": 12,
    #     "cache.hits": 4, "serve.statuses.ok": 8, ...}

Rules of the schema:

* keys are dotted paths, prefix = the subsystem argument name,
* every leaf is a JSON scalar (int / float / str / None); nested dicts
  flatten into further dotted segments; lists of scalars stay lists,
* anything exposing ``as_dict()`` (OptStats, CacheStats) is absorbed
  as-is — the legacy surfaces keep working and gain one canonical view.

Histograms use fixed bucket boundaries (no deps, no reservoir): ``p50``/
``p90``/``p99`` are upper-bound estimates from the first bucket whose
cumulative count crosses the quantile — exactly the Prometheus
``histogram_quantile`` contract, coarse but monotone and mergeable.
"""

from __future__ import annotations

import math
from typing import Any, Iterable

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "flatten",
    "snapshot",
    "to_prometheus",
]

#: default bucket upper bounds for latency histograms, in milliseconds —
#: ~log-spaced from sub-ms decode steps to multi-second cold compiles
DEFAULT_MS_BUCKETS = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0, 30000.0,
)


class Counter:
    """A monotone counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """A last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram with count/sum/min/max and quantile bounds.

    ``buckets`` are upper bounds (an implicit +inf bucket is appended).
    ``observe`` is O(log B) (bisect); no per-sample storage, so an armed
    serve engine can observe every decode step forever in O(B) memory."""

    __slots__ = ("buckets", "counts", "count", "sum", "min", "max")

    def __init__(self, buckets: Iterable[float] = DEFAULT_MS_BUCKETS) -> None:
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.counts = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        import bisect

        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float | None:
        """Upper bound of the bucket where the ``q``-quantile falls (the
        true max for the overflow bucket), or None when empty."""
        if not self.count:
            return None
        target = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= target:
                return self.buckets[i] if i < len(self.buckets) else self.max
        return self.max

    def as_dict(self) -> dict:
        if not self.count:
            return {"count": 0}
        return {
            "count": self.count,
            "sum": round(self.sum, 4),
            "mean": round(self.sum / self.count, 4),
            "min": round(self.min, 4),
            "max": round(self.max, 4),
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Named metrics, created on first touch.

    One registry per subsystem instance (a serve engine, a bench run);
    ``snapshot(m=registry)`` flattens it into the shared schema."""

    def __init__(self) -> None:
        self._metrics: dict[str, Any] = {}

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, buckets: Iterable[float] = DEFAULT_MS_BUCKETS) -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Histogram(buckets)
        elif not isinstance(m, Histogram):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, not Histogram")
        return m

    def _get(self, name: str, cls: type) -> Any:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls()
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, not {cls.__name__}")
        return m

    def as_dict(self) -> dict:
        out: dict[str, Any] = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, (Counter, Gauge)):
                out[name] = m.value
            else:
                out[name] = m.as_dict()
        return out


def flatten(value: Any, prefix: str = "") -> dict[str, Any]:
    """Flatten nested dicts into dotted keys; scalars and scalar lists are
    leaves; objects exposing ``as_dict()`` are absorbed through it."""
    if hasattr(value, "as_dict"):
        value = value.as_dict()
    out: dict[str, Any] = {}
    if isinstance(value, dict):
        for k, v in value.items():
            key = f"{prefix}.{k}" if prefix else str(k)
            out.update(flatten(v, key))
        return out
    if isinstance(value, (list, tuple)):
        out[prefix] = [x if _scalar(x) else repr(x) for x in value]
        return out
    out[prefix] = value if _scalar(value) else repr(value)
    return out


def _scalar(v: Any) -> bool:
    return v is None or isinstance(v, (str, int, float, bool))


def _prom_name(name: str) -> str:
    """A legal Prometheus metric name: dotted keys become underscores,
    anything outside ``[a-zA-Z0-9_:]`` is replaced, leading digits get a
    prefix."""
    out = "".join(c if c.isalnum() or c in "_:" else "_" for c in name)
    if not out or out[0].isdigit():
        out = "m_" + out
    return out


def to_prometheus(registry: "MetricsRegistry | None" = None, *, extra: Any = None) -> str:
    """Prometheus text exposition (format 0.0.4) of ``registry`` plus an
    optional ``extra`` source of scalars (a dict / anything ``flatten``
    absorbs, e.g. ``snapshot(serve=engine.stats())``).

    Counters and gauges emit one sample each; histograms emit the full
    ``_bucket{le="..."}`` cumulative series (including ``+Inf``) plus
    ``_sum`` and ``_count`` — exactly what ``histogram_quantile`` needs.
    Non-numeric extra leaves are skipped (exposition is numbers-only)."""
    lines: list[str] = []
    if registry is not None:
        for name, m in sorted(registry._metrics.items()):
            pname = _prom_name(name)
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {m.value}")
            else:
                lines.append(f"# TYPE {pname} histogram")
                acc = 0
                for ub, c in zip(m.buckets, m.counts):
                    acc += c
                    lines.append(f'{pname}_bucket{{le="{ub:g}"}} {acc}')
                lines.append(f'{pname}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{pname}_sum {m.sum}")
                lines.append(f"{pname}_count {m.count}")
    if extra is not None:
        for key, v in sorted(flatten(extra).items()):
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue  # exposition carries numbers only
            pname = _prom_name(key)
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {v}")
    return "\n".join(lines) + ("\n" if lines else "")


def snapshot(**sources: Any) -> dict[str, Any]:
    """The one metrics surface: flatten every named source into a single
    flat dotted-key dict.

        snapshot(opt=OptStats(), cache=CacheStats(), serve=engine.stats())

    Sources may be ``OptStats`` / ``CacheStats`` / ``MetricsRegistry``
    (anything with ``as_dict()``), plain dicts, or None (skipped) —
    benches and ``check_bench.py`` read this instead of each subsystem's
    private counter names."""
    out: dict[str, Any] = {}
    for prefix, src in sorted(sources.items()):
        if src is None:
            continue
        out.update(flatten(src, prefix))
    return out
