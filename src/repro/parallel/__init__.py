"""Parallelism layer: logical-axis sharding rules and the mesh context.

Models never mention physical mesh axes.  They call
:func:`constrain` with *logical* axis names ("batch", "seq", "heads",
"embed", "mlp", "experts", "vocab", "kv_seq", …); the active
:class:`MeshContext` maps logical → physical ("data"/"model"/"pod") and
inserts ``with_sharding_constraint``.  Without an active context (CPU
smoke tests) everything is a no-op, so the same model code runs on one
device and on a 512-chip mesh.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "MeshContext",
    "mesh_context",
    "current_mesh_context",
    "constrain",
    "logical_to_spec",
    "DEFAULT_RULES",
    "named_sharding",
    "abstract_mesh",
    "shard_map",
]


def abstract_mesh(axis_sizes, axis_names):
    """Device-less mesh for structural sharding checks, across jax's
    ``AbstractMesh`` signature variants: one tuple of ``(name, size)``
    pairs (e.g. jax 0.4.37) vs. two positionals ``(sizes, names)``."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


def _shard_map():
    """``jax.shard_map`` moved between jax versions (experimental →
    top-level); resolve whichever this jax provides."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map
    from jax.experimental.shard_map import shard_map as sm

    return sm


shard_map = _shard_map()

#: logical axis → physical mesh axis (or tuple of axes, or None=replicated).
#: ``batch`` spans the pure-data axes; model-parallel dims map to "model".
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,  # activations: sequence replicated by default
    "kv_seq": "model",  # long-context decode: KV cache sharded on sequence
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "vocab": "model",
    "fsdp": "data",  # parameter shard axis for ZeRO/FSDP-style setups
    "conv": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "ssm_proj": "model",
    "image_seq": None,
}


class MeshContext:
    """An active mesh + logical-axis rules."""

    def __init__(self, mesh: Mesh, rules: Mapping[str, Any] | None = None) -> None:
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def spec(self, logical: Sequence[str | None], shape: Sequence[int] | None = None) -> P:
        """logical → PartitionSpec.  With ``shape``, axes that do not
        divide their dim (batch=1 on a 16-way axis, kv=8 on model=16)
        fall back to replication, and no mesh axis is used twice."""
        sizes = dict(self.mesh.shape)
        used: set[str] = set()
        axes = []
        for i, name in enumerate(logical):
            phys = None if name is None else self.rules.get(name)
            if phys is None:
                axes.append(None)
                continue
            cand = phys if isinstance(phys, tuple) else (phys,)
            cand = tuple(a for a in cand if a in sizes and a not in used)
            if not cand:
                axes.append(None)
                continue
            if shape is not None:
                total = 1
                for a in cand:
                    total *= sizes[a]
                if shape[i] % total != 0:
                    axes.append(None)
                    continue
            used.update(cand)
            axes.append(cand if len(cand) > 1 else cand[0])
        return P(*axes)

    def sharding(self, logical: Sequence[str | None], shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


_STATE = threading.local()


def current_mesh_context() -> MeshContext | None:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh | None, rules: Mapping[str, Any] | None = None):
    """Activate (mesh, rules) for model code; None deactivates (no-op mode)."""
    prev = current_mesh_context()
    _STATE.ctx = MeshContext(mesh, rules) if mesh is not None else None
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """``with_sharding_constraint(x, logical axes)`` under the active mesh
    context; identity when no context is active.  Non-divisible dims fall
    back to replication (checked against x.shape)."""
    ctx = current_mesh_context()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding(logical, x.shape))


def logical_to_spec(logical: Sequence[str | None]) -> P:
    ctx = current_mesh_context()
    if ctx is None:
        return P()
    return ctx.spec(logical)


def named_sharding(logical: Sequence[str | None]) -> NamedSharding | None:
    ctx = current_mesh_context()
    if ctx is None:
        return None
    return ctx.sharding(logical)
