"""Train a small LM with gradients produced by the PAPER'S AD — the Myia
closure-based source transformation — and verify they match jax.grad.

The model (embedding → tanh-MLP blocks → logits, written in the pure
Myia Python subset) is differentiated by ``repro.core`` ST AD, compiled
through the pipeline, and stepped with the repro AdamW optimizer.  This
is the "Myia end-to-end" path of DESIGN.md §4: the same technique jax
uses, implemented from the paper.

    PYTHONPATH=src python examples/train_lm_myia.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api as myia
import repro.core.primitives as P
from repro.data import DataConfig, SyntheticLM
from repro.optim import OptConfig, make_optimizer

VOCAB, DIM, SEQ, BATCH = 256, 64, 32, 8

take, tanh, reduce_sum = P.take, P.tanh, P.reduce_sum
matmul, one_hot, log = P.matmul, P.one_hot, P.log
exp, reduce_max = P.exp, P.reduce_max


def lm_loss(emb, w1, w2, wout, tokens, labels):
    # embedding lookup (gather) — (B,S,D)
    h = take(emb, tokens)
    h = tanh(matmul(h, w1))
    h = h + tanh(matmul(h, w2))  # residual block
    logits = matmul(h, wout)  # (B,S,V)
    # stable log-softmax cross-entropy, in the Myia subset
    m = reduce_max(logits, (2,), True)
    z = logits - m
    lse = log(reduce_sum(exp(z), (2,), True)) + m
    gold = reduce_sum(logits * one_hot(labels, VOCAB, np.float32), (2,), True)
    return reduce_sum(lse - gold, (0, 1, 2), False) / (BATCH * SEQ)


def main():
    rng = np.random.default_rng(0)
    params = [
        jnp.asarray(rng.standard_normal((VOCAB, DIM)) * 0.05, jnp.float32),
        jnp.asarray(rng.standard_normal((DIM, DIM)) * 0.1, jnp.float32),
        jnp.asarray(rng.standard_normal((DIM, DIM)) * 0.1, jnp.float32),
        jnp.asarray(rng.standard_normal((DIM, VOCAB)) * 0.1, jnp.float32),
    ]
    ds = SyntheticLM(DataConfig(vocab=VOCAB, seq_len=SEQ, global_batch=BATCH))

    # The paper's pipeline: parse → closure-based ST AD → optimize → XLA
    vag = myia.value_and_grad(lm_loss, wrt=(0, 1, 2, 3))

    # one-time check: Myia gradients == jax gradients
    b0 = ds.batch(0)
    toks, labs = jnp.asarray(b0["tokens"]), jnp.asarray(b0["labels"])
    _, g_myia = vag(*params, toks, labs)
    g_jax = jax.grad(lambda *p: lm_loss(*p, toks, labs), argnums=(0, 1, 2, 3))(*params)
    for a, b in zip(g_myia, g_jax):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
    print("✓ Myia ST-AD gradients match jax.grad on the LM loss")

    opt = make_optimizer(OptConfig(lr=3e-3, warmup_steps=20, total_steps=200, weight_decay=0.0))
    state = opt.init(params)
    losses = []
    for step in range(200):
        b = ds.batch(step)
        loss, grads = vag(*params, jnp.asarray(b["tokens"]), jnp.asarray(b["labels"]))
        params, state, _ = opt.update(list(grads), state, params, jnp.int32(step))
        losses.append(float(loss))
        if step % 25 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}")
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss {first:.4f} → {last:.4f} (Myia-AD training works)")
    assert last < first * 0.8
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
