"""Batched serving example: prefill + cached decode across architecture
families (dense sliding-window, MoE, hybrid Mamba+attention) — the same
``prefill``/``decode_step`` the decode_32k / long_500k dry-run cells
lower at production shape — followed by the Myia serving runtime
(``repro.serve``): bucketed continuous batching over the compiled decode
graph with a persistent AOT program cache (run the script twice with
``MYIA_SERVE_CACHE=dir`` to see a warm, zero-recompile start).

    PYTHONPATH=src python examples/serve_batch.py
"""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill


def serve(arch: str, batch=4, prompt_len=24, gen=16):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)
    max_len = prompt_len + gen

    extras = {}
    if cfg.enc_dec:
        extras["enc_frames"] = jnp.asarray(
            rng.standard_normal((batch, 48, cfg.d_model)), cfg.cdtype
        )
    if cfg.cross_attn_period and not cfg.enc_dec:
        extras["image_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.num_image_tokens, cfg.d_model)), cfg.cdtype
        )

    prefill_jit = jax.jit(lambda p, t: prefill(cfg, p, t, max_len, batch_extras=extras))
    decode_jit = jax.jit(lambda p, tok, pos, c: decode_step(cfg, p, tok, pos, c))

    logits, caches = prefill_jit(params, prompts)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.monotonic()
    out = [tok]
    for i in range(gen - 1):
        logits, caches = decode_jit(params, tok, jnp.int32(prompt_len + i), caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    tok.block_until_ready()
    rate = (gen - 1) * batch / (time.monotonic() - t0)
    gen_tokens = np.stack([np.asarray(t) for t in out], 1)
    print(f"{arch:22s} batch={batch} gen={gen}: {rate:7.1f} tok/s   sample: {gen_tokens[0][:8].tolist()}")


def serve_myia_engine(n_requests=6, gen=12):
    """The serving runtime: mixed-length requests over 2 slots — buckets
    bound the compiled-specialization count, the AOT cache makes the
    compilations durable, and every stream matches the full-prefix
    oracle bit-for-bit."""
    from repro.core import ProgramCache
    from repro.serve import ServeEngine, ServeLMDims, init_serve_params, oracle_generate

    dims = ServeLMDims(vocab=128, d_model=32)
    params = init_serve_params(dims, jax.random.PRNGKey(0))
    cache_dir = os.environ.get("MYIA_SERVE_CACHE") or tempfile.mkdtemp(prefix="progcache-")
    engine = ServeEngine(
        dims, params, n_slots=2, min_bucket=16, program_cache=ProgramCache(cache_dir)
    )
    rng = np.random.default_rng(0)
    submitted = []
    for i in range(n_requests):
        prompt = rng.integers(0, dims.vocab, 4 + 3 * i).tolist()
        submitted.append((engine.submit(prompt, gen), prompt))
    t0 = time.monotonic()
    results = engine.run()
    wall = time.monotonic() - t0
    stats = engine.stats()
    print(
        f"myia engine: {n_requests} reqs, buckets {stats['buckets_in_use']}, "
        f"compilations {stats['total_compilations']} (floor {stats['compilation_floor']}), "
        f"{stats['tokens_generated'] / max(wall, 1e-9):6.1f} tok/s, "
        f"cache {stats['program_cache']['hits']}h/{stats['program_cache']['misses']}m"
    )
    rid, prompt = submitted[0]
    assert results[rid]["tokens"] == oracle_generate(dims, params, prompt, gen)
    print(f"   sample (== full-prefix oracle): {results[rid]['tokens'][:8]}")


if __name__ == "__main__":
    for arch in ("gemma3-1b", "grok-1-314b", "jamba-v0.1-52b", "mamba2-370m"):
        serve(arch)
    print()
    serve_myia_engine()
    print("\n(reduced configs on CPU; production shapes are exercised by the dry-run)")
