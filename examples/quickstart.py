"""Quickstart: the paper's toolchain in five minutes.

Demonstrates exactly what the paper promises the IR can do that dataflow
graphs cannot (§3): recursion, higher-order functions, closures — and
closure-based ST AD through all of them, including reverse-over-reverse.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import api as myia
import repro.core.primitives as P

tanh = P.tanh  # Myia primitives are plain callables inside @myia code


# -- 1. compile a function through the pipeline ------------------------------


@myia.myia
def f(x):
    return x ** 3 + 2.0 * x


print("f(2.0) =", f(2.0), "(expected 12.0)")


# -- 2. gradients via closure-based source transformation --------------------

def g(x):
    return x ** 3 + 2.0 * x

df = myia.grad(g)
print("g'(2.0) =", df(2.0), "(expected 3·4+2 = 14.0)")

# reverse-over-reverse: the transform applies to its own output (§3.2)
ddf = myia.grad(myia.grad(g))
print("g''(2.0) =", ddf(2.0), "(expected 6·2 = 12.0)")


# -- 3. recursion — "some models are more naturally expressed using
#       recursion than loops" (§1) ------------------------------------------

def power_rec(x, n):
    if n == 0:
        return 1.0
    return x * power_rec(x, n - 1)


@myia.myia
def use_recursion(x):
    return power_rec(x, 5)


print("x^5 at 2:", use_recursion(2.0), "(expected 32)")
print("d/dx x^5 at 2:", myia.grad(use_recursion)(2.0), "(expected 80)")


# -- 4. higher-order functions + closures ------------------------------------

def compose_twice(fn, x):
    return fn(fn(x))


@myia.myia
def hof(x):
    def scaled_tanh(v):
        return tanh(v) * x  # closes over x — a real closure

    return compose_twice(scaled_tanh, x)


print("hof(0.5) =", hof(0.5))
print("hof'(0.5) =", myia.grad(hof)(0.5), "(gradient flows through the closure's free variable)")


# -- 5. arrays: same pipeline, and the gradient matches jax ------------------

def loss(w, x):
    h = tanh(x @ w)
    return P.reduce_sum(h * h, (0, 1), False)


w = jax.random.normal(jax.random.PRNGKey(0), (8, 8))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
g_myia = myia.grad(loss)(w, x)
g_jax = jax.grad(lambda w_: jnp.sum(jnp.tanh(x @ w_) ** 2))(w)
print("myia grad == jax grad:", bool(jnp.allclose(g_myia, g_jax, atol=1e-5)))
