"""End-to-end driver (deliverable b): train a ~100M-parameter LM for a
few hundred steps through the FULL framework stack — config → model →
data pipeline → AdamW → fault-tolerant loop with checkpointing — on
whatever devices exist (CPU here; the same code runs under the
production mesh via repro.launch.train).

    PYTHONPATH=src python examples/train_e2e.py --steps 300

The config is a scaled gemma3-family model (~100M params).  Expect
CPU wall-time of a few seconds/step; pass --steps 20 for a quick look.
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, SyntheticLM
from repro.distributed import make_train_state_fn, make_train_step
from repro.models import LayerSpec, ModelConfig
from repro.optim import OptConfig, make_optimizer
from repro.runtime import TrainLoopConfig, train_loop
import jax


def config_100m() -> ModelConfig:
    # ~103M params: 8 layers (5 local : 1 global pattern), d=512, vocab 32k
    return ModelConfig(
        name="gemma3-100m",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=2,
        d_ff=2048,
        vocab=32768,
        layer_period=(LayerSpec(attn_kind="local"),) * 5 + (LayerSpec(attn_kind="global"),),
        local_window=256,
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args(argv)

    cfg = config_100m()
    n_params = None
    opt = make_optimizer(
        OptConfig(lr=6e-4, warmup_steps=args.steps // 10, total_steps=args.steps)
    )
    ds = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))

    init_fn = make_train_state_fn(cfg, opt)
    step_jit = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))

    t0 = time.monotonic()

    def on_step(step, metrics):
        if step % 20 == 0:
            print(
                f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                f"({time.monotonic()-t0:.0f}s)",
                flush=True,
            )

    res = train_loop(
        TrainLoopConfig(
            total_steps=args.steps,
            checkpoint_every=max(50, args.steps // 4),
            checkpoint_dir=args.ckpt_dir,
        ),
        step_jit,
        init_fn,
        lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()},
        on_step=on_step,
    )
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(res.state["params"]))
    first, last = np.mean(res.losses[:10]), np.mean(res.losses[-10:])
    toks = args.steps * args.batch * args.seq
    dt = time.monotonic() - t0
    print(
        f"\n{n_params/1e6:.1f}M params · {args.steps} steps · loss {first:.3f} → {last:.3f}"
        f" · {toks/dt:.0f} tok/s · {res.restarts} restarts"
    )
    assert last < first
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
