#!/usr/bin/env bash
# CI entry point: lint → tier-1 tests → serve smoke + chaos corpus →
# quick benchmarks → bench gate.
#
#   scripts/ci.sh                 # everything (the CI "full" job)
#   SKIP_SLOW=1 SKIP_BENCH=1 scripts/ci.sh   # the CI "fast" job (minutes)
#
# The quick benchmark run rewrites the repo-root BENCH_*.json trajectory
# files (compile time, AD overhead, fusion, spmd) and scripts/check_bench.py
# diffs them against the committed trajectory — >25% regression in compile
# time, AD overhead ratio, or fused/sharded launch counts fails the build.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Run an inline python script through a real temp file instead of stdin:
# parse_function needs inspect.getsource, which cannot read a `python -`
# heredoc (OSError: could not get source code).
pyfile() {
  local tmp rc=0
  tmp="$(mktemp "${TMPDIR:-/tmp}/ci-inline-XXXXXX.py")"
  cat > "$tmp"
  python "$tmp" || rc=$?
  rm -f "$tmp"
  return "$rc"
}

echo "== lint (ruff via pyproject; in-repo fallback when unavailable) =="
python scripts/lint.py

echo "== tier-1 tests (fast suite) =="
python -m pytest -x -q -m "not slow"

echo "== serve engine smoke (tmpdir AOT cache: cold run compiles, warm run hits) =="
python - <<'PY'
import tempfile
import jax, numpy as np
from repro.core.jax_backend import ProgramCache
from repro.serve import ServeEngine, ServeLMDims, init_serve_params

dims = ServeLMDims(vocab=48, d_model=8, d_hidden=16)
params = init_serve_params(dims, jax.random.PRNGKey(0))
with tempfile.TemporaryDirectory(prefix="ci-progcache-") as d:
    outs = []
    for leg in ("cold", "warm"):
        cache = ProgramCache(d)
        eng = ServeEngine(dims, params, n_slots=2, min_bucket=16, program_cache=cache)
        rng = np.random.default_rng(0)
        rids = [eng.submit(rng.integers(0, dims.vocab, n).tolist(), m)
                for n, m in [(5, 6), (9, 4)]]
        res = eng.run()
        outs.append({r: res[r]["tokens"] for r in rids})
        print(f"  {leg}: {cache.stats.as_dict()}")
        if leg == "cold":
            assert cache.stats.misses > 0 and cache.stats.puts > 0
        else:
            assert cache.stats.hits > 0, "warm run found no cached programs"
            assert cache.stats.misses == 0 and cache.stats.xla_compiles == 0
    assert outs[0] == outs[1], "warm serve diverged from cold serve"
print("  serve smoke OK")
PY

echo "== graph-cache smoke (cold run optimizes + stores, warm run skips optimize) =="
pyfile <<'PY'
import tempfile
import jax.numpy as jnp
from repro.core import build_grad_graph, parse_function
from repro.core.api import CompileOptions, compile_pipeline
from repro.core.infer import abstract_of_value
from repro.core.jax_backend import ProgramCache
from repro.core.primitives import reduce_sum as _rsum, tanh as _tanh
from repro.core.serialize import dumps
from repro.obs import trace as obs_trace

def _loss(w, x):
    h = _tanh(x @ w)
    return _rsum(h * h, None, False)

g = build_grad_graph(build_grad_graph(parse_function(_loss), 0), 0)
ex = tuple(abstract_of_value(a) for a in
           (jnp.ones((4, 4), jnp.float32), jnp.ones((2, 4), jnp.float32)))
with tempfile.TemporaryDirectory(prefix="ci-graphcache-") as d:
    pc = ProgramCache(d)
    opts = CompileOptions(graph_cache=pc)
    cold = compile_pipeline(g, ex, options=opts)
    assert pc.stats.graph_misses == 1 and pc.stats.graph_puts == 1, pc.stats.as_dict()
    tr = obs_trace.Tracer()
    with obs_trace.tracing(tr):
        warm = compile_pipeline(g, ex, options=opts)
    assert pc.stats.graph_hits == 1, pc.stats.as_dict()
    phases = tr.phase_totals_ms("compile_pipeline")
    assert "optimize" not in phases, f"warm run still optimized: {phases}"
    assert dumps(warm, names=False) == dumps(cold, names=False)
    print(f"  graph-cache smoke OK (warm phases: {sorted(phases)})")
PY

echo "== explain + profile smoke (every job: reports stay structured, profiler stays armed) =="
pyfile <<'PY'
import jax.numpy as jnp
from repro.core.api import CompileOptions, grad
from repro.core.primitives import reduce_sum as _rsum, tanh as _tanh
from repro.obs import Profiler, profiling
from repro.obs.explain import ExplainReport

def _loss(w1, w2, x):
    h = _tanh(x @ w1)
    return _rsum(_tanh(h @ w2), None, False)

opts = CompileOptions(fuse=True, profile=True)
df = grad(_loss, (0, 1), options=opts)
args = (jnp.ones((8, 8), jnp.float32) * 0.1,
        jnp.ones((8, 8), jnp.float32) * 0.1,
        jnp.ones((4, 8), jnp.float32))

# explain: every cluster and every node carries a structured verdict,
# and the report survives a JSON round trip
rep = df.explain(*args)
rt = ExplainReport.from_json(rep.to_json())
assert rt.as_dict() == rep.as_dict(), "explain report not JSON-round-trippable"
fus = rep["fusion"]
assert fus["enabled"] and fus["clusters"], "grad corpus program produced no clusters"
for c in fus["clusters"]:
    assert c["verdict"] in ("emitted", "declined"), c
for n in fus["nodes"]:
    assert n["decision"] in ("fused", "unfused"), n
    if n["decision"] == "unfused":
        assert isinstance(n.get("reason"), dict) and "kind" in n["reason"], n

# profile: armed run of the fused workload lands on the roofline scale
df(*args)  # warm: compile outside the profiled window
prof = Profiler()
with profiling(prof):
    for _ in range(3):
        df(*args)
assert prof.sites, "armed profiler recorded no launches"
agg = prof.aggregate()
fr = agg["roofline_fraction"]
assert fr is not None and 0.0 < fr <= 1.0, f"roofline_fraction out of range: {fr}"
print(f"  explain+profile smoke OK ({len(fus['clusters'])} clusters, "
      f"{agg['calls']} launches, roofline_fraction {fr})")
PY

echo "== chaos corpus (deterministic fault injection, fixed seed) =="
# part of every job, fast included: the chaos tests use explicit
# fire-at-step fault plans (seed 0xC0FFEE feeds only the garbage bytes),
# so this run is deterministic — a flake here is a real robustness bug.
python -m pytest -q -m "not slow" tests/serve/test_chaos.py

if [ "${SKIP_SLOW:-0}" != "1" ]; then
  echo "== slow suite (multi-device subprocess corpus) =="
  python -m pytest -x -q -m slow
fi

if [ "${SKIP_BENCH:-0}" != "1" ]; then
  echo "== quick benchmarks (BENCH_*.json trajectories) =="
  python -m benchmarks.run --quick
  echo "== bench regression gate =="
  python scripts/check_bench.py
  echo "== traced bench (Perfetto trace uploaded via artifacts/bench/) =="
  # one traced --quick rerun of the higher-order bench: the trace lands in
  # artifacts/bench/ which ci.yml already uploads, so every full CI run
  # leaves a loadable compile-pipeline profile next to the BENCH numbers
  python -m benchmarks.run --quick --only higher_order \
    --trace artifacts/bench/trace_higher_order.json
  echo "== runtime profile artifact (per-launch attribution + counter tracks) =="
  # armed profiler over the fused MLP adjoint: the attribution JSON and a
  # Perfetto trace with GB/s counter tracks land in artifacts/bench/,
  # which ci.yml uploads — every full run leaves a runtime profile next
  # to the compile profile above
  python - <<'PY'
import json, os
import jax
from repro.core import build_grad_graph, parse_function
from repro.core.api import compile_pipeline
from repro.core.infer import abstract_of_value
from repro.core.lowering import lower_graph
from benchmarks.bench_fusion import _two_layer
from repro.obs import Profiler, Tracer, profiling

k = jax.random.PRNGKey
args = (jax.random.normal(k(0), (256, 256)) * 0.1,
        jax.random.normal(k(1), (256, 256)) * 0.1,
        jax.random.normal(k(2), (32, 256)))
g = compile_pipeline(build_grad_graph(parse_function(_two_layer), (0, 1)),
                     tuple(abstract_of_value(a) for a in args))
fn = lower_graph(g, fuse=True, profile=True)
jax.block_until_ready(fn(*args))  # warm
prof = Profiler()
with profiling(prof):
    for _ in range(10):
        fn(*args)
os.makedirs("artifacts/bench", exist_ok=True)
with open("artifacts/bench/profile_fusion.json", "w") as f:
    json.dump(prof.as_dict(), f, indent=1)
tr = Tracer()
prof.export_counters(tr)
tr.write_chrome_trace("artifacts/bench/trace_profile_fusion.json")
print(prof.attribution_table(top=10))
print("  wrote artifacts/bench/profile_fusion.json + trace_profile_fusion.json")
PY
fi
