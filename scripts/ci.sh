#!/usr/bin/env bash
# CI entry point: tier-1 test suite + quick benchmark refresh.
#
#   scripts/ci.sh            # everything
#   SKIP_BENCH=1 scripts/ci.sh   # tests only
#
# The quick benchmark run rewrites the repo-root BENCH_*.json trajectory
# files (compile time, AD overhead, fusion), so every CI pass leaves a
# perf data point for the next PR to diff against.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [ "${SKIP_BENCH:-0}" != "1" ]; then
  echo "== quick benchmarks (BENCH_*.json trajectories) =="
  python -m benchmarks.run --quick
fi
