#!/usr/bin/env bash
# CI entry point: lint → tier-1 tests → quick benchmarks → bench gate.
#
#   scripts/ci.sh                 # everything (the CI "full" job)
#   SKIP_SLOW=1 SKIP_BENCH=1 scripts/ci.sh   # the CI "fast" job (minutes)
#
# The quick benchmark run rewrites the repo-root BENCH_*.json trajectory
# files (compile time, AD overhead, fusion, spmd) and scripts/check_bench.py
# diffs them against the committed trajectory — >25% regression in compile
# time, AD overhead ratio, or fused/sharded launch counts fails the build.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint (ruff via pyproject; in-repo fallback when unavailable) =="
python scripts/lint.py

echo "== tier-1 tests (fast suite) =="
python -m pytest -x -q -m "not slow"

if [ "${SKIP_SLOW:-0}" != "1" ]; then
  echo "== slow suite (multi-device subprocess corpus) =="
  python -m pytest -x -q -m slow
fi

if [ "${SKIP_BENCH:-0}" != "1" ]; then
  echo "== quick benchmarks (BENCH_*.json trajectories) =="
  python -m benchmarks.run --quick
  echo "== bench regression gate =="
  python scripts/check_bench.py
fi
