#!/usr/bin/env python
"""CI bench gate: diff fresh BENCH_*.json against the committed trajectory.

``benchmarks/run.py --quick`` rewrites the repo-root trajectory files;
this script compares them with the versions committed at HEAD
(``git show HEAD:<file>``) and fails CI when a guarded metric regressed
by more than ``--tol`` (default 25%).

Guarded metrics (rows matched by workload/signature/mesh key):

* ``BENCH_compile.json``   — ``compile_call_ms`` (compile time; lower is
  better, with a small absolute floor so sub-noise wiggle never trips)
  and ``vm_fallbacks`` (closure-elimination tier: corpus graphs failing
  ``try_lower`` — deterministic, and HARD-pinned at 0: the fresh value is
  gated absolutely, baseline or not, see ``HARD_CEILINGS``),
* ``BENCH_higher_order.json`` — ``vm_fallback`` per workload (grad-of-grad
  and the MLP HVP must stay on the lowered path) + floored ``steady_us``
  + the compile-time trajectory: floored ``pipeline_ms``,
  ``pipeline_phase_ms.optimize`` (dotted paths descend into nested row
  dicts) and ``pipeline_phase_total_ms`` all may only fall, and
  ``graph_cache_hit_rate`` (the optimized-graph tier's warm lookup,
  deterministically 1.0) may only rise,
* ``BENCH_ad_overhead.json`` — ``st_over_jax`` (the AD overhead ratio),
* ``BENCH_fusion.json``    — ``launches_after`` (fused launch counts;
  deterministic, any increase is a real partitioner regression), plus the
  runtime-profiler trajectory on the MLP adjoint: ``fused_over_unfused``
  (the fused/unfused wall ratio, noise-floored, may only fall) and
  ``roofline_fraction`` (achieved fraction of the 819 GB/s HBM model,
  noise-floored, may only RISE — fusion v2's acceptance metric),
* ``BENCH_spmd.json``      — ``launches_fused`` and the collective count
  ``n_psum`` + ``n_all_gather`` (a propagation regression shows up as
  extra communication before it shows up on a wall clock),
* ``BENCH_serve.json``     — ``compilations`` / ``xla_compiles`` at the
  bucket-derived floor (the serving runtime compiles per bucket, never
  per generated length; deterministic, may only fall),
  ``cache_hit_rate`` (may only RISE: the warm row losing hits means the
  AOT program cache key or serialization went unstable), and the
  robustness row: ``timeouts`` / ``corrupt_entries`` / ``vm_fallbacks``
  / ``budget_exhausted`` are deterministic under the fixed fault seed
  and may only fall, while ``completed_pct`` may only rise — the chaos
  workload finishing below 100% means the degraded-mode ladder dropped
  a request.

Rows present only in the fresh file (new benchmarks) pass; rows present
only at HEAD (removed benchmarks) fail — deleting a regressing benchmark
must not green the gate.  Override the tolerance with ``--tol`` or
``CHECK_BENCH_TOL``.

Caveat: timing rows compare against a baseline committed from whatever
machine last refreshed it, so a systematically slower CI runner can trip
them without a code regression (launch/collective counts are immune —
they are the noise-free part of the gate).  When first arming this gate
on a new runner class, refresh the committed BENCH_*.json from that
runner's artifact (the full CI job uploads them), or raise
``CHECK_BENCH_TOL`` for the transition.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

#: file -> (row-key fields, [(metric, absolute floor[, direction])]).
#: ``direction`` defaults to "lower" (lower is better); "higher" inverts
#: the gate for metrics that may only RISE (cache hit rates).
#: Floor 0.0 marks a DETERMINISTIC counter (launches, collectives, VM
#: fallbacks, serve compilations): compared exactly — any move in the bad
#: direction fails, no relative tolerance.  The timing floors are
#: calibrated to observed
#: run-to-run variance on loaded CI boxes (compile_call_ms swings
#: ±15 ms at the ~25 ms scale; st_over_jax, a ratio of two µs-scale
#: medians, was observed swinging 0.58↔1.53 across consecutive runs):
#: a regression must clear BOTH the relative tolerance and the floor,
#: so load spikes don't fail builds while a genuine multi-× regression
#: still does.
GUARDS: dict[str, tuple[tuple[str, ...], list[tuple[str, float]]]] = {
    "BENCH_compile.json": (
        ("signature",),
        # vm_fallbacks is the closure-elimination tier's deterministic
        # teeth: the count of corpus graphs that fail try_lower after the
        # full pipeline may only fall, never rise (floor 0, no noise)
        [("compile_call_ms", 15.0), ("vm_fallbacks", 0.0)],
    ),
    "BENCH_ad_overhead.json": (("workload",), [("st_over_jax", 1.0)]),
    # launches_after is the deterministic partition gate; the two
    # profiler-derived metrics are wall-clock-based, so they carry noise
    # floors calibrated to eager-dispatch jitter (the ratio swings ~0.1
    # run to run at the ~1.0 scale; the roofline fraction is tiny on CPU
    # and the 0.05 floor means only a structural collapse trips it)
    "BENCH_fusion.json": (
        ("workload",),
        [
            ("launches_after", 0.0),
            ("fused_over_unfused", 0.15),
            ("roofline_fraction", 0.05, "higher"),
        ],
    ),
    "BENCH_spmd.json": (
        ("workload", "mesh"),
        [("launches_fused", 0.0), ("n_psum", 0.0), ("n_all_gather", 0.0)],
    ),
    # higher-order workloads must stay on the lowered path (vm_fallback
    # 0/1 per row, deterministic); steady-state latency is noise-floored.
    # Compile-time trajectory (may only fall): cold pipeline_ms end to
    # end, the optimize phase alone (dotted path into the span-derived
    # pipeline_phase_ms breakdown — the superlinear-cost watchdog), and
    # the summed phase total.  Noise floors are calibrated to observed
    # swings on loaded boxes: the MLP rows run ~1-2 s with ±40% load
    # wiggle, so a regression must clear 25% AND the ~600 ms floor —
    # load spikes pass, a 2× optimizer regression trips.
    # graph_cache_hit_rate is the warm lookup of the optimized-graph
    # tier: deterministically 1.0, may only rise — a fall means the
    # pre-opt structural hash or the loose encoding went unstable.
    "BENCH_higher_order.json": (
        ("workload",),
        [
            ("vm_fallback", 0.0),
            ("steady_us", 150.0),
            ("pipeline_ms", 600.0),
            ("pipeline_phase_ms.optimize", 500.0),
            ("pipeline_phase_total_ms", 600.0),
            ("graph_cache_hit_rate", 0.0, "higher"),
        ],
    ),
    # serve: compilations pinned at the bucket-derived floor (cold row),
    # warm row must keep xla_compiles at 0 and its hit rate may only rise
    "BENCH_serve.json": (
        ("workload",),
        [
            ("compilations", 0.0),
            ("decode_compilations", 0.0),
            ("xla_compiles", 0.0),
            ("cache_hit_rate", 0.0, "higher"),
            # robustness counters (chaos row runs under a FIXED fault
            # seed, so these are deterministic too): fault impact may
            # only shrink, and degraded-mode completion may only rise
            ("timeouts", 0.0),
            ("corrupt_entries", 0.0),
            ("vm_fallbacks", 0.0),
            ("budget_exhausted", 0.0),
            ("completed_pct", 0.0, "higher"),
        ],
    ),
}


#: (file, metric) -> absolute ceiling the FRESH value may never exceed —
#: enforced even with no committed baseline (a regressed baseline being
#: committed alongside the regression must not green the gate).
#: ``vm_fallbacks`` hit 0 when loop adjoints / nested SCCs / affine
#: non-tail recursion learned to lower; the corpus is pinned there.
HARD_CEILINGS: dict[tuple[str, str], float] = {
    ("BENCH_compile.json", "vm_fallbacks"): 0.0,
}


def _baseline(fname: str) -> list[dict] | None:
    """The committed rows for ``fname``, or None when there is nothing to
    gate against: a fresh BENCH_*.json not yet at HEAD (a brand-new
    metric family lands gate-green and becomes the baseline once
    committed), no git repo, or no git binary at all."""
    try:
        res = subprocess.run(
            ["git", "show", f"HEAD:{fname}"], capture_output=True, text=True
        )
    except OSError:
        return None  # git itself unavailable: report-only mode
    if res.returncode != 0:
        return None  # file not committed yet: nothing to gate against
    try:
        return json.loads(res.stdout)
    except json.JSONDecodeError:
        return None


def _rows_by_key(rows: list[dict], key_fields: tuple[str, ...]) -> dict[tuple, dict]:
    return {tuple(str(r.get(k)) for k in key_fields): r for r in rows}


def _metric(row: dict, name: str):
    """Resolve ``name`` in ``row``, descending into nested dicts on dots
    (``pipeline_phase_ms.optimize``).  None when any step is missing —
    the caller skips the gate, same as a flat missing metric."""
    cur = row
    for part in name.split("."):
        if not isinstance(cur, dict):
            return None
        cur = cur.get(part)
        if cur is None:
            return None
    return cur


def check_file(fname: str, tol: float) -> list[str]:
    key_fields, metrics = GUARDS[fname]
    if not os.path.exists(fname):
        return [f"{fname}: fresh file missing (did benchmarks/run.py run?)"]
    with open(fname) as f:
        fresh = _rows_by_key(json.load(f), key_fields)
    failures: list[str] = []
    # absolute hard floors first: baseline-independent, checked on the
    # FRESH rows alone — committing a regressed trajectory cannot green it
    for (gf, metric), ceiling in HARD_CEILINGS.items():
        if gf != fname:
            continue
        for key, frow in fresh.items():
            val = _metric(frow, metric)
            if val is not None and float(val) > ceiling:
                failures.append(
                    f"{fname}: {metric} = {float(val):g} for {key} exceeds "
                    f"the hard floor {ceiling:g} (absolute gate, "
                    "baseline-independent)"
                )
    base_rows = _baseline(fname)
    if base_rows is None:
        print(
            f"  {fname}: no committed baseline (new metric family or no "
            "git history) — relative gates report-only, arm on next commit"
        )
        return failures
    base = _rows_by_key(base_rows, key_fields)
    for key, brow in base.items():
        frow = fresh.get(key)
        if frow is None:
            failures.append(f"{fname}: row {key} present at HEAD but missing now")
            continue
        for spec in metrics:
            metric, floor = spec[0], spec[1]
            direction = spec[2] if len(spec) > 2 else "lower"
            old, new = _metric(brow, metric), _metric(frow, metric)
            if old is None or new is None:
                continue
            old, new = float(old), float(new)
            if floor == 0.0:
                # deterministic counter (launches, collectives, VM
                # fallbacks, serve compilations / hit rates): noise-free,
                # so ANY move in the bad direction is a real regression —
                # no relative tolerance applies (a baseline of 4 must not
                # green a move to 5)
                if direction == "higher":
                    if new < old:
                        failures.append(
                            f"{fname}: {metric} fell for {key}: {old:g} -> {new:g} "
                            "(deterministic counter, may only rise)"
                        )
                elif new > old:
                    failures.append(
                        f"{fname}: {metric} rose for {key}: {old:g} -> {new:g} "
                        "(deterministic counter, exact gate)"
                    )
                continue
            if direction == "higher":
                # noise-floored may-only-rise metric (roofline fractions):
                # a fall must clear BOTH the relative tolerance and the
                # absolute floor to fail, mirroring the "lower" branch
                if new >= old * (1.0 - tol):
                    continue
                if abs(new - old) <= floor:
                    continue  # within measurement-noise floor
                failures.append(
                    f"{fname}: {metric} fell for {key}: "
                    f"{old:g} -> {new:g} "
                    f"(-{100 * (old - new) / max(old, 1e-12):.1f}%, "
                    f"tol {100 * tol:.0f}%, may only rise)"
                )
                continue
            if new <= old * (1.0 + tol):
                continue
            if abs(new - old) <= floor:
                continue  # within measurement-noise floor
            failures.append(
                f"{fname}: {metric} regressed for {key}: "
                f"{old:g} -> {new:g} (+{100 * (new - old) / max(old, 1e-12):.1f}%, "
                f"tol {100 * tol:.0f}%)"
            )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tol",
        type=float,
        default=float(os.environ.get("CHECK_BENCH_TOL", "0.25")),
        help="allowed relative regression (default 0.25 = 25%%)",
    )
    args = ap.parse_args()
    os.chdir(os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))
    failures: list[str] = []
    for fname in GUARDS:
        failures.extend(check_file(fname, args.tol))
    if failures:
        print("\nBENCH GATE FAILED:")
        for f in failures:
            print("  " + f)
        return 1
    print(f"bench gate passed ({len(GUARDS)} trajectories, tol {args.tol:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
