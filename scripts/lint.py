#!/usr/bin/env python
"""Lint gate for scripts/ci.sh.

Runs ``ruff check`` (configured in pyproject.toml) when ruff is
installed.  This container does not ship ruff and nothing may be pip
installed, so a minimal in-repo fallback enforces the mechanical subset
of the same config — syntax, unused imports (F401), line length (E501,
100 cols), tabs and trailing whitespace — on the same file set.  CI
(ubuntu runners, see .github/workflows/ci.yml) installs ruff and gets
the full rule set; the fallback keeps the gate meaningful locally.

Independent of ruff, the **span-registry check** always runs: every
``span("...")`` / ``mark("...")`` string literal in ``src/`` and
``benchmarks/`` must appear in ``repro.obs.trace``'s ``SPAN_NAMES`` /
``MARK_NAMES`` (parsed by AST, no import) — ``check_bench.py`` gates
metrics derived from those exact strings, so an unregistered name is a
silently un-armed CI gate, not a style nit.
"""

from __future__ import annotations

import ast
import pathlib
import shutil
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
TARGETS = ["src", "benchmarks", "scripts", "tests"]
LINE_LENGTH = 100


def _pinned_ruff() -> str | None:
    """The ruff pin from pyproject's ``[project.optional-dependencies]``
    lint extra (e.g. ``"0.8.4"``) — the single source of truth CI installs."""
    try:
        import tomllib

        with open(ROOT / "pyproject.toml", "rb") as f:
            deps = tomllib.load(f)["project"]["optional-dependencies"]["lint"]
        for d in deps:
            if d.startswith("ruff=="):
                return d.split("==", 1)[1]
    except Exception:
        pass
    return None


def _ruff() -> int | None:
    exe = shutil.which("ruff")
    cmd = [exe] if exe else None
    if cmd is None:
        probe = subprocess.run(
            [sys.executable, "-m", "ruff", "--version"], capture_output=True
        )
        if probe.returncode == 0:
            cmd = [sys.executable, "-m", "ruff"]
    if cmd is None:
        return None
    pin = _pinned_ruff()
    if pin is not None:
        ver = subprocess.run(cmd + ["--version"], capture_output=True, text=True)
        got = (ver.stdout or "").strip().split()[-1] if ver.returncode == 0 else ""
        if got and got != pin:
            print(
                f"lint: WARNING local ruff {got} != pinned {pin} "
                "(pyproject [lint]); results may differ from CI"
            )
    return subprocess.run(cmd + ["check"] + TARGETS, cwd=ROOT).returncode


class _ImportCollector(ast.NodeVisitor):
    def __init__(self) -> None:
        self.imported: dict[str, int] = {}  # bound name -> lineno
        self.used: set[str] = set()

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            name = a.asname or a.name.split(".")[0]
            self.imported.setdefault(name, node.lineno)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "__future__":
            return  # __future__ imports are directives, never "unused"
        for a in node.names:
            if a.name == "*":
                continue
            self.imported.setdefault(a.asname or a.name, node.lineno)

    def visit_Name(self, node: ast.Name) -> None:
        if isinstance(node.ctx, ast.Load):
            self.used.add(node.id)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        self.generic_visit(node)


def _noqa_lines(src: str) -> set[int]:
    return {
        i for i, line in enumerate(src.splitlines(), 1) if "# noqa" in line
    }


def _check_file(path: pathlib.Path) -> list[str]:
    rel = path.relative_to(ROOT)
    src = path.read_text()
    problems: list[str] = []
    try:
        tree = ast.parse(src, filename=str(rel))
    except SyntaxError as e:
        return [f"{rel}:{e.lineno}: E999 syntax error: {e.msg}"]
    noqa = _noqa_lines(src)
    coll = _ImportCollector()
    coll.visit(tree)
    # names used in docstring-level __all__ count as used
    for node in ast.walk(tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            coll.used.add(node.value)
    for name, lineno in coll.imported.items():
        if name not in coll.used and lineno not in noqa:
            problems.append(f"{rel}:{lineno}: F401 unused import {name!r}")
    for i, line in enumerate(src.splitlines(), 1):
        if i in noqa:
            continue
        if len(line) > LINE_LENGTH:
            problems.append(f"{rel}:{i}: E501 line too long ({len(line)} > {LINE_LENGTH})")
        if "\t" in line:
            problems.append(f"{rel}:{i}: W191 tab in indentation/content")
        if line != line.rstrip():
            problems.append(f"{rel}:{i}: W291 trailing whitespace")
    return problems


# ---------------------------------------------------------------------------
# Span-name registry check (runs in BOTH the ruff and fallback paths)
# ---------------------------------------------------------------------------

TRACE_MODULE = ROOT / "src" / "repro" / "obs" / "trace.py"
#: the file sets the registry check scans: instrumented production code.
#: tests are exempt — they exercise the tracer with throwaway names.
SPAN_CHECK_TARGETS = ["src", "benchmarks"]


def _registry_names(var: str) -> set[str]:
    """The string members of ``trace.py``'s ``var`` frozenset, read by AST
    (no import: lint must not require jax or the package on sys.path)."""
    tree = ast.parse(TRACE_MODULE.read_text(), filename=str(TRACE_MODULE))
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        if not any(isinstance(t, ast.Name) and t.id == var for t in node.targets):
            continue
        out: set[str] = set()
        for c in ast.walk(node.value):
            if isinstance(c, ast.Constant) and isinstance(c.value, str):
                out.add(c.value)
        return out
    raise AssertionError(f"{var} not found in {TRACE_MODULE}")


def _span_calls(tree: ast.AST) -> list[tuple[int, str, str]]:
    """Every ``span(...)`` / ``mark(...)`` call (bare name or attribute,
    e.g. ``obs_trace.span``) whose first argument is a string literal:
    ``(lineno, func, name)``."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        fname = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else None
        )
        if fname not in ("span", "mark"):
            continue
        if not node.args:
            continue
        a0 = node.args[0]
        if isinstance(a0, ast.Constant) and isinstance(a0.value, str):
            out.append((node.lineno, fname, a0.value))
    return out


def _span_registry_check() -> int:
    span_names = _registry_names("SPAN_NAMES")
    mark_names = _registry_names("MARK_NAMES")
    registry = {"span": span_names, "mark": mark_names}
    problems: list[str] = []
    for target in SPAN_CHECK_TARGETS:
        for path in sorted((ROOT / target).rglob("*.py")):
            if "artifacts" in path.parts:
                continue
            try:
                tree = ast.parse(path.read_text(), filename=str(path))
            except SyntaxError:
                continue  # the main lint reports syntax errors
            rel = path.relative_to(ROOT)
            for lineno, fname, name in _span_calls(tree):
                if name not in registry[fname]:
                    problems.append(
                        f"{rel}:{lineno}: SPAN001 {fname}({name!r}) not in "
                        f"trace.{'SPAN_NAMES' if fname == 'span' else 'MARK_NAMES'} "
                        "— register the name there first (it arms the bench gates)"
                    )
    for p in problems:
        print(p)
    return 1 if problems else 0


def _fallback() -> int:
    problems: list[str] = []
    for target in TARGETS:
        for path in sorted((ROOT / target).rglob("*.py")):
            if "artifacts" in path.parts:
                continue
            problems.extend(_check_file(path))
    for p in problems:
        print(p)
    print(
        f"fallback lint (ruff unavailable): {len(problems)} problem(s) over "
        f"{TARGETS} [F401/E501/W191/W291 + syntax]"
    )
    return 1 if problems else 0


def main() -> int:
    spans = _span_registry_check()  # always runs: ruff cannot check this
    rc = _ruff()
    if rc is None:
        rc = _fallback()
    return rc or spans


if __name__ == "__main__":
    raise SystemExit(main())
