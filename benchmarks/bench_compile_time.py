"""Paper §4.2 claim: call-site specialization — each new input signature
triggers type-inference + optimization + compilation once; repeat calls
hit the specialization cache.

Measures, per signature:

* ``first_call_ms`` — specialize + first execution.  With direct lowering
  the first call answers from a cheap tier-0 XLA compile of the
  straight-line callable (a fraction of the full-opt compile latency).
* ``compile_call_ms`` — the second call, which traces + XLA-compiles the
  fully optimized jitted path (tiered compilation moves it here).
* ``cached_call_us`` — steady-state cached calls (after the jit warmed).
* ``specializations`` — cache isolation across signatures.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import api as myia
from repro.core.primitives import tanh as _tanh


def model(w, x):
    h = _tanh(x @ w)
    return h @ w


def run(reps: int = 50) -> list[dict]:
    rows = []
    for shape in [(8, 8), (64, 64), (256, 256)]:
        fn = myia.myia(model)
        w = jnp.ones(shape)
        x = jnp.ones((4, shape[0]))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(w, x))
        first = time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(fn(w, x))
        compile_call = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(reps):
            r = fn(w, x)
        jax.block_until_ready(r)
        cached = (time.perf_counter() - t0) / reps
        runner = fn.specialize((w, x))
        rows.append(
            {
                "signature": f"f32{list(shape)}",
                "first_call_ms": round(first * 1e3, 2),
                "compile_call_ms": round(compile_call * 1e3, 2),
                "cached_call_us": round(cached * 1e6, 1),
                "lowered": bool(getattr(runner, "lowered", False)),
                "specializations": len(fn._specializations),
            }
        )
    # polymorphic reuse: one function, two signatures → two specializations
    fn = myia.myia(model)
    fn(jnp.ones((8, 8)), jnp.ones((4, 8)))
    fn(jnp.ones((16, 16)), jnp.ones((4, 16)))
    rows.append({"signature": "polymorphic(2 shapes)", "specializations": len(fn._specializations)})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
