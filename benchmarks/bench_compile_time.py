"""Paper §4.2 claim: call-site specialization — each new input signature
triggers type-inference + optimization + compilation once; repeat calls
hit the specialization cache.

Measures, per signature:

* ``first_call_ms`` — specialize + first execution.  With direct lowering
  the first call answers from a cheap tier-0 XLA compile of the
  straight-line callable (a fraction of the full-opt compile latency).
* ``compile_call_ms`` — the second call, which traces + XLA-compiles the
  fully optimized jitted path (tiered compilation moves it here).
* ``cached_call_us`` — steady-state cached calls (after the jit warmed).
* ``specializations`` — cache isolation across signatures.

Additionally reports the **VM-fallback counter**: how many programs of a
fixed corpus (straight-line, first- and second-order adjoints, loops and
loop adjoints, nested loops, non-tail recursion, higher-order /
defunctionalized calls) fail ``try_lower`` after the full pipeline.  The
corpus now lowers completely — ``vm_fallbacks`` is 0 and
``scripts/check_bench.py`` hard-fails CI on *any* nonzero count, which is
the teeth that keep the fallback set from regrowing.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import api as myia
from repro.core import build_grad_graph, parse_function
from repro.core.closure import analyze_blockers
from repro.core.infer import abstract_of_value
from repro.core.primitives import reduce_sum as _rsum, tanh as _tanh


def model(w, x):
    h = _tanh(x @ w)
    return h @ w


# -- VM-fallback corpus ------------------------------------------------------
# Deterministic programs spanning every pipeline tier.  The final rows of
# BENCH_compile.json record how many fail try_lower; any increase vs the
# committed trajectory fails CI (scripts/check_bench.py).


def _cube(x):
    return x * x * x


def _sq(y):
    return y * y


def _iterate(f, x, n):
    i = 0
    while i < n:
        x = f(x)
        i = i + 1
    return x


def _while_pow(x, n):
    i = 0
    acc = x
    while i < n:
        acc = acc * x
        i = i + 1
    return acc


def _for_fold(x):
    s = 0.0
    for i in range(5):
        s = s + x * x
    return s


def _defunc(x, n):
    return _iterate(_sq, x, n)


def _partial(x, y, n):
    g = lambda z: z * y  # noqa: E731
    return _iterate(g, x, n)


def _compose_use(x):
    h = lambda v: _sq(_sq(v))  # noqa: E731
    return h(x)


def _fold_rec(x, n):  # non-tail self-call: lowers via count + unwind loops
    if n == 0:
        return 1.0
    return x * _fold_rec(x, n - 1)


def _nested(x, n):  # nested loops: one SCC, lowers to loop-in-loop-step
    i = 0
    s = 0.0
    while i < n:
        j = 0
        while j < i:
            s = s + x
            j = j + 1
        i = i + 1
    return s


_F = jnp.asarray(1.3, jnp.float32)
_N = jnp.asarray(4)
_WM = jnp.ones((4, 4), jnp.float32) * 0.3
_XM = jnp.ones((2, 4), jnp.float32)


def _grad(fn, wrt=0, order=1, example_args=None):
    # example_args arm the pre-grad pipeline for loop/recursive primals
    # (loops lower before J, so the adjoint is itself loop-shaped)
    g = parse_function(fn)
    for _ in range(order):
        g = build_grad_graph(g, wrt, example_args=example_args)
    return g


def _mlp_sum(w, x):
    return _rsum(_tanh(x @ w), None, False)


def _fallback_corpus() -> list[tuple[str, object, tuple]]:
    mlp = _mlp_sum
    return [
        ("fwd_mlp", parse_function(mlp), (_WM, _XM)),
        ("grad_mlp", _grad(mlp), (_WM, _XM)),
        ("grad2_cube", _grad(_cube, order=2), (_F,)),
        ("while_pow", parse_function(_while_pow), (_F, _N)),
        ("for_fold", parse_function(_for_fold), (_F,)),
        ("defunc_iterate", parse_function(_defunc), (_F, _N)),
        ("partial_application", parse_function(_partial), (_F, _F, _N)),
        ("compose", parse_function(_compose_use), (_F,)),
        ("grad_while_pow", _grad(_while_pow, example_args=(_F, _N)), (_F, _N)),
        ("fold_rec_grad", _grad(_fold_rec, example_args=(_F, 5)), (_F, 5)),
        ("nested_loops", parse_function(_nested), (_F, _N)),
    ]


def _fallback_rows() -> list[dict]:
    from repro.core.api import compile_pipeline

    fallbacks = 0
    kinds: dict[str, int] = {}
    per_graph = {}
    corpus = _fallback_corpus()
    for name, g, args in corpus:
        og = compile_pipeline(g, tuple(abstract_of_value(a) for a in args))
        reasons = analyze_blockers(og)
        per_graph[name] = sorted({r.kind for r in reasons})
        if reasons:
            fallbacks += 1
            for r in reasons:
                kinds[r.kind] = kinds.get(r.kind, 0) + 1
    return [
        {
            "signature": "vm_fallback_corpus",
            "corpus_size": len(corpus),
            "vm_fallbacks": fallbacks,
            "fallback_kinds": dict(sorted(kinds.items())),
            "per_graph": per_graph,
        }
    ]


def run(reps: int = 50) -> list[dict]:
    rows = []
    for shape in [(8, 8), (64, 64), (256, 256)]:
        fn = myia.myia(model)
        w = jnp.ones(shape)
        x = jnp.ones((4, shape[0]))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(w, x))
        first = time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(fn(w, x))
        compile_call = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(reps):
            r = fn(w, x)
        jax.block_until_ready(r)
        cached = (time.perf_counter() - t0) / reps
        runner = fn.specialize((w, x))
        rows.append(
            {
                "signature": f"f32{list(shape)}",
                "first_call_ms": round(first * 1e3, 2),
                "compile_call_ms": round(compile_call * 1e3, 2),
                "cached_call_us": round(cached * 1e6, 1),
                "lowered": bool(getattr(runner, "lowered", False)),
                "specializations": len(fn._specializations),
            }
        )
    # polymorphic reuse: one function, two signatures → two specializations
    fn = myia.myia(model)
    fn(jnp.ones((8, 8)), jnp.ones((4, 8)))
    fn(jnp.ones((16, 16)), jnp.ones((4, 16)))
    rows.append({"signature": "polymorphic(2 shapes)", "specializations": len(fn._specializations)})
    rows.extend(_fallback_rows())
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
