"""Serving-runtime benchmark: throughput, TTFT, and the compilation economy.

Two rows on a fixed mixed-length workload (4 requests over 2 slots,
landing in two power-of-two buckets):

* ``serve_cold`` — fresh tmpdir AOT cache: every specialization is a
  cache miss and an XLA compile.  ``compilations`` must equal the
  bucket-derived floor (2 programs × |buckets|) — the engine compiles
  per *bucket*, never per generated length — and ``scripts/
  check_bench.py`` gates it exactly (deterministic, may only fall).
* ``serve_warm`` — same workload, same cache directory, fresh engine +
  cache handle: every lookup hits, ``xla_compiles`` stays 0 and
  ``cache_hit_rate`` is 1.0 (gated as may-only-rise).

Timing fields (tokens/s, TTFT) are reported for the trajectory but not
gated — cold TTFT is dominated by the pipeline+XLA compile, which is
exactly what the warm row shows evaporating.
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

import jax

from repro.core.jax_backend import ProgramCache
from repro.serve import ServeEngine, ServeLMDims, init_serve_params

#: the fixed workload: (prompt_len, max_new) per request.  Totals 30, 36,
#: 48, 64 → buckets {32, 64} at min_bucket=32 → compilation floor 4.
_REQUESTS = [(6, 24), (12, 24), (24, 24), (40, 24)]
_MIN_BUCKET = 32
_N_SLOTS = 2


def _run_once(cache_dir: str) -> dict:
    dims = ServeLMDims(vocab=256, d_model=32, d_hidden=64)
    params = init_serve_params(dims, jax.random.PRNGKey(0))
    cache = ProgramCache(cache_dir)
    engine = ServeEngine(
        dims, params, n_slots=_N_SLOTS, min_bucket=_MIN_BUCKET, program_cache=cache
    )
    rng = np.random.default_rng(0)
    for plen, mx in _REQUESTS:
        engine.submit(rng.integers(0, dims.vocab, plen).tolist(), mx)
    t0 = time.monotonic()
    results = engine.run()
    wall = time.monotonic() - t0
    stats = engine.stats()
    cs = cache.stats
    return {
        "n_slots": _N_SLOTS,
        "min_bucket": _MIN_BUCKET,
        "n_requests": len(_REQUESTS),
        "buckets": stats["buckets_in_use"],
        "compilations": stats["total_compilations"],
        "decode_compilations": stats["compilations"]["decode"],
        "compilation_floor": stats["compilation_floor"],
        "xla_compiles": cs.xla_compiles,
        "cache_hit_rate": round(cs.hit_rate, 4),
        "cache_hits": cs.hits,
        "cache_misses": cs.misses,
        "tokens_generated": stats["tokens_generated"],
        "decode_steps": stats["decode_steps"],
        "tokens_per_s": round(stats["tokens_generated"] / max(wall, 1e-9), 1),
        "ttft_ms": round(min(r["ttft_s"] for r in results.values()) * 1e3, 2),
        "wall_s": round(wall, 3),
    }


def run(reps: int = 1) -> list[dict]:
    with tempfile.TemporaryDirectory(prefix="bench-progcache-") as cache_dir:
        cold = {"workload": "serve_cold", **_run_once(cache_dir)}
        warm = {"workload": "serve_warm", **_run_once(cache_dir)}
    # the economics the runtime exists for — fail fast here, not in CI diff
    assert cold["compilations"] == cold["compilation_floor"], (
        f"compilations {cold['compilations']} != bucket floor "
        f"{cold['compilation_floor']} — a specialization leak"
    )
    assert cold["decode_compilations"] == len(cold["buckets"])
    assert warm["xla_compiles"] == 0, "warm cache still compiled"
    assert warm["cache_hit_rate"] == 1.0
    return [cold, warm]


if __name__ == "__main__":
    for row in run():
        print(row)
