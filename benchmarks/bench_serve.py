"""Serving-runtime benchmark: throughput, TTFT, and the compilation economy.

Three rows on a fixed mixed-length workload (4 requests over 2 slots,
landing in two power-of-two buckets):

* ``serve_cold`` — fresh tmpdir AOT cache: every specialization is a
  cache miss and an XLA compile.  ``compilations`` must equal the
  bucket-derived floor (2 programs × |buckets|) — the engine compiles
  per *bucket*, never per generated length — and ``scripts/
  check_bench.py`` gates it exactly (deterministic, may only fall).
* ``serve_warm`` — same workload, same cache directory, fresh engine +
  cache handle: every lookup hits, ``xla_compiles`` stays 0 and
  ``cache_hit_rate`` is 1.0 (gated as may-only-rise).
* ``serve_chaos`` — same workload and cache directory under a fixed
  fault plan (every cache entry garbage-corrupted on first read, the
  first compile attempt raises): the degraded-mode ladder must absorb
  every fault — all requests finish ``ok`` with tokens identical to the
  cold run (``completed_pct`` gated at exactly 100.0), corrupt entries
  are quarantined (exact count gated), and nothing times out or
  exhausts the step budget.

Timing fields (tokens/s, TTFT) are reported for the trajectory but not
gated — cold TTFT is dominated by the pipeline+XLA compile, which is
exactly what the warm row shows evaporating.
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

import jax

from repro.core.jax_backend import ProgramCache
from repro.obs import metrics as obs_metrics
from repro.serve import (
    CacheFault,
    CompileFault,
    FaultPlan,
    ServeEngine,
    ServeLMDims,
    init_serve_params,
    inject_faults,
)

#: the fixed workload: (prompt_len, max_new) per request.  Totals 30, 36,
#: 48, 64 → buckets {32, 64} at min_bucket=32 → compilation floor 4.
_REQUESTS = [(6, 24), (12, 24), (24, 24), (40, 24)]
_MIN_BUCKET = 32
_N_SLOTS = 2

#: the chaos row's fixed plan: every cached program is corrupted on its
#: first read and the first fresh-compile attempt raises — the ladder
#: must quarantine + retry through both without a single lost request.
_CHAOS_SEED = 0xC0FFEE


def _run_once(cache_dir: str) -> tuple[dict, dict]:
    dims = ServeLMDims(vocab=256, d_model=32, d_hidden=64)
    params = init_serve_params(dims, jax.random.PRNGKey(0))
    cache = ProgramCache(cache_dir)
    engine = ServeEngine(
        dims, params, n_slots=_N_SLOTS, min_bucket=_MIN_BUCKET, program_cache=cache
    )
    rng = np.random.default_rng(0)
    rids = [
        engine.submit(rng.integers(0, dims.vocab, plen).tolist(), mx)
        for plen, mx in _REQUESTS
    ]
    t0 = time.monotonic()
    results = engine.run()
    wall = time.monotonic() - t0
    # every counter below comes off the unified dotted-key snapshot —
    # CacheStats and the engine stats dict are absorbed through one schema
    # (the row keys stay as-is: check_bench gates them by exact name)
    snap = obs_metrics.snapshot(cache=cache.stats, serve=engine.stats())
    ttfts = [r["ttft_s"] for r in results.values() if r["ttft_s"] is not None]
    row = {
        "n_slots": _N_SLOTS,
        "min_bucket": _MIN_BUCKET,
        "n_requests": len(_REQUESTS),
        "buckets": snap["serve.buckets_in_use"],
        "compilations": snap["serve.total_compilations"],
        "decode_compilations": snap["serve.compilations.decode"],
        "compilation_floor": snap["serve.compilation_floor"],
        "xla_compiles": snap["cache.xla_compiles"],
        "cache_hit_rate": snap["cache.hit_rate"],
        "cache_hits": snap["cache.hits"],
        "cache_misses": snap["cache.misses"],
        "tokens_generated": snap["serve.tokens_generated"],
        "decode_steps": snap["serve.decode_steps"],
        "tokens_per_s": round(snap["serve.tokens_generated"] / max(wall, 1e-9), 1),
        "ttft_ms": round(min(ttfts) * 1e3, 2) if ttfts else None,
        "wall_s": round(wall, 3),
        # robustness telemetry (all-zero on the fault-free rows)
        "timeouts": snap["serve.statuses.timeout"],
        "failed": snap["serve.statuses.failed"],
        "corrupt_entries": snap["cache.corrupt_entries"],
        "quarantined": snap["cache.quarantined"],
        "compile_retries": snap["cache.compile_retries"],
        "vm_fallbacks": snap["cache.vm_fallbacks"],
        "budget_exhausted": snap["serve.budget_exhausted"],
        "completed_pct": round(100.0 * snap["serve.statuses.ok"] / len(rids), 1),
    }
    tokens = {rid: results[rid]["tokens"] for rid in rids}
    return row, tokens


def run(reps: int = 1) -> list[dict]:
    with tempfile.TemporaryDirectory(prefix="bench-progcache-") as cache_dir:
        cold, cold_tokens = _run_once(cache_dir)
        cold = {"workload": "serve_cold", **cold}
        warm, warm_tokens = _run_once(cache_dir)
        warm = {"workload": "serve_warm", **warm}
        plan = FaultPlan(
            seed=_CHAOS_SEED,
            cache_fault=CacheFault(mode="garbage"),
            compile_fault=CompileFault(kind="raise", count=1),
        )
        with inject_faults(plan):
            chaos, chaos_tokens = _run_once(cache_dir)
        chaos = {"workload": "serve_chaos", **chaos}
    # the economics the runtime exists for — fail fast here, not in CI diff
    assert cold["compilations"] == cold["compilation_floor"], (
        f"compilations {cold['compilations']} != bucket floor "
        f"{cold['compilation_floor']} — a specialization leak"
    )
    assert cold["decode_compilations"] == len(cold["buckets"])
    assert warm["xla_compiles"] == 0, "warm cache still compiled"
    assert warm["cache_hit_rate"] == 1.0
    assert warm_tokens == cold_tokens
    # the robustness contract: faults are absorbed, not surfaced
    assert chaos["completed_pct"] == 100.0, f"chaos lost requests: {chaos}"
    assert chaos_tokens == cold_tokens, "degraded mode changed outputs"
    assert chaos["timeouts"] == 0 and chaos["budget_exhausted"] == 0
    assert chaos["quarantined"] == chaos["corrupt_entries"] > 0
    return [cold, warm, chaos]


if __name__ == "__main__":
    for row in run():
        print(row)
