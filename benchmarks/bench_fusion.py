"""Fusion subsystem benchmark (paper §3–4: kernels as the compilation
target of ST adjoints).

For the MLP adjoint (the paper's running example) and a forward reduction
chain, report

* the **partition**: cluster count, fused nodes, average nodes per
  cluster (acceptance: ≥3 on the MLP adjoint) and kernel-launch
  reduction (apply nodes emitted before/after fusion),
* **wall clock**: median jitted step time of the unfused straight-line
  lowering vs. the fused lowering in ``ref`` mode (cluster oracles —
  the CPU production path; parity or better expected, XLA sees an
  equivalent program with fewer call sites) and in ``pallas_interpret``
  mode (the Pallas interpreter is a correctness simulator, its time is
  reported for completeness, not compared),
* **achieved bandwidth**: the runtime profiler (``repro.obs.profile``)
  armed over the instrumented eager lowering — summed bytes moved over
  summed launch wall per workload, reported as ``achieved_gbps`` and
  ``roofline_fraction`` against the 819 GB/s HBM model
  (``benchmarks/roofline.py``); ``check_bench.py`` gates the fraction
  may-only-rise on the MLP adjoint.

Rows land in ``BENCH_fusion.json`` via ``benchmarks/run.py`` so
successive PRs leave a trajectory.
"""

from __future__ import annotations

import time

import jax

from repro.core import P, build_grad_graph, parse_function
from repro.core.api import compile_pipeline
from repro.core.infer import abstract_of_value
from repro.core.lowering import lower_graph
from repro.kernels import get_kernel_mode, set_kernel_mode
from repro.obs import profile as obs_profile


def _two_layer(w1, w2, x):
    h = P.tanh(x @ w1)
    return P.reduce_sum(P.tanh(h @ w2), (0, 1), False)


def _reduce_chain(x):
    return P.reduce_sum(P.tanh(x) * P.sigmoid(x) + 1.0, (0, 1), False)


def _median_us(fn, args, reps: int) -> float:
    ts = []
    r = fn(*args)
    jax.block_until_ready(r)  # compile outside the timer
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def _bench_graph(name: str, graph, args, reps: int) -> dict:
    g = compile_pipeline(graph, tuple(abstract_of_value(a) for a in args))
    unfused = jax.jit(lower_graph(g))
    fused_fn = lower_graph(g, fuse=True)
    # the attached plan counts only clusters that actually emitted kernels
    plan = fused_fn.__fusion_plan__
    fused = jax.jit(fused_fn)

    prev = get_kernel_mode()
    prof = obs_profile.Profiler()
    try:
        set_kernel_mode("ref")
        unfused_us = _median_us(unfused, args, reps)
        fused_ref_us = _median_us(fused, args, reps)
        # achieved bandwidth: the instrumented eager lowering under an
        # armed profiler — one record per launch (fused clusters time
        # themselves, everything else through call_profiled).  Warm one
        # call first so jnp op compilation stays out of the aggregates.
        prof_fn = lower_graph(g, fuse=True, profile=True)
        jax.block_until_ready(prof_fn(*args))
        with obs_profile.profiling(prof):
            for _ in range(max(3, reps // 10)):
                prof_fn(*args)
        set_kernel_mode("pallas_interpret")
        fused_interp = jax.jit(lower_graph(g, fuse=True))
        fused_interp_us = _median_us(fused_interp, args, reps)
    finally:
        set_kernel_mode(prev)

    agg = prof.aggregate()
    stats = plan.stats()
    emitted = len(fused_fn.__fused_kernels__)
    return {
        "workload": name,
        "n_clusters": stats["n_clusters"],
        "kernels_emitted": emitted,
        "nodes_per_cluster": stats["nodes_per_cluster"],
        "launches_before": stats["launches_before"],
        "launches_after": stats["launches_after"],
        "unfused_us": round(unfused_us, 1),
        "fused_ref_us": round(fused_ref_us, 1),
        "fused_over_unfused": round(fused_ref_us / unfused_us, 3),
        "fused_interpret_us": round(fused_interp_us, 1),
        "achieved_gbps": agg["achieved_gbps"],
        "roofline_fraction": agg["roofline_fraction"],
    }


def run(reps: int = 50) -> list[dict]:
    rows = []
    for size in (64, 256):
        k = jax.random.PRNGKey(0)
        w1 = jax.random.normal(k, (size, size)) * 0.1
        w2 = jax.random.normal(jax.random.PRNGKey(1), (size, size)) * 0.1
        x = jax.random.normal(jax.random.PRNGKey(2), (32, size))
        g = build_grad_graph(parse_function(_two_layer), (0, 1))
        rows.append(_bench_graph(f"mlp_adjoint_{size}", g, (w1, w2, x), reps))
    x = jax.random.normal(jax.random.PRNGKey(3), (256, 512))
    rows.append(
        _bench_graph("reduce_chain_fwd", parse_function(_reduce_chain), (x,), reps)
    )
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
