"""Higher-order compilation benchmark (closure-elimination tier).

The paper's claim is that ST adjoints — including adjoints of adjoints —
are ordinary programs amenable to ahead-of-time compilation.  This bench
measures exactly that on grad-of-grad and an HVP of the ``myia_step`` MLP
loss: the full pipeline must produce a VM-free lowered program
(``vm_fallback`` = 0 is CI-gated via BENCH_higher_order.json), and we
record compile time plus steady-state latency against the VM-traced
baseline (``lower=False`` — the pre-closure-elimination execution path).

Every workload compiles with the optimized-graph cache tier armed
(``CompileOptions.graph_cache``) and runs the pipeline twice: the cold
row is a cache miss (full optimize + store), the warm row a hit — the
stored post-optimize graph deserializes and the optimize/closure-elim
phases are skipped entirely.  The bench asserts the warm graph's
canonical encoding is byte-identical to the cold one and that the warm
``optimize`` phase is ≤5% of the warm pipeline; ``graph_cache_hit_rate``
(the warm lookup, deterministically 1.0) is CI-gated may-only-rise.
"""

from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.core import Graph, P, build_grad_graph, parse_function
from repro.core.api import CompileOptions, compile_pipeline
from repro.core.infer import abstract_of_value
from repro.core.jax_backend import ProgramCache, compile_graph
from repro.core.primitives import reduce_sum as _rsum, tanh as _tanh
from repro.core.serialize import dumps as _gdumps
from repro.launch.myia_step import MyiaLMDims, build_lm_loss, init_lm_params
from repro.obs import trace as obs_trace


def _cube(x):
    return x * x * x


def _scan_mlp_loss(w, x):
    # static-trip loop → scan_loop; its adjoint is a reversed scan over
    # the saved-carry stack (the loop-AD tier's flagship workload)
    h = x
    for i in range(4):
        h = _tanh(h @ w)
    return _rsum(h, None, False)


_SW = jnp.ones((4, 4), jnp.float32) * 0.3
_SX = jnp.ones((2, 4), jnp.float32)


def _hvp_graph(f_graph, nargs):
    """grad of sum(grad(f)·v) wrt arg 0 — an HVP spelled in the IR."""
    g1 = build_grad_graph(f_graph, 0)
    h = Graph("hvp_host")
    ps = [h.add_parameter(f"p{i}") for i in range(nargs)]
    v = h.add_parameter("v")
    dot = h.apply(P.reduce_sum, h.apply(P.mul, h.apply(g1, *ps), v), None, False)
    h.set_return(dot)
    return build_grad_graph(h, 0)


def _mlp_workloads():
    # deliberately tiny: the workload is the *graph shape* (take/one-hot/
    # stable-logsoftmax adjoint-of-adjoint), not FLOPs — reverse-over-
    # reverse node counts grow fast and quick-mode CI runs this
    dims = MyiaLMDims(vocab=8, d_model=4, d_hidden=8)
    B, S = 1, 2
    loss_g = parse_function(build_lm_loss(dims, B, S))
    params = init_lm_params(dims, jax.random.PRNGKey(0))
    tokens = jnp.zeros((B, S), jnp.int32)
    labels = jnp.ones((B, S), jnp.int32)
    args = (*params, tokens, labels)
    grad2 = build_grad_graph(build_grad_graph(loss_g, 0), 0)
    hvp = _hvp_graph(loss_g, len(args))
    return [
        ("grad2_mlp", grad2, args),
        ("hvp_mlp", hvp, (*args, jnp.ones_like(params[0]))),
    ]


def _time_runner(runner, args, reps: int) -> tuple[float, float]:
    t0 = time.perf_counter()
    jax.block_until_ready(runner(*args))
    first = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(reps):
        r = runner(*args)
    jax.block_until_ready(r)
    return first, (time.perf_counter() - t0) / reps


def run(reps: int = 30) -> list[dict]:
    workloads = [
        (
            "grad2_cube",
            build_grad_graph(build_grad_graph(parse_function(_cube))),
            (jnp.asarray(1.3, jnp.float32),),
        ),
        (
            "grad_scan_mlp",
            build_grad_graph(
                parse_function(_scan_mlp_loss), 0, example_args=(_SW, _SX)
            ),
            (_SW, _SX),
        ),
    ] + _mlp_workloads()

    rows = []
    cache_root = tempfile.mkdtemp(prefix="bench_graph_cache_")
    for name, g, args in workloads:
        example = tuple(abstract_of_value(a) for a in args)
        pc = ProgramCache(os.path.join(cache_root, name))
        opts = CompileOptions(graph_cache=pc)
        tracer = obs_trace.Tracer()
        t0 = time.perf_counter()
        with obs_trace.tracing(tracer):
            og = compile_pipeline(g, example, options=opts)
        pipeline_s = time.perf_counter() - t0
        # phase breakdown from the direct children of the compile_pipeline
        # span; its sum must reproduce the end-to-end wall time (no phase
        # is unaccounted for) — a >10% gap means an instrumentation hole
        phase_ms = tracer.phase_totals_ms("compile_pipeline")
        phase_total = sum(phase_ms.values())
        assert abs(phase_total - pipeline_s * 1e3) <= 0.10 * pipeline_s * 1e3, (
            f"{name}: phase sum {phase_total:.1f}ms vs pipeline "
            f"{pipeline_s * 1e3:.1f}ms (>10% unaccounted)"
        )
        # warm pass: the graph tier answers from disk — optimize and
        # closure-elim never run (their spans are absent), and the graph
        # must be byte-identical to the one the cold pass just produced
        hits0, misses0 = pc.stats.graph_hits, pc.stats.graph_misses
        warm_tracer = obs_trace.Tracer()
        t0 = time.perf_counter()
        with obs_trace.tracing(warm_tracer):
            og_warm = compile_pipeline(g, example, options=opts)
        warm_s = time.perf_counter() - t0
        warm_phase_ms = warm_tracer.phase_totals_ms("compile_pipeline")
        warm_lookups = (pc.stats.graph_hits - hits0) + (pc.stats.graph_misses - misses0)
        warm_hit_rate = (pc.stats.graph_hits - hits0) / max(warm_lookups, 1)
        warm_opt_ms = warm_phase_ms.get("optimize", 0.0)
        assert warm_opt_ms <= 0.05 * warm_s * 1e3, (
            f"{name}: warm optimize phase {warm_opt_ms:.1f}ms exceeds 5% of "
            f"warm pipeline {warm_s * 1e3:.1f}ms"
        )
        assert _gdumps(og_warm, names=False) == _gdumps(og, names=False), (
            f"{name}: warm (cached) graph differs from the cold one"
        )
        compiled = compile_graph(og)
        first, steady = _time_runner(compiled, args, reps)
        # VM baseline: the same optimized graph traced through the
        # interpreter (what every higher-order program did before this tier)
        vm = compile_graph(og, lower=False)
        vm_first, vm_steady = _time_runner(vm, args, reps)
        rows.append(
            {
                "workload": name,
                "vm_fallback": 0 if compiled.lowered else 1,
                "pipeline_ms": round(pipeline_s * 1e3, 1),
                "pipeline_phase_ms": {k: round(v, 1) for k, v in phase_ms.items()},
                "pipeline_phase_total_ms": round(phase_total, 1),
                "warm_pipeline_ms": round(warm_s * 1e3, 1),
                "warm_pipeline_phase_ms": {
                    k: round(v, 1) for k, v in warm_phase_ms.items()
                },
                "graph_cache_hit_rate": round(warm_hit_rate, 4),
                "compile_first_ms": round(first * 1e3, 2),
                "steady_us": round(steady * 1e6, 1),
                "vm_trace_first_ms": round(vm_first * 1e3, 2),
                "vm_steady_us": round(vm_steady * 1e6, 1),
            }
        )
    return rows


if __name__ == "__main__":
    for row in run(reps=10):
        print(row)
