"""Benchmark harness: one module per paper claim + the roofline reporter.

    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run --only ad_overhead

Results land in ``artifacts/bench/<name>.json`` and a summary prints to
stdout.  The roofline section only reports if the dry-run artifacts exist
(run ``python -m repro.launch.dryrun`` first)."""

from __future__ import annotations

import argparse
import json
import os


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    from . import bench_ad_overhead, bench_compile_time, bench_kernels, bench_opt_effectiveness

    benches = {
        "ad_overhead": bench_ad_overhead.run,
        "opt_effectiveness": bench_opt_effectiveness.run,
        "compile_time": bench_compile_time.run,
        "kernels": bench_kernels.run,
    }
    os.makedirs("artifacts/bench", exist_ok=True)
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n=== {name} ===")
        rows = fn()
        for row in rows:
            print("  ", row)
        with open(f"artifacts/bench/{name}.json", "w") as f:
            json.dump(rows, f, indent=1, default=str)

    # roofline summary (from dry-run artifacts, if present)
    if (args.only in (None, "roofline")) and os.path.isdir("artifacts/dryrun"):
        import glob

        if glob.glob("artifacts/dryrun/*.json"):
            print("\n=== roofline (see EXPERIMENTS.md §Roofline for the analysis) ===")
            from . import roofline

            roofline.main(["--md", "artifacts/roofline.md"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
