"""Benchmark harness: one module per paper claim + the roofline reporter.

    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run --only ad_overhead
    PYTHONPATH=src python -m benchmarks.run --quick    # reduced reps

Results land in ``artifacts/bench/<name>.json`` and a summary prints to
stdout.  The compile-time and AD-overhead rows are additionally written to
``BENCH_compile.json`` / ``BENCH_ad_overhead.json`` at the repo root so
successive PRs leave a perf trajectory to compare against (``--quick`` is
the cheap way to refresh them).  The roofline section only reports if the
dry-run artifacts exist (run ``python -m repro.launch.dryrun`` first)."""

from __future__ import annotations

import argparse
import json
import os
import random
import time

import numpy as np

#: repo-root trajectory files: bench name -> filename
TRAJECTORY = {
    "compile_time": "BENCH_compile.json",
    "ad_overhead": "BENCH_ad_overhead.json",
    "fusion": "BENCH_fusion.json",
    "spmd": "BENCH_spmd.json",
    "higher_order": "BENCH_higher_order.json",
    "serve": "BENCH_serve.json",
}


def _quick_selection(benches: dict) -> dict:
    """Narrow a ``--quick`` sweep to the benches whose module actually
    changed vs HEAD.  Only applies when *every* uncommitted change is a
    ``benchmarks/bench_*.py`` file — anything else (src/, run.py, configs)
    can shift any trajectory, so the full sweep runs.  This stops a
    serve-only bench edit from re-running the whole compile-time corpus."""
    import subprocess

    try:
        out = subprocess.run(
            ["git", "diff", "--name-only", "HEAD"],
            capture_output=True, text=True, check=True,
        ).stdout
    except Exception:
        return benches  # not a git checkout: run everything
    changed = {line.strip() for line in out.splitlines() if line.strip()}
    if not changed:
        return benches
    if any(
        not (c.startswith("benchmarks/bench_") and c.endswith(".py"))
        for c in changed
    ):
        return benches
    keep = {
        name: fn
        for name, fn in benches.items()
        if f"benchmarks/bench_{name}.py" in changed
    }
    if not keep:
        return benches
    skipped = sorted(set(benches) - set(keep))
    print(f"--quick: only {sorted(keep)} changed; skipping {skipped}")
    return keep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--quick",
        action="store_true",
        help="reduced reps; still refreshes the BENCH_*.json trajectory files",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="OUT.json",
        help="arm the tracer across the sweep and write a Chrome trace-event "
        "file (open in https://ui.perfetto.dev) covering every compile the "
        "benches trigger",
    )
    args = ap.parse_args(argv)

    from . import (
        bench_ad_overhead,
        bench_compile_time,
        bench_fusion,
        bench_higher_order,
        bench_kernels,
        bench_opt_effectiveness,
        bench_serve,
        bench_spmd,
    )

    benches = {
        "ad_overhead": lambda: bench_ad_overhead.run(reps=5 if args.quick else 30),
        "opt_effectiveness": bench_opt_effectiveness.run,
        "compile_time": lambda: bench_compile_time.run(reps=10 if args.quick else 50),
        "fusion": lambda: bench_fusion.run(reps=10 if args.quick else 50),
        "spmd": lambda: bench_spmd.run(reps=10 if args.quick else 30),
        "higher_order": lambda: bench_higher_order.run(reps=10 if args.quick else 30),
        "serve": bench_serve.run,
        "kernels": bench_kernels.run,
    }
    if args.quick and not args.only:
        # kernels are the slow outlier and have no trajectory file
        benches.pop("kernels")
        benches = _quick_selection(benches)
    from repro.obs import trace as obs_trace

    tracer = obs_trace.Tracer() if args.trace else None
    os.makedirs("artifacts/bench", exist_ok=True)
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n=== {name} ===")
        # Reseed the global RNGs per benchmark: trajectory diffs must be a
        # function of the code, not of which benches ran before this one
        # (--only vs the full sweep used to leave different global RNG
        # state, making BENCH json diffs ordering-dependent).
        random.seed(0)
        np.random.seed(0)
        t0 = time.perf_counter()
        with obs_trace.tracing(tracer):
            rows = fn()
        wall = time.perf_counter() - t0
        for row in rows:
            # ride-along provenance: how long the whole bench took, and
            # how heavy its instrumentation got (peak tracer occupancy) —
            # a trajectory diff can then tell "bench got slower" from
            # "tracing got heavier".  Not CI-gated (wall time is noisy).
            row["bench_wall_s"] = round(wall, 3)
            row["trace_buffer_peak"] = tracer.high_water if tracer else 0
        for row in rows:
            print("  ", row)
        with open(f"artifacts/bench/{name}.json", "w") as f:
            json.dump(rows, f, indent=1, default=str)
        if name in TRAJECTORY:
            with open(TRAJECTORY[name], "w") as f:
                json.dump(rows, f, indent=1, default=str)

    # roofline summary (from dry-run artifacts, if present)
    if (args.only in (None, "roofline")) and os.path.isdir("artifacts/dryrun"):
        import glob

        if glob.glob("artifacts/dryrun/*.json"):
            print("\n=== roofline (see EXPERIMENTS.md §Roofline for the analysis) ===")
            from . import roofline

            roofline.main(["--md", "artifacts/roofline.md"])
    if tracer is not None:
        tracer.write_chrome_trace(args.trace)
        print(
            f"\nwrote {len(tracer.events)} spans to {args.trace} "
            f"(open in https://ui.perfetto.dev)"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
