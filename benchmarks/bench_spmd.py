"""SPMD tier benchmark: launch counts and step time per mesh size.

For the MLP adjoint (the paper's running example) compiled through the
shard-aware tier, report — per mesh shape (1×1, 2×1, 2×2) —

* the **partition**: kernel launches of the per-shard program before and
  after fusion (collectives included; clusters never span one) and the
  collective counts the propagation pass inserted (psum / pmax /
  all_gather / shard_slice),
* **wall clock**: median jitted step time of the fused sharded program
  under ``shard_map`` vs the single-device unfused oracle, and the
  allclose check against that oracle (``max_rel_err``).

Mesh sizes beyond the host's device count are simulated per-row in a
subprocess with ``--xla_force_host_platform_device_count`` (the parent
process keeps its 1-device backend — same pattern as tests/distributed).
Rows land in ``BENCH_spmd.json`` via ``benchmarks/run.py`` so successive
PRs leave a trajectory; ``scripts/check_bench.py`` gates launch-count
regressions in CI.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import textwrap

_WORKER = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
    import sys
    sys.path.insert(0, %(src)r)
    import json, time
    import jax, numpy as np

    import repro.core.primitives as P
    from repro.core import build_grad_graph, parse_function
    from repro.core.api import compile_pipeline
    from repro.core.infer import abstract_of_value
    from repro.core.jax_backend import compile_graph_spmd
    from repro.core.lowering import lower_graph

    MESH = %(mesh)r
    REPS = %(reps)d

    def _two_layer(w1, w2, x):
        h = P.tanh(x @ w1)
        return P.reduce_sum(P.tanh(h @ w2), (0, 1), False)

    k = jax.random.PRNGKey
    d, b = 64, 32
    w1 = jax.random.normal(k(0), (d, d)) * 0.1
    w2 = jax.random.normal(k(1), (d, d)) * 0.1
    x = jax.random.normal(k(2), (b, d))
    args = (w1, w2, x)
    in_specs = (None, None, ("data",))

    g = compile_pipeline(
        build_grad_graph(parse_function(_two_layer), (0, 1)),
        tuple(abstract_of_value(a) for a in args),
    )
    oracle = jax.jit(lower_graph(g))
    ref = oracle(*args)

    def median_us(fn):
        r = fn(*args)
        jax.block_until_ready(r)  # compile outside the timer
        ts = []
        for _ in range(REPS):
            t0 = time.perf_counter()
            r = fn(*args)
            jax.block_until_ready(r)
            ts.append((time.perf_counter() - t0) * 1e6)
        ts.sort()
        return ts[len(ts) // 2]

    run = compile_graph_spmd(g, jax.make_mesh(MESH, ("data", "model")), in_specs, fuse=True)
    got = run(*args)
    rel = max(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b)))
              / (np.max(np.abs(np.asarray(b))) + 1e-30))
        for a, b in zip(got, ref)
    )
    plan = run.fn.__fusion_plan__
    stats = run.sharded.stats
    row = {
        "workload": "mlp_adjoint_dp",
        "mesh": "x".join(map(str, MESH)),
        "devices": %(ndev)d,
        "launches_unfused": plan.launches_before,
        "launches_fused": plan.launches_after,
        "n_clusters": len(plan.clusters),
        "n_psum": stats["psum"],
        "n_pmax": stats["pmax"],
        "n_all_gather": stats["all_gather"],
        "n_shard_slice": stats["shard_slice"],
        "oracle_us": round(median_us(oracle), 1),
        "spmd_fused_us": round(median_us(run), 1),
        "max_rel_err": float(f"{rel:.2e}"),
    }
    print("ROW " + json.dumps(row))
    """
)

_MESHES = (((1, 1), 1), ((2, 1), 2), ((2, 2), 4))


def run(reps: int = 30) -> list[dict]:
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    rows = []
    for mesh, ndev in _MESHES:
        script = _WORKER % {"ndev": ndev, "src": src, "mesh": mesh, "reps": reps}
        with tempfile.NamedTemporaryFile("w", suffix="_bench_spmd.py", delete=False) as f:
            f.write(script)
            path = f.name
        try:
            res = subprocess.run(
                [sys.executable, path], capture_output=True, text=True, timeout=600
            )
        finally:
            os.unlink(path)
        if res.returncode != 0:  # pragma: no cover - surfaced to the console
            raise RuntimeError(
                f"bench_spmd worker (mesh {mesh}) failed:\n{res.stderr[-2000:]}"
            )
        for line in res.stdout.splitlines():
            if line.startswith("ROW "):
                rows.append(json.loads(line[4:]))
    return rows


if __name__ == "__main__":
    for row in run(reps=10):
        print(row)
