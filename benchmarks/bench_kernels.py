"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference on CPU.

On this container the Pallas interpreter executes the kernel body in
Python, so wall-times are NOT indicative of TPU performance — the TPU
story is the roofline analysis.  What this bench DOES verify and report:
numerical agreement at benchmark shapes and the arithmetic-intensity
(FLOPs/byte) of each kernel, which determines which roofline regime it
lands in on a v5e (ridge point ≈ 240 FLOPs/byte)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention, ref, rmsnorm, ssd_scan


def _time(fn, *args, reps=3):
    r = fn(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps


def run() -> list[dict]:
    rows = []
    k = jax.random.PRNGKey(0)

    # flash attention: B=1 H=4 S=512 D=64
    B, H, S, D = 1, 4, 512, 64
    q = jax.random.normal(k, (B, H, S, D), jnp.float32)
    kk = jax.random.normal(k, (B, H // 2, S, D), jnp.float32)
    v = jax.random.normal(k, (B, H // 2, S, D), jnp.float32)
    o_ref = ref.flash_attention_ref(q, kk, v, causal=True)
    o_pal = flash_attention(q, kk, v, causal=True, impl="pallas_interpret")
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    flops = 4 * B * H * S * S / 2 * D
    bytes_ = (q.size + 2 * kk.size + o_ref.size) * 4
    rows.append(
        {
            "kernel": "flash_attention",
            "shape": f"B{B} H{H} S{S} D{D} GQA2 causal",
            "max_err_vs_ref": err,
            "flops_per_byte": round(flops / bytes_, 1),
            "regime_v5e": "compute-bound" if flops / bytes_ > 240 else "memory-bound",
            "ref_ms_cpu": round(
                _time(lambda: ref.flash_attention_ref(q, kk, v, causal=True)) * 1e3, 2
            ),
        }
    )

    # rmsnorm: 4096×1024
    x = jax.random.normal(k, (4096, 1024), jnp.float32)
    w = jnp.ones((1024,))
    err = float(jnp.max(jnp.abs(ref.rmsnorm_ref(x, w) - rmsnorm(x, w, impl="pallas_interpret"))))
    flops = 4 * x.size
    bytes_ = 2 * x.size * 4
    rows.append(
        {
            "kernel": "rmsnorm",
            "shape": "4096x1024",
            "max_err_vs_ref": err,
            "flops_per_byte": round(flops / bytes_, 2),
            "regime_v5e": "memory-bound (fusion target)",
            "ref_ms_cpu": round(_time(lambda: ref.rmsnorm_ref(x, w)) * 1e3, 2),
        }
    )

    # ssd scan: B=1 S=256 H=4 P=16 N=32
    Bt, S2, H2, P2, G2, N2 = 1, 256, 4, 16, 1, 32
    ks = jax.random.split(k, 5)
    xs = jax.random.normal(ks[0], (Bt, S2, H2, P2))
    dt = 0.1 * jax.random.uniform(ks[1], (Bt, S2, H2)) + 0.01
    A = -jnp.ones((H2,))
    Bm = jax.random.normal(ks[3], (Bt, S2, G2, N2))
    Cm = jax.random.normal(ks[4], (Bt, S2, G2, N2))
    y_ref, _ = ref.ssd_scan_ref(xs, dt, A, Bm, Cm)
    y_pal = ssd_scan(xs, dt, A, Bm, Cm, impl="pallas_interpret")
    err = float(jnp.max(jnp.abs(y_ref - y_pal)))
    L = 64
    flops = Bt * H2 * (S2 // L) * (2 * L * L * N2 + 2 * L * L * P2 + 2 * L * N2 * P2 * 2)
    bytes_ = (xs.size + Bm.size + Cm.size + y_ref.size) * 4
    rows.append(
        {
            "kernel": "ssd_scan",
            "shape": f"B{Bt} S{S2} H{H2} P{P2} N{N2} chunk{L}",
            "max_err_vs_ref": err,
            "flops_per_byte": round(flops / bytes_, 1),
            "regime_v5e": "compute-bound" if flops / bytes_ > 240 else "memory-bound",
            "ref_ms_cpu": round(_time(lambda: ref.ssd_scan_ref(xs, dt, A, Bm, Cm)) * 1e3, 2),
        }
    )
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
