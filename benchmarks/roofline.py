"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh).

Reads the dry-run artifacts (SPMD memory/collective schedule) and the
depth-extrapolation cost probes (true global HLO FLOPs/bytes — XLA's
cost_analysis counts scan bodies once, so the scanned production program
under-reports; see repro.launch.dryrun.cost_probe), then derives

    compute    = HLO_FLOPs        / (chips × 197 TFLOP/s bf16)
    memory     = HLO_bytes        / (chips × 819 GB/s HBM)
    collective = wire_bytes/chip  / (50 GB/s/link ICI)

plus MODEL_FLOPS (6·N_active·tokens + attention term) and the usefulness
ratio MODEL_FLOPS / HLO_FLOPs that exposes remat/routing waste.

    PYTHONPATH=src python -m benchmarks.roofline            # table to stdout
    PYTHONPATH=src python -m benchmarks.roofline --md FILE  # + markdown
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 per chip (TPU v5e)
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DRYRUN_DIR = "artifacts/dryrun"
_PROBE_DIR = "artifacts/probe"


# ---------------------------------------------------------------------------
# MODEL_FLOPS: useful work from the architecture formula
# ---------------------------------------------------------------------------


def active_params_per_token(cfg) -> float:
    """Parameters touched per token: dense layers fully, MoE layers only
    top-k (+shared) experts; embedding *gather* is free, the logits
    matmul counts via lm_head/tied-embed."""
    D = cfg.d_model
    hd = cfg.hd if cfg.n_heads else 0
    total = 0.0
    for spec in cfg.layer_specs():
        if spec.mixer == "attn":
            total += D * cfg.n_heads * hd * 2  # wq, wo
            total += D * cfg.n_kv_heads * hd * 2  # wk, wv
        else:
            G = 1
            conv_dim = cfg.d_inner + 2 * G * cfg.ssm_state
            total += D * (2 * cfg.d_inner + 2 * G * cfg.ssm_state + cfg.n_ssm_heads)
            total += cfg.conv_kernel * conv_dim + cfg.d_inner * D
        if spec.cross_attn:
            total += D * cfg.n_heads * hd * 2 + D * cfg.n_kv_heads * hd * 2
        if spec.ffn:
            F = (cfg.moe_d_ff or cfg.d_ff) if spec.moe else cfg.d_ff
            if spec.moe:
                total += 3 * D * F * (cfg.top_k + cfg.shared_experts) + D * cfg.num_experts
            else:
                total += 3 * D * F
    if cfg.enc_dec:  # encoder layers (dense attn + mlp)
        total += cfg.n_enc_layers * (
            D * cfg.n_heads * hd * 2 + D * cfg.n_kv_heads * hd * 2 + 3 * D * cfg.d_ff
        )
    total += D * cfg.vocab  # logits matmul
    return total


def attention_flops_per_token(cfg, ctx_len: int, causal: bool = True) -> float:
    """2·(QKᵀ) + 2·(PV) per attention layer at context ``ctx_len``."""
    if not cfg.n_heads:
        return 0.0
    eff = ctx_len / 2 if causal else ctx_len
    per_layer = 4 * eff * cfg.n_heads * cfg.hd
    n_attn = sum(s.mixer == "attn" for s in cfg.layer_specs())
    flops = n_attn * per_layer
    # local attention layers see at most the window
    n_local = sum(s.mixer == "attn" and s.attn_kind == "local" for s in cfg.layer_specs())
    if n_local:
        local_eff = min(cfg.local_window, ctx_len) / (2 if causal else 1)
        flops -= n_local * 4 * (eff - local_eff) * cfg.n_heads * cfg.hd
    return flops


def model_flops(cfg, cell) -> float:
    n_act = active_params_per_token(cfg)
    tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        return (6 * n_act + 3 * attention_flops_per_token(cfg, cell.seq_len)) * tokens
    if cell.kind == "prefill":
        return (2 * n_act + attention_flops_per_token(cfg, cell.seq_len)) * tokens
    # decode: one token per sequence against a ctx_len cache
    return (2 * n_act + attention_flops_per_token(cfg, cell.seq_len)) * cell.global_batch


# ---------------------------------------------------------------------------
# Assembly
# ---------------------------------------------------------------------------


def load(dryrun_dir=_DRYRUN_DIR, probe_dir=_PROBE_DIR) -> list[dict]:
    from repro.configs import SHAPES, get_config

    probes = {}
    for path in glob.glob(os.path.join(probe_dir, "*.json")):
        rec = json.load(open(path))
        probes[(rec["arch"], rec["cell"])] = rec

    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        arch, cellname, mesh = rec["arch"], rec["cell"], rec["mesh"]
        cfg = get_config(arch)
        cell = SHAPES[cellname]
        chips = 1
        for d in rec["mesh_shape"]:
            chips *= d
        probe = probes.get((arch, cellname))
        flops_g = probe["hlo_flops_global"] if probe else None
        bytes_g = probe["hlo_bytes_global"] if probe else None
        coll = sum(rec["collective_bytes"].values())
        row = {
            "arch": arch,
            "cell": cellname,
            "mesh": mesh,
            "chips": chips,
            "hlo_flops_global": flops_g,
            "hlo_bytes_global": bytes_g,
            "collective_bytes_per_chip": coll,
            "t_compute": (flops_g / (chips * PEAK_FLOPS)) if flops_g else None,
            "t_memory": (bytes_g / (chips * HBM_BW)) if bytes_g else None,
            "t_collective": coll / ICI_BW,
            "model_flops": model_flops(cfg, cell),
            "memory": rec["memory"],
            "collectives": rec["collective_bytes"],
        }
        if row["t_compute"] is not None:
            terms = {
                "compute": row["t_compute"],
                "memory": row["t_memory"],
                "collective": row["t_collective"],
            }
            row["bottleneck"] = max(terms, key=terms.get)
            step_time = max(terms.values())
            row["roofline_fraction"] = row["t_compute"] / step_time if step_time else 0.0
            row["useful_ratio"] = row["model_flops"] / flops_g if flops_g else None
        rows.append(row)
    return rows


def fmt_s(x):
    if x is None:
        return "—"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def to_markdown(rows) -> str:
    hdr = (
        "| arch | cell | mesh | compute | memory | collective | bottleneck "
        "| roofline frac | MODEL/HLO flops |\n|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        frac = r.get("roofline_fraction")
        useful = r.get("useful_ratio")
        lines.append(
            "| {arch} | {cell} | {mesh} | {c} | {m} | {x} | {b} | {f} | {u} |".format(
                arch=r["arch"],
                cell=r["cell"],
                mesh=r["mesh"].replace("_pod", ""),
                c=fmt_s(r["t_compute"]),
                m=fmt_s(r["t_memory"]),
                x=fmt_s(r["t_collective"]),
                b=r.get("bottleneck", "—"),
                f=f"{frac:.2f}" if frac is not None else "—",
                u=f"{useful:.2f}" if useful is not None else "—",
            )
        )
    return hdr + "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", default=None, help="write a markdown table here")
    ap.add_argument("--json", default="artifacts/roofline.json")
    args = ap.parse_args(argv)
    rows = load()
    for r in rows:
        print(
            f"{r['arch']:22s} {r['cell']:12s} {r['mesh']:18s} "
            f"C={fmt_s(r['t_compute']):>8s} M={fmt_s(r['t_memory']):>8s} "
            f"X={fmt_s(r['t_collective']):>8s}  {r.get('bottleneck','?'):10s} "
            f"frac={r.get('roofline_fraction', 0) or 0:.2f} "
            f"useful={r.get('useful_ratio') or 0:.2f}"
        )
    os.makedirs(os.path.dirname(args.json), exist_ok=True)
    with open(args.json, "w") as f:
        json.dump(rows, f, indent=1)
    if args.md:
        with open(args.md, "w") as f:
            f.write(to_markdown(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
