"""Paper Figure 1 claim: "After optimization, all functions and
backpropagators end up being inlined.  All unused computations are cut,
and what remains is an expression for ∂f/∂x that is essentially identical
to what one would have written by hand."

Measured as IR node counts of the AD-transformed graph before/after the
optimization pipeline, against the node count of the hand-written
derivative parsed directly."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import api as myia
from repro.core.opt import count_nodes


def run() -> list[dict]:
    import repro.core.primitives as P

    global _tanh
    _tanh = P.tanh

    cases = []

    def cube(x):
        return x ** 3

    def cube_hand(x):  # d/dx x³ by hand
        return 3.0 * x * x

    def poly(x):
        return 2.0 * x ** 3 + 4.0 * x * x + x + 1.0

    def poly_hand(x):
        return 6.0 * x * x + 8.0 * x + 1.0

    def chain(x):
        return _tanh(_tanh(_tanh(x)))

    for name, fn, hand, arg in [
        ("x**3 (paper Fig.1)", cube, cube_hand, 2.0),
        ("2x³+4x²+x+1", poly, poly_hand, 2.0),
        ("tanh∘tanh∘tanh", chain, None, 0.5),
    ]:
        g_noopt = myia.grad(fn, opt=False)
        g_opt = myia.grad(fn, opt=True)
        before = g_noopt.node_count(arg, optimized=False)
        after = g_opt.node_count(arg, optimized=True)
        row = {
            "case": name,
            "nodes_after_ad": before,
            "nodes_after_opt": after,
            "reduction": f"{before / after:.1f}×",
        }
        if hand is not None:
            h = myia.MyiaFunction(hand)
            row["nodes_handwritten"] = h.node_count(arg, optimized=True)
        # correctness unchanged by optimization
        assert abs(g_noopt(arg) - g_opt(arg)) < 1e-6
        cases.append(row)
    return cases


if __name__ == "__main__":
    for row in run():
        print(row)
