"""Paper Figure 1 claim: "After optimization, all functions and
backpropagators end up being inlined.  All unused computations are cut,
and what remains is an expression for ∂f/∂x that is essentially identical
to what one would have written by hand."

Measured as IR node counts of the AD-transformed graph before/after the
optimization pipeline, against the node count of the hand-written
derivative parsed directly.  Also records the worklist rewriter's effort
(``OptStats``): total rule hits, nodes examined, and verification-sweep
stragglers (which should stay 0 — see ``repro.core.opt``), plus whether
the optimized graph lowers to a straight-line callable.
"""

from __future__ import annotations


from repro.core import api as myia
from repro.core.infer import abstract_of_value
from repro.core.lowering import lowering_blockers
from repro.core.opt import OptStats, count_nodes
from repro.core.primitives import tanh as _tanh
from repro.obs import metrics as obs_metrics


def cube(x):
    return x ** 3


def cube_hand(x):  # d/dx x³ by hand
    return 3.0 * x * x


def poly(x):
    return 2.0 * x ** 3 + 4.0 * x * x + x + 1.0


def poly_hand(x):
    return 6.0 * x * x + 8.0 * x + 1.0


def chain(x):
    return _tanh(_tanh(_tanh(x)))


def _cascade_case(n: int = 400) -> dict:
    """Rewriter-engine scaling on a leaf→root constant-fold cascade — the
    worst case for whole-family sweeps (quadratic) and the best showcase of
    the worklist engine (linear)."""
    import time

    from repro.core.ir import Graph
    import repro.core.primitives as P

    def build():
        g = Graph("cascade")
        p = g.add_parameter("x")
        node = g.apply(P.add, 1.0, 1.0)
        for _ in range(n):
            node = g.apply(P.add, 1.0, node)
        g.set_return(g.apply(P.mul, p, node))
        return g

    from repro.core.opt import optimize

    row = {"case": f"fold_cascade({n})"}
    for engine in ("sweep", "worklist"):
        g = build()
        stats = OptStats()
        t0 = time.perf_counter()
        optimize(g, inline=False, engine=engine, stats=stats)
        row[f"{engine}_ms"] = round((time.perf_counter() - t0) * 1e3, 1)
    row["speedup"] = f"{row['sweep_ms'] / max(row['worklist_ms'], 1e-9):.1f}×"
    return row


def run() -> list[dict]:
    cases = []
    for name, fn, hand, arg in [
        ("x**3 (paper Fig.1)", cube, cube_hand, 2.0),
        ("2x³+4x²+x+1", poly, poly_hand, 2.0),
        ("tanh∘tanh∘tanh", chain, None, 0.5),
    ]:
        g_noopt = myia.grad(fn, options=myia.CompileOptions(opt=False))
        g_opt = myia.grad(fn, options=myia.CompileOptions(opt=True))
        before = g_noopt.node_count(arg, optimized=False)
        stats = OptStats()
        opt_graph = myia.compile_pipeline(
            g_opt.graph, (abstract_of_value(arg),), stats=stats
        )
        after = count_nodes(opt_graph)
        # one read through the unified schema instead of four attribute
        # spellings: OptStats is absorbed via its as_dict(), keys come out
        # flat and dotted (opt.total_rewrites, opt.rule_hits.<rule>, ...)
        snap = obs_metrics.snapshot(opt=stats)
        row = {
            "case": name,
            "nodes_after_ad": before,
            "nodes_after_opt": after,
            "reduction": f"{before / after:.1f}×",
            "rewrites": snap["opt.total_rewrites"],
            "inlined_calls": snap["opt.inlined_calls"],
            "worklist_pops": snap["opt.worklist_pops"],
            "verify_sweep_hits": snap["opt.verify_sweep_hits"],
            "top_rules": dict(
                sorted(
                    (
                        (k.split(".", 2)[2], v)
                        for k, v in snap.items()
                        if k.startswith("opt.rule_hits.")
                    ),
                    key=lambda kv: -kv[1],
                )[:5]
            ),
            "lowerable": not lowering_blockers(opt_graph),
        }
        if hand is not None:
            h = myia.MyiaFunction(hand)
            row["nodes_handwritten"] = h.node_count(arg, optimized=True)
        # correctness unchanged by optimization
        assert abs(g_noopt(arg) - g_opt(arg)) < 1e-6
        cases.append(row)
    cases.append(_cascade_case())
    return cases


if __name__ == "__main__":
    for row in run():
        print(row)
