"""Paper claim (§2.1, §5, footnote 1): OO/tape AD pays per-call tracing
overhead — pathological for scalar/small-tensor workloads — while ST
compiles the adjoint once and matches compiled frameworks.

Workloads:
  * scalar-heavy: an unrolled 40-step scalar recurrence (the pytorch
    issue #2518 pathology from the paper's footnote),
  * small-matrix MLP loss,
  * medium-matrix MLP loss (tracing amortizes — OO catches up).

Systems: OO tape interpreter (repro.core.oo_tape), Myia ST pipeline
(parse → closure-based AD → optimize → XLA), and raw jax.grad (the
"compiled framework" reference — itself the ST/closure lineage)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import api as myia
from repro.core import oo_tape as oo
from repro.core.primitives import reduce_sum as _sum
from repro.core.primitives import tanh as _tanh


def timeit(fn, *args, reps=30, warmup=3) -> float:
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6  # µs/call


# -- workloads (written once, consumed by all three systems) ----------------


def scalar_chain(x, y):
    z = x
    z = z * y + x
    z = z * z + y
    z = z * y + x
    z = z * z + y
    z = z * y + x
    z = z * z + y
    z = z * y + x
    z = z * z + y
    z = z * y + x
    z = z * z + y
    return z


def make_mlp(size):
    def mlp_loss_oo(w1, w2, x):
        h = oo.tanh(x @ w1)
        return oo.reduce_sum(oo.tanh(h @ w2))

    def mlp_loss(w1, w2, x):
        h = _tanh(x @ w1)
        return _sum(_tanh(h @ w2), (0, 1), False)

    return mlp_loss_oo, mlp_loss


def run(reps: int = 30) -> list[dict]:
    results = []

    # scalar workload
    oo_fn = oo.oo_grad(scalar_chain, wrt=(0, 1))
    st_fn = myia.grad(scalar_chain, wrt=(0, 1))
    jx_fn = jax.jit(jax.grad(scalar_chain, argnums=(0, 1)))
    a, b = 0.3, 0.7
    st_fn(a, b), jx_fn(a, b)  # compile outside timer
    results.append(
        {
            "workload": "scalar_chain(40 ops)",
            "oo_us": timeit(oo_fn, a, b, reps=reps),
            "st_myia_us": timeit(st_fn, a, b, reps=reps),
            "jax_grad_us": timeit(jx_fn, a, b, reps=reps),
        }
    )

    for size in (8, 256):
        oo_w, st_w = make_mlp(size)
        k = jax.random.PRNGKey(0)
        w1 = jax.random.normal(k, (size, size))
        w2 = jax.random.normal(k, (size, size))
        x = jax.random.normal(k, (4, size))
        oo_fn = oo.oo_grad(oo_w, wrt=(0, 1))
        st_fn = myia.grad(st_w, wrt=(0, 1))
        jx_fn = jax.jit(
            jax.grad(lambda a_, b_, c_: jnp.sum(jnp.tanh(jnp.tanh(c_ @ a_) @ b_)), argnums=(0, 1))
        )
        st_fn(w1, w2, x), jx_fn(w1, w2, x)
        results.append(
            {
                "workload": f"mlp_{size}x{size}",
                "oo_us": timeit(oo_fn, w1, w2, x, reps=reps),
                "st_myia_us": timeit(st_fn, w1, w2, x, reps=reps),
                "jax_grad_us": timeit(jx_fn, w1, w2, x, reps=reps),
            }
        )
    for r in results:
        r["oo_over_st"] = r["oo_us"] / r["st_myia_us"]
        r["st_over_jax"] = r["st_myia_us"] / r["jax_grad_us"]
    return results


if __name__ == "__main__":
    for row in run():
        print(row)
