"""Serving runtime: engine vs full-prefix oracle, bucket-bounded
compilation, continuous batching.

The hard contract (ISSUE 5 acceptance): a 64-token generation performs
exactly *buckets*-many decode compilations — never one per generated
length — and the engine's token streams are identical to the
full-prefix-recompute oracle (the pre-runtime serving path)."""

import jax
import numpy as np
import pytest

from repro.serve import (
    ServeEngine,
    ServeLMDims,
    bucket_for,
    init_serve_params,
    oracle_generate,
)

DIMS = ServeLMDims(vocab=48, d_model=8, d_hidden=16)
PARAMS = init_serve_params(DIMS, jax.random.PRNGKey(0))


def _prompts(spec, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, DIMS.vocab, n).tolist() for n in spec]


class TestBucketing:
    def test_power_of_two_rounding(self):
        assert bucket_for(1, min_bucket=16) == 16
        assert bucket_for(16, min_bucket=16) == 16
        assert bucket_for(17, min_bucket=16) == 32
        assert bucket_for(100, min_bucket=16) == 128

    def test_oversize_request_rejected(self):
        with pytest.raises(ValueError):
            bucket_for(5000, min_bucket=16, max_bucket=4096)


class TestEngineVsOracle:
    def test_mixed_requests_match_full_prefix_oracle(self):
        """Continuous batching (4 requests over 2 slots, two buckets)
        serves every stream identically to per-request O(T²) recompute."""
        engine = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16)
        prompts = _prompts([5, 9, 3, 20])
        max_new = [8, 6, 10, 14]
        rids = [engine.submit(p, m) for p, m in zip(prompts, max_new)]
        results = engine.run()
        fns: dict = {}
        for rid, prompt, m in zip(rids, prompts, max_new):
            want = oracle_generate(DIMS, PARAMS, prompt, m, fns=fns)
            assert results[rid]["tokens"] == want
        assert sorted(results) == sorted(rids)

    def test_single_token_request(self):
        engine = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16)
        prompt = _prompts([6])[0]
        rid = engine.submit(prompt, 1)
        results = engine.run()
        assert results[rid]["tokens"] == oracle_generate(DIMS, PARAMS, prompt, 1)


class TestCompilationBudget:
    def test_64_token_generation_compiles_per_bucket_not_per_length(self):
        """The acceptance bound: gen=64 ⇒ decode compilations == number of
        buckets (here 1), not 64."""
        engine = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16)
        rid = engine.submit(_prompts([4])[0], 64)  # total 68 → one 128-bucket
        results = engine.run()
        assert len(results[rid]["tokens"]) == 64
        assert engine.buckets_in_use == [128]
        assert engine.compilations["decode"] == len(engine.buckets_in_use) == 1
        assert engine.total_compilations == engine.compilation_floor() == 2

    def test_two_buckets_two_decode_specializations(self):
        engine = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16)
        for p, m in zip(_prompts([4, 40]), [8, 8]):
            engine.submit(p, m)
        engine.run()
        assert engine.buckets_in_use == [16, 64]
        assert engine.compilations == {"prefill": 2, "decode": 2}
        assert engine.total_compilations == engine.compilation_floor()

    def test_same_bucket_requests_share_the_specialization(self):
        engine = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16)
        for p in _prompts([3, 5, 7, 4]):
            engine.submit(p, 6)  # all land in the 16-bucket
        engine.run()
        assert engine.total_compilations == 2  # one prefill + one decode


class TestContinuousBatching:
    def test_queue_refills_freed_slots(self):
        """6 same-bucket requests over 2 slots: early finishers free their
        slot mid-flight and queued requests ride the SAME running batch —
        total decode steps must be far below the serial sum."""
        engine = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=32)
        prompts = _prompts([4, 5, 6, 7, 8, 9])
        max_new = [12, 4, 12, 4, 12, 4]
        rids = [engine.submit(p, m) for p, m in zip(prompts, max_new)]
        results = engine.run()
        assert sorted(results) == sorted(rids)
        serial_steps = sum(m - 1 for m in max_new)
        assert engine.steps < serial_steps
        fns: dict = {}
        for rid, prompt, m in zip(rids, prompts, max_new):
            assert results[rid]["tokens"] == oracle_generate(
                DIMS, PARAMS, prompt, m, fns=fns
            )

    def test_ttft_recorded(self):
        engine = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16)
        rid = engine.submit(_prompts([4])[0], 4)
        results = engine.run()
        assert results[rid]["ttft_s"] >= 0.0
        assert results[rid]["bucket"] == 16
