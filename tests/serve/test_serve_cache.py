"""AOT program cache under the serve engine: durable compiled programs.

* In-process cold→warm over one tmpdir cache: the warm engine answers
  every specialization from disk (hits > 0, zero XLA compiles) and
  serves identical tokens — this is the CI fast-job smoke.
* Subprocess cold→warm: a genuine process restart replays serialized
  executables with **zero recompilations** (the ISSUE 5 acceptance
  criterion), pinned via the cache counters.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.jax_backend import ProgramCache
from repro.serve import ServeEngine, ServeLMDims, init_serve_params

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "src")
)

DIMS = ServeLMDims(vocab=48, d_model=8, d_hidden=16)
PARAMS = init_serve_params(DIMS, jax.random.PRNGKey(0))


def _workload(engine):
    rng = np.random.default_rng(0)
    rids = [
        engine.submit(rng.integers(0, DIMS.vocab, n).tolist(), m)
        for n, m in [(5, 6), (9, 4), (3, 8)]
    ]
    return rids, engine.run()


def test_cold_then_warm_in_process(tmp_path):
    cold_cache = ProgramCache(str(tmp_path))
    cold = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16, program_cache=cold_cache)
    _rids, cold_res = _workload(cold)
    assert cold_cache.stats.misses > 0
    assert cold_cache.stats.puts == cold_cache.stats.misses
    assert cold_cache.stats.hits == 0

    warm_cache = ProgramCache(str(tmp_path))
    warm = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16, program_cache=warm_cache)
    _rids2, warm_res = _workload(warm)
    assert warm_cache.stats.hits > 0
    assert warm_cache.stats.misses == 0
    assert warm_cache.stats.xla_compiles == 0  # answered purely from disk
    assert warm_cache.stats.exec_loads == warm_cache.stats.hits
    for rid in cold_res:
        assert warm_res[rid]["tokens"] == cold_res[rid]["tokens"]


def test_aot_runner_survives_tracer_args_after_eager_call(tmp_path):
    """The specialization key cannot tell a concrete array from a
    same-shaped tracer: a MyiaFunction called eagerly first (caching the
    AOT runner) and then under an outer jit must not hand the compiled
    executable tracer arguments — it falls back to an ordinary jit."""
    import jax
    import jax.numpy as jnp
    from repro.core import P, api

    def f(x, w):
        return P.reduce_sum(P.tanh(x @ w), None, False)

    g = api.myia(f, options=api.CompileOptions(program_cache=ProgramCache(str(tmp_path))))
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8, 8), jnp.float32) * 0.1
    eager = g(x, w)  # caches the AOT runner for this signature
    assert getattr(g.specialize((x, w)), "aot", False)
    traced = jax.jit(lambda x_, w_: g(x_, w_) * 2.0)(x, w)
    np.testing.assert_allclose(
        np.asarray(traced), np.asarray(eager) * 2.0, rtol=1e-6
    )


def test_cache_spills_when_over_capacity(tmp_path):
    cache = ProgramCache(str(tmp_path), max_entries=1)
    engine = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16, program_cache=cache)
    rng = np.random.default_rng(0)
    engine.submit(rng.integers(0, DIMS.vocab, 4).tolist(), 4)    # 16-bucket
    engine.submit(rng.integers(0, DIMS.vocab, 20).tolist(), 8)   # 32-bucket
    engine.run()
    assert cache.stats.puts >= 2
    assert cache.stats.spills >= 1
    files = [n for n in os.listdir(tmp_path) if n.endswith(".pkl")]
    assert len(files) == 1


def _tiny_fn():
    from repro.core import P

    def f(x, w):
        return P.reduce_sum(P.tanh(x @ w), None, False)

    return f


def test_truncated_entry_quarantined_on_load(tmp_path):
    """A truncated entry file is classified corrupt, renamed aside
    (``*.quarantined``) so it is never re-read, and recompiled around —
    the caller sees a plain miss, never an exception."""
    import jax.numpy as jnp
    from repro.core import api

    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.full((8, 8), 0.1, jnp.float32)
    cache = ProgramCache(str(tmp_path))
    mf = api.myia(_tiny_fn(), options=api.CompileOptions(program_cache=cache))
    want = np.asarray(mf(x, w))
    (entry,) = [n for n in os.listdir(tmp_path) if n.endswith(".pkl")]
    with open(tmp_path / entry, "r+b") as f:
        f.truncate(16)

    cache2 = ProgramCache(str(tmp_path))
    mf2 = api.myia(_tiny_fn(), options=api.CompileOptions(program_cache=cache2))
    got = np.asarray(mf2(x, w))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert cache2.stats.corrupt_entries == 1
    assert cache2.stats.quarantined == 1
    assert cache2.stats.hits == 0 and cache2.stats.misses == 1
    names = set(os.listdir(tmp_path))
    assert entry + ".quarantined" in names  # renamed aside …
    assert entry in names  # … and the key re-written fresh by the miss

    cache3 = ProgramCache(str(tmp_path))
    mf3 = api.myia(_tiny_fn(), options=api.CompileOptions(program_cache=cache3))
    np.testing.assert_allclose(np.asarray(mf3(x, w)), want, rtol=1e-6)
    assert cache3.stats.hits == 1  # the re-written entry answers
    assert cache3.stats.corrupt_entries == 0  # quarantine was never re-read


_RACE_SCRIPT = textwrap.dedent(
    """
    import json, os, sys
    import jax.numpy as jnp
    from repro.core import P, api
    from repro.core.jax_backend import ProgramCache

    cachedir, iters = sys.argv[1], int(sys.argv[2])
    cache = ProgramCache(cachedir)

    def f(x, w):
        return P.reduce_sum(P.tanh(x @ w), None, False)

    mf = api.myia(f, options=api.CompileOptions(program_cache=cache))
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.full((8, 8), 0.1, jnp.float32)
    key = None
    for _ in range(iters):
        # churn the one shared key: unlink, then re-specialize (miss ->
        # compile -> atomic _write), racing the sibling process's
        # reads/writes of the same file
        if key is not None:
            try:
                os.unlink(os.path.join(cachedir, key + ".pkl"))
            except FileNotFoundError:
                pass
        mf._specializations.clear()
        runner = mf.specialize((x, w))
        key = getattr(runner, "cache_key", None)
        assert key is not None, "specialization left the AOT tier"
        float(runner(x, w))  # and the program actually runs
    print(json.dumps(cache.stats.as_dict()))
    """
)


@pytest.mark.slow
def test_concurrent_same_key_writers_last_writer_wins(tmp_path):
    """Two processes churn the SAME cache key concurrently (unlink +
    re-write through ``_write``'s tmpfile + atomic rename).  Torn reads
    would surface as ``corrupt_entries``/``quarantined`` in either
    process; the survivor entry must be a clean, loadable last-writer
    artifact."""
    script = tmp_path / "race.py"
    script.write_text(_RACE_SCRIPT)
    cachedir = tmp_path / "cache"
    cachedir.mkdir()
    env = dict(os.environ, PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    procs = [
        subprocess.Popen(
            [sys.executable, str(script), str(cachedir), "12"],
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
            text=True,
            env=env,
        )
        for _ in range(2)
    ]
    stats = []
    for p in procs:
        out, err = p.communicate(timeout=600)
        assert p.returncode == 0, err
        stats.append(json.loads(out.strip().splitlines()[-1]))
    for s in stats:
        # atomic rename ⇒ no reader ever saw a half-written entry
        assert s["corrupt_entries"] == 0, s
        assert s["quarantined"] == 0, s
        assert s["puts"] > 0, s
    # no tmpfile leaks, and exactly the one (last-written) entry survives
    names = os.listdir(cachedir)
    assert not [n for n in names if n.endswith(".tmp")], names
    assert len([n for n in names if n.endswith(".pkl")]) == 1, names

    import jax.numpy as jnp
    from repro.core import api

    cache = ProgramCache(str(cachedir))
    mf = api.myia(_tiny_fn(), options=api.CompileOptions(program_cache=cache))
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.full((8, 8), 0.1, jnp.float32)
    val = float(mf(x, w))
    assert cache.stats.hits == 1 and cache.stats.corrupt_entries == 0
    assert np.isfinite(val)


_SUBPROCESS_SCRIPT = textwrap.dedent(
    """
    import json, sys
    import jax, numpy as np
    from repro.core.jax_backend import ProgramCache
    from repro.serve import ServeEngine, ServeLMDims, init_serve_params

    dims = ServeLMDims(vocab=48, d_model=8, d_hidden=16)
    params = init_serve_params(dims, jax.random.PRNGKey(0))
    cache = ProgramCache(sys.argv[1])
    engine = ServeEngine(dims, params, n_slots=2, min_bucket=16, program_cache=cache)
    rng = np.random.default_rng(0)
    rids = [
        engine.submit(rng.integers(0, dims.vocab, n).tolist(), m)
        for n, m in [(5, 6), (9, 4), (3, 8)]
    ]
    results = engine.run()
    print(json.dumps({
        "stats": cache.stats.as_dict(),
        "engine": engine.stats(),
        "tokens": {str(r): results[r]["tokens"] for r in rids},
    }))
    """
)


@pytest.mark.slow
def test_warm_process_restart_zero_recompilations(tmp_path):
    """The acceptance criterion: the same workload in a fresh process hits
    the persistent cache for every specialization and performs zero XLA
    compilations, serving identical tokens."""
    script = tmp_path / "serve_once.py"
    script.write_text(_SUBPROCESS_SCRIPT)
    cachedir = tmp_path / "cache"
    env = dict(os.environ, PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    runs = []
    for _ in range(2):
        res = subprocess.run(
            [sys.executable, str(script), str(cachedir)],
            capture_output=True,
            text=True,
            env=env,
        )
        assert res.returncode == 0, res.stderr
        runs.append(json.loads(res.stdout.strip().splitlines()[-1]))
    cold, warm = runs
    assert cold["stats"]["misses"] == cold["engine"]["total_compilations"]
    assert cold["stats"]["xla_compiles"] > 0
    # warm restart: every lookup hits, nothing compiles
    assert warm["stats"]["misses"] == 0
    assert warm["stats"]["xla_compiles"] == 0
    assert warm["stats"]["hits"] == cold["stats"]["misses"]
    assert warm["stats"]["exec_loads"] == warm["stats"]["hits"]
    assert warm["tokens"] == cold["tokens"]
