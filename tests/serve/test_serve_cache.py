"""AOT program cache under the serve engine: durable compiled programs.

* In-process cold→warm over one tmpdir cache: the warm engine answers
  every specialization from disk (hits > 0, zero XLA compiles) and
  serves identical tokens — this is the CI fast-job smoke.
* Subprocess cold→warm: a genuine process restart replays serialized
  executables with **zero recompilations** (the ISSUE 5 acceptance
  criterion), pinned via the cache counters.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.jax_backend import ProgramCache
from repro.serve import ServeEngine, ServeLMDims, init_serve_params

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", "src")
)

DIMS = ServeLMDims(vocab=48, d_model=8, d_hidden=16)
PARAMS = init_serve_params(DIMS, jax.random.PRNGKey(0))


def _workload(engine):
    rng = np.random.default_rng(0)
    rids = [
        engine.submit(rng.integers(0, DIMS.vocab, n).tolist(), m)
        for n, m in [(5, 6), (9, 4), (3, 8)]
    ]
    return rids, engine.run()


def test_cold_then_warm_in_process(tmp_path):
    cold_cache = ProgramCache(str(tmp_path))
    cold = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16, program_cache=cold_cache)
    _rids, cold_res = _workload(cold)
    assert cold_cache.stats.misses > 0
    assert cold_cache.stats.puts == cold_cache.stats.misses
    assert cold_cache.stats.hits == 0

    warm_cache = ProgramCache(str(tmp_path))
    warm = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16, program_cache=warm_cache)
    _rids2, warm_res = _workload(warm)
    assert warm_cache.stats.hits > 0
    assert warm_cache.stats.misses == 0
    assert warm_cache.stats.xla_compiles == 0  # answered purely from disk
    assert warm_cache.stats.exec_loads == warm_cache.stats.hits
    for rid in cold_res:
        assert warm_res[rid]["tokens"] == cold_res[rid]["tokens"]


def test_aot_runner_survives_tracer_args_after_eager_call(tmp_path):
    """The specialization key cannot tell a concrete array from a
    same-shaped tracer: a MyiaFunction called eagerly first (caching the
    AOT runner) and then under an outer jit must not hand the compiled
    executable tracer arguments — it falls back to an ordinary jit."""
    import jax
    import jax.numpy as jnp
    from repro.core import P, api

    def f(x, w):
        return P.reduce_sum(P.tanh(x @ w), None, False)

    g = api.myia(f, program_cache=ProgramCache(str(tmp_path)))
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8, 8), jnp.float32) * 0.1
    eager = g(x, w)  # caches the AOT runner for this signature
    assert getattr(g.specialize((x, w)), "aot", False)
    traced = jax.jit(lambda x_, w_: g(x_, w_) * 2.0)(x, w)
    np.testing.assert_allclose(
        np.asarray(traced), np.asarray(eager) * 2.0, rtol=1e-6
    )


def test_cache_spills_when_over_capacity(tmp_path):
    cache = ProgramCache(str(tmp_path), max_entries=1)
    engine = ServeEngine(DIMS, PARAMS, n_slots=2, min_bucket=16, program_cache=cache)
    rng = np.random.default_rng(0)
    engine.submit(rng.integers(0, DIMS.vocab, 4).tolist(), 4)    # 16-bucket
    engine.submit(rng.integers(0, DIMS.vocab, 20).tolist(), 8)   # 32-bucket
    engine.run()
    assert cache.stats.puts >= 2
    assert cache.stats.spills >= 1
    files = [n for n in os.listdir(tmp_path) if n.endswith(".pkl")]
    assert len(files) == 1


_SUBPROCESS_SCRIPT = textwrap.dedent(
    """
    import json, sys
    import jax, numpy as np
    from repro.core.jax_backend import ProgramCache
    from repro.serve import ServeEngine, ServeLMDims, init_serve_params

    dims = ServeLMDims(vocab=48, d_model=8, d_hidden=16)
    params = init_serve_params(dims, jax.random.PRNGKey(0))
    cache = ProgramCache(sys.argv[1])
    engine = ServeEngine(dims, params, n_slots=2, min_bucket=16, program_cache=cache)
    rng = np.random.default_rng(0)
    rids = [
        engine.submit(rng.integers(0, dims.vocab, n).tolist(), m)
        for n, m in [(5, 6), (9, 4), (3, 8)]
    ]
    results = engine.run()
    print(json.dumps({
        "stats": cache.stats.as_dict(),
        "engine": engine.stats(),
        "tokens": {str(r): results[r]["tokens"] for r in rids},
    }))
    """
)


@pytest.mark.slow
def test_warm_process_restart_zero_recompilations(tmp_path):
    """The acceptance criterion: the same workload in a fresh process hits
    the persistent cache for every specialization and performs zero XLA
    compilations, serving identical tokens."""
    script = tmp_path / "serve_once.py"
    script.write_text(_SUBPROCESS_SCRIPT)
    cachedir = tmp_path / "cache"
    env = dict(os.environ, PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    runs = []
    for _ in range(2):
        res = subprocess.run(
            [sys.executable, str(script), str(cachedir)],
            capture_output=True,
            text=True,
            env=env,
        )
        assert res.returncode == 0, res.stderr
        runs.append(json.loads(res.stdout.strip().splitlines()[-1]))
    cold, warm = runs
    assert cold["stats"]["misses"] == cold["engine"]["total_compilations"]
    assert cold["stats"]["xla_compiles"] > 0
    # warm restart: every lookup hits, nothing compiles
    assert warm["stats"]["misses"] == 0
    assert warm["stats"]["xla_compiles"] == 0
    assert warm["stats"]["hits"] == cold["stats"]["misses"]
    assert warm["stats"]["exec_loads"] == warm["stats"]["hits"]
    assert warm["tokens"] == cold["tokens"]
