"""Chaos corpus: the serving runtime under every injected fault class.

The invariant (ISSUE 6 acceptance, gated in CI at a fixed fault seed):
under each fault class — corrupt cache entry, compile failure/hang, NaN
or inf decode, slot delay, oversized/zero-budget request, exhausted step
budget — ``ServeEngine.run()``

* terminates within its step budget (never hangs),
* serves *unaffected* requests token streams **bit-identical** to the
  full-prefix ``oracle_generate``,
* gives *affected* requests a structured non-``ok`` terminal status
  (``rejected`` / ``timeout`` / ``failed`` + taxonomy reason),
* never lets the fault escape as an exception or kill the process.

Every test also asserts ``plan.fired`` — a chaos test whose fault never
actually fired proves nothing.
"""

import os

import jax
import numpy as np
import pytest

from repro.core.jax_backend import ProgramCache
from repro.serve import (
    CacheFault,
    CompileFault,
    DecodeNaN,
    FaultPlan,
    ServeEngine,
    ServeLMDims,
    StepDelay,
    init_serve_params,
    inject_faults,
    oracle_generate,
)

SEED = 0xC0FFEE  # the fixed chaos seed (referenced by scripts/ci.sh)
DIMS = ServeLMDims(vocab=48, d_model=8, d_hidden=16)
PARAMS = init_serve_params(DIMS, jax.random.PRNGKey(0))

#: the fixed workload: (prompt_len, max_new); all land in the 16-bucket
WORKLOAD = [(5, 6), (9, 4), (3, 8)]
_ORACLE_FNS: dict = {}


def _prompts(seed=0):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(0, DIMS.vocab, n)) for n, _ in WORKLOAD]


def _oracle(prompt, max_new):
    return oracle_generate(DIMS, PARAMS, prompt, max_new, fns=_ORACLE_FNS)


def _engine(**kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("min_bucket", 16)
    return ServeEngine(DIMS, PARAMS, **kw)


def _submit_workload(engine):
    return [
        engine.submit(p, m) for p, (_, m) in zip(_prompts(), WORKLOAD)
    ]


def _assert_terminates(engine):
    assert engine.last_step_budget is not None
    assert engine.steps <= engine.last_step_budget


def _assert_structured(results, rids):
    for rid in rids:
        row = results[rid]
        assert row["status"] in ("ok", "rejected", "timeout", "failed")
        if row["status"] != "ok":
            assert row["reason"], f"non-ok rid {rid} lacks a structured reason"
            assert row["error"], f"non-ok rid {rid} lacks an error message"


class TestNoFaultBaseline:
    def test_armed_but_empty_plan_changes_nothing(self):
        """An armed plan with no fault specs is the production fast path:
        streams identical to the oracle, zero hooks fired."""
        engine = _engine()
        rids = _submit_workload(engine)
        with inject_faults(FaultPlan(seed=SEED)) as plan:
            results = engine.run()
        assert plan.fired == {}
        for rid, p, (_, m) in zip(rids, _prompts(), WORKLOAD):
            assert results[rid]["status"] == "ok"
            assert results[rid]["tokens"] == _oracle(p, m)
        _assert_terminates(engine)


class TestCorruptCache:
    @pytest.mark.parametrize("mode", ["garbage", "truncate", "delete"])
    def test_corrupt_entries_quarantined_streams_identical(self, tmp_path, mode):
        """A warm engine over a damaged cache dir recompiles around every
        bad entry: identical tokens, corrupt entries quarantined (renamed
        aside), never fatal."""
        cold = _engine(program_cache=ProgramCache(str(tmp_path)))
        rids = _submit_workload(cold)
        cold_results = cold.run()
        want = {r: cold_results[r]["tokens"] for r in rids}

        cache = ProgramCache(str(tmp_path))
        warm = _engine(program_cache=cache)
        rids2 = _submit_workload(warm)
        plan = FaultPlan(seed=SEED, cache_fault=CacheFault(mode=mode))
        with inject_faults(plan):
            results = warm.run()
        assert plan.fired.get("cache", 0) > 0
        _assert_structured(results, rids2)
        for r, r2 in zip(rids, rids2):
            assert results[r2]["status"] == "ok"
            assert results[r2]["tokens"] == want[r]
        if mode == "delete":
            assert cache.stats.misses > 0  # vanished entries are plain misses
        else:
            assert cache.stats.corrupt_entries > 0
            assert cache.stats.quarantined == cache.stats.corrupt_entries
            quarantined = [
                n for n in os.listdir(tmp_path) if n.endswith(".quarantined")
            ]
            assert len(quarantined) == cache.stats.quarantined
        _assert_terminates(warm)

    def test_quarantined_entry_never_reread(self, tmp_path):
        """After quarantine, a third run must not touch the renamed file:
        the re-written clean entry answers, with zero new corruption."""
        cache = ProgramCache(str(tmp_path))
        eng = _engine(program_cache=cache)
        rids = _submit_workload(eng)
        plan = FaultPlan(seed=SEED, cache_fault=CacheFault(mode="garbage"))
        with inject_faults(plan):
            eng.run()  # cold: nothing to corrupt (no entries yet)
        cache2 = ProgramCache(str(tmp_path))
        eng2 = _engine(program_cache=cache2)
        _submit_workload(eng2)
        with inject_faults(FaultPlan(seed=SEED, cache_fault=CacheFault(mode="garbage"))):
            eng2.run()  # warm: entries corrupted, quarantined, re-written
        before = {n for n in os.listdir(tmp_path) if n.endswith(".quarantined")}
        assert before
        cache3 = ProgramCache(str(tmp_path))
        eng3 = _engine(program_cache=cache3)
        rids3 = _submit_workload(eng3)
        results = eng3.run()  # no faults armed: clean warm restart
        assert cache3.stats.corrupt_entries == 0
        assert cache3.stats.misses == 0 and cache3.stats.hits > 0
        assert {n for n in os.listdir(tmp_path) if n.endswith(".quarantined")} == before
        for rid in rids3:
            assert results[rid]["status"] == "ok"
        assert len(rids) == len(rids3)


class TestCompileFaults:
    def test_transient_compile_failure_retries(self, tmp_path):
        """First compile attempt raises: the bounded retry absorbs it —
        all requests ok, streams oracle-identical, one retry counted."""
        cache = ProgramCache(str(tmp_path))
        engine = _engine(program_cache=cache)
        rids = _submit_workload(engine)
        plan = FaultPlan(seed=SEED, compile_fault=CompileFault(kind="raise", count=1))
        with inject_faults(plan):
            results = engine.run()
        assert plan.fired.get("compile") == 1
        assert cache.stats.compile_retries == 1
        assert cache.stats.vm_fallbacks == 0
        for rid, p, (_, m) in zip(rids, _prompts(), WORKLOAD):
            assert results[rid]["status"] == "ok"
            assert results[rid]["tokens"] == _oracle(p, m)
        _assert_terminates(engine)

    def test_persistent_compile_failure_degrades_to_vm(self, tmp_path):
        """Every compile attempt raises: the ladder bottoms out at the VM
        oracle — slow, but every request still completes with streams
        identical to the oracle, and the downgrade is counted."""
        cache = ProgramCache(str(tmp_path), max_compile_retries=1)
        engine = _engine(program_cache=cache)
        rids = _submit_workload(engine)
        plan = FaultPlan(seed=SEED, compile_fault=CompileFault(kind="raise", count=10**6))
        with inject_faults(plan):
            results = engine.run()
        assert plan.fired.get("compile", 0) >= 2
        assert cache.stats.vm_fallbacks > 0
        _assert_structured(results, rids)
        for rid, p, (_, m) in zip(rids, _prompts(), WORKLOAD):
            assert results[rid]["status"] == "ok"
            assert results[rid]["tokens"] == _oracle(p, m)
        _assert_terminates(engine)

    def test_compile_hang_absorbed_by_deadline(self, tmp_path):
        """A hung compile (finite injected sleep) delays admission past
        the request deadline: the request times out structurally, the
        engine never wedges."""
        cache = ProgramCache(str(tmp_path))
        engine = _engine(program_cache=cache, default_deadline_s=0.05)
        rids = _submit_workload(engine)
        plan = FaultPlan(
            seed=SEED, compile_fault=CompileFault(kind="hang", count=2, hang_s=0.2)
        )
        with inject_faults(plan):
            results = engine.run()
        assert plan.fired.get("compile", 0) > 0
        _assert_structured(results, rids)
        statuses = {results[r]["status"] for r in rids}
        assert "timeout" in statuses  # at least one request paid for the hang
        for r in rids:  # and nothing crashed or leaked an exception
            assert results[r]["status"] in ("ok", "timeout")
        _assert_terminates(engine)


class TestNumericalFaults:
    def test_nan_decode_fails_only_poisoned_slot(self):
        """Slot 0's logits NaN at decode step 2: that lane fails with a
        NumericalFault reason; the other lane's stream is bit-identical
        to the oracle."""
        engine = _engine()
        prompts = _prompts()
        a = engine.submit(prompts[0], 6)
        b = engine.submit(prompts[1], 6)
        plan = FaultPlan(seed=SEED, decode_nan=DecodeNaN(step=2, slot=0))
        with inject_faults(plan):
            results = engine.run()
        assert plan.fired.get("decode_nan") == 1
        assert results[a]["status"] == "failed"
        assert results[a]["reason"] == "nonfinite_logits"
        assert 0 < len(results[a]["tokens"]) < 6  # partial stream preserved
        assert results[b]["status"] == "ok"
        assert results[b]["tokens"] == _oracle(prompts[1], 6)
        assert engine.slot_faults == 1
        assert engine.stats()["statuses"]["failed"] == 1
        _assert_terminates(engine)

    def test_inf_prefill_fails_admission_only(self):
        """Infinite prefill logits fail that admission; later requests
        admit into the same slot and serve clean."""
        engine = _engine()
        prompts = _prompts()
        a = engine.submit(prompts[0], 6)
        b = engine.submit(prompts[1], 6)
        plan = FaultPlan(
            seed=SEED,
            decode_nan=DecodeNaN(step=0, site="prefill", value=float("inf")),
        )
        with inject_faults(plan):
            results = engine.run()
        assert plan.fired.get("decode_nan") == 1
        assert results[a]["status"] == "failed"
        assert results[a]["reason"] == "nonfinite_logits"
        assert results[a]["tokens"] == []
        assert results[b]["status"] == "ok"
        assert results[b]["tokens"] == _oracle(prompts[1], 6)
        _assert_terminates(engine)


class TestDelaysAndDeadlines:
    def test_step_delay_trips_deadline_not_liveness(self):
        """Injected per-step delays with a tight deadline: every request
        ends structurally (ok or timeout), the loop exits within budget."""
        engine = _engine(default_deadline_s=0.05)
        rids = _submit_workload(engine)
        plan = FaultPlan(seed=SEED, step_delay=StepDelay(delay_s=0.06))
        with inject_faults(plan):
            results = engine.run()
        assert plan.fired.get("delay", 0) > 0
        _assert_structured(results, rids)
        assert {results[r]["status"] for r in rids} <= {"ok", "timeout"}
        assert "timeout" in {results[r]["status"] for r in rids}
        timed_out = [r for r in rids if results[r]["status"] == "timeout"]
        assert all(results[r]["reason"] == "deadline" for r in timed_out)
        _assert_terminates(engine)

    def test_deadline_expires_in_queue(self):
        """A queued request whose deadline passes before a slot frees is
        retired from the queue with timeout — it never occupies a slot."""
        engine = _engine(n_slots=1)
        prompts = _prompts()
        a = engine.submit(prompts[0], 8)  # hogs the single slot
        b = engine.submit(prompts[1], 4, deadline_s=0.0)  # expired on arrival
        results = engine.run()
        assert results[a]["status"] == "ok"
        assert results[b]["status"] == "timeout"
        assert results[b]["tokens"] == []
        assert results[a]["tokens"] == _oracle(prompts[0], 8)

    def test_step_budget_exhaustion_fails_stragglers(self):
        """A run whose step budget is too small fails the remaining work
        with a structured step_budget reason instead of spinning."""
        engine = _engine()
        rids = _submit_workload(engine)
        results = engine.run(step_budget=2)
        _assert_structured(results, rids)
        assert engine.budget_exhausted == 1
        failed = [r for r in rids if results[r]["status"] == "failed"]
        assert failed
        assert all(results[r]["reason"] == "step_budget" for r in failed)
        assert engine.steps <= 2 + len(engine.buckets_in_use)


class TestAdmissionControl:
    def test_oversize_and_zero_budget_rejected_not_raised(self):
        engine = _engine()
        rng = np.random.default_rng(1)
        over = engine.submit(list(rng.integers(0, DIMS.vocab, 5000)), 8)
        zero = engine.submit([1, 2, 3], 0)
        neg = engine.submit([1, 2, 3], -4)
        ok = engine.submit([1, 2, 3], 4)
        results = engine.run()
        assert results[over]["status"] == "rejected"
        assert results[over]["reason"] == "oversize"
        assert results[zero]["status"] == "rejected"
        assert results[zero]["reason"] == "zero_budget"
        assert results[neg]["reason"] == "zero_budget"
        assert results[ok]["status"] == "ok"
        assert results[ok]["tokens"] == _oracle([1, 2, 3], 4)
        assert engine.stats()["rejected"] == {
            "oversize": 1, "zero_budget": 2, "queue_full": 0,
        }

    def test_bounded_queue_backpressure(self):
        engine = _engine(max_queue=2)
        prompts = _prompts()
        kept = [engine.submit(prompts[0], 4), engine.submit(prompts[1], 4)]
        shed = engine.submit(prompts[2], 4)
        results = engine.run()
        assert results[shed]["status"] == "rejected"
        assert results[shed]["reason"] == "queue_full"
        for rid in kept:
            assert results[rid]["status"] == "ok"
        stats = engine.stats()
        assert stats["rejected"]["queue_full"] == 1
        assert stats["queue_peak"] == 2

    def test_rejections_reported_once(self):
        """A second run() must not re-report a prior run's rejections."""
        engine = _engine()
        bad = engine.submit([1], 0)
        ok1 = engine.submit([1, 2], 4)
        first = engine.run()
        assert set(first) == {bad, ok1}
        ok2 = engine.submit([3, 4], 4)
        second = engine.run()
        assert set(second) == {ok2}
        assert second[ok2]["status"] == "ok"


class TestCombinedChaos:
    def test_kitchen_sink_terminates_with_structured_statuses(self, tmp_path):
        """Everything at once — corrupt warm cache, transient compile
        failure, NaN slot, step delays, tight deadlines, oversize and
        zero-budget requests: the run terminates, every rid gets a
        structured status, and no exception escapes."""
        cold = _engine(program_cache=ProgramCache(str(tmp_path)))
        _submit_workload(cold)
        cold.run()

        cache = ProgramCache(str(tmp_path))
        engine = _engine(program_cache=cache, default_deadline_s=2.0, max_queue=8)
        rng = np.random.default_rng(SEED)
        rids = _submit_workload(engine)
        rids.append(engine.submit(list(rng.integers(0, DIMS.vocab, 5000)), 4))
        rids.append(engine.submit([1, 2, 3], 0))
        plan = FaultPlan(
            seed=SEED,
            cache_fault=CacheFault(mode="garbage", count=2),
            compile_fault=CompileFault(kind="raise", count=1),
            decode_nan=DecodeNaN(step=3, slot=1),
            step_delay=StepDelay(delay_s=0.002),
        )
        with inject_faults(plan):
            results = engine.run()
        assert set(results) == set(rids)
        _assert_structured(results, rids)
        assert plan.fired  # chaos actually happened
        stats = engine.stats()
        assert stats["statuses"]["rejected"] == 2
        assert sum(stats["statuses"].values()) == len(rids)
        _assert_terminates(engine)
