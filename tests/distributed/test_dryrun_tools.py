"""Dry-run tooling: HLO collective parser, roofline term math, registry
coverage of the artifact matrix."""

import jax
import jax.numpy as jnp
import pytest


class TestCollectiveParser:
    def _parse(self, text):
        from repro.launch.dryrun import collective_bytes

        return collective_bytes(text)

    def test_counts_each_collective(self):
        hlo = """
  %ag = bf16[4,128]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %rs = f32[2,16]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = s8[8,8]{1,0} all-to-all(%w)
  %cp = bf16[64]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %mm = f32[4,4]{1,0} dot(%a, %b)
"""
        out = self._parse(hlo)
        assert out["all-gather"] == 4 * 128 * 2
        assert out["all-reduce"] == 1024 * 4
        assert out["reduce-scatter"] == 2 * 16 * 4
        assert out["all-to-all"] == 8 * 8 * 1
        assert out["collective-permute"] == 64 * 2

    def test_tuple_shapes_and_root(self):
        hlo = "  ROOT %ag = (f32[8]{0}, f32[8]{0}) all-gather(%a, %b)\n"
        assert self._parse(hlo)["all-gather"] == 2 * 8 * 4

    def test_real_compiled_module(self):
        """Parse an actual partitioned module containing an all-reduce."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        mesh = jax.make_mesh((1,), ("d",))

        def f(x):
            return jnp.sum(x)

        hlo = (
            jax.jit(f, in_shardings=NamedSharding(mesh, P()))
            .lower(jax.ShapeDtypeStruct((8, 8), jnp.float32))
            .compile()
            .as_text()
        )
        out = self._parse(hlo)  # must not raise; 1 device → no collectives
        assert all(v >= 0 for v in out.values())


class TestRooflineMath:
    def test_terms_and_bottleneck(self):
        import benchmarks.roofline as rl

        # synthetic row math (high arithmetic intensity → compute-bound)
        flops, bytes_, coll, chips = 1e16, 1e13, 1e9, 256
        tc = flops / (chips * rl.PEAK_FLOPS)
        tm = bytes_ / (chips * rl.HBM_BW)
        tx = coll / rl.ICI_BW
        assert tc > tm and tc > tx  # compute-bound in this regime

    def test_active_params_moe_vs_dense(self):
        from benchmarks.roofline import active_params_per_token
        from repro.configs import get_config

        kimi = get_config("kimi-k2-1t-a32b")
        n_act = active_params_per_token(kimi)
        # ~32B active (brief: a32b); must be way below the 1T total
        assert 2e10 < n_act < 6e10, n_act

    def test_attention_flops_local_vs_global(self):
        from benchmarks.roofline import attention_flops_per_token
        from repro.configs import get_config

        gemma = get_config("gemma3-1b")  # 5:1 local(512):global
        internlm = get_config("internlm2-1.8b")  # all global
        g = attention_flops_per_token(gemma, 32768)
        i = attention_flops_per_token(internlm, 32768)
        # per attention layer, gemma's local layers are far cheaper
        assert g / gemma.n_layers < i / internlm.n_layers

    def test_model_flops_kind_scaling(self):
        from benchmarks.roofline import model_flops
        from repro.configs import SHAPES, get_config

        cfg = get_config("internlm2-1.8b")
        tr = model_flops(cfg, SHAPES["train_4k"])
        de = model_flops(cfg, SHAPES["decode_32k"])
        assert tr > 1000 * de  # decode is one token per sequence
