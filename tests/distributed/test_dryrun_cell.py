"""The multi-pod dry-run stays green: lower+compile one real cell on the
production 16×16 mesh in a subprocess (the main pytest process has a
locked 1-device backend)."""

import json
import os
import subprocess
import sys


def test_dryrun_cell_compiles(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    res = subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.launch.dryrun",
            "--arch",
            "internlm2-1.8b",
            "--cell",
            "decode_32k",
            "--mesh",
            "single",
            "--out",
            str(tmp_path),
        ],
        capture_output=True,
        text=True,
        timeout=420,
        env=env,
        cwd=os.path.dirname(os.path.abspath("src")),
    )
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    art = tmp_path / "internlm2-1.8b__decode_32k__single_pod_16x16.json"
    rec = json.loads(art.read_text())
    assert rec["mesh_shape"] == [16, 16]
    assert rec["cost"]["flops"] > 0
    assert rec["memory"]["argument_size_bytes"] > 0
    assert sum(rec["collective_bytes"].values()) >= 0
